"""Sharding policy unit tests (no production mesh — uses the real device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES_BY_NAME
from repro.launch import sharding as shardlib
from repro.launch.specs import input_specs, arg_shardings
from repro.models.registry import build_model


class FakeMesh:
    """Shape-only stand-in so specs can be tested without 512 devices."""
    def __init__(self, shape, names):
        self.axis_names = names
        self._shape = shape
        import numpy as _np
        self.devices = _np.empty(shape, dtype=object)

    @property
    def shape(self):
        return dict(zip(self.axis_names, self._shape))


MESH1 = FakeMesh((16, 16), ("data", "model"))
MESH2 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _specs_ok(tree_specs, mesh, pspec_fn, **kw):
    """Every pspec must divide its dim evenly."""
    def visit(path, leaf):
        spec = pspec_fn(path, leaf, mesh, **kw)
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            n = shardlib._axis_size(mesh, axes)
            assert leaf.shape[dim] % n == 0, (path, leaf.shape, spec)
        return leaf
    jax.tree_util.tree_map_with_path(visit, tree_specs)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "granite-moe-3b-a800m",
                                  "xlstm-350m", "zamba2-1.2b",
                                  "whisper-medium", "gemma3-12b"])
@pytest.mark.parametrize("mesh", [MESH1, MESH2])
def test_param_specs_divisible(arch, mesh):
    model = build_model(get_config(arch), param_dtype=jnp.bfloat16)
    specs = model.param_specs()
    _specs_ok(specs, mesh, shardlib.param_pspec, fsdp=True)
    _specs_ok(specs, mesh, shardlib.param_pspec, fsdp=False)


@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-110b", "decode_32k"), ("gemma3-12b", "long_500k"),
    ("zamba2-1.2b", "long_500k"), ("xlstm-350m", "decode_32k"),
    ("whisper-medium", "decode_32k")])
@pytest.mark.parametrize("mesh", [MESH1, MESH2])
def test_cache_specs_divisible(arch, shape, mesh):
    shp = SHAPES_BY_NAME[shape]
    model = build_model(get_config(arch), param_dtype=jnp.bfloat16)
    caches = model.cache_specs(shp.global_batch, shp.seq_len)
    _specs_ok(caches, mesh, shardlib.cache_pspec, batch=shp.global_batch)


def test_kv_cache_seq_sharded_when_batch_one():
    model = build_model(get_config("gemma3-12b"), param_dtype=jnp.bfloat16)
    shp = SHAPES_BY_NAME["long_500k"]
    caches = model.cache_specs(1, shp.seq_len)
    found_seq_shard = []

    def visit(path, leaf):
        name = shardlib._path_names(path)[-1]
        if name == "k" and leaf.shape[-3] > 4096:   # a global-attn cache
            spec = shardlib.cache_pspec(path, leaf, MESH1, batch=1)
            found_seq_shard.append(spec[leaf.ndim - 3])
        return leaf
    jax.tree_util.tree_map_with_path(visit, caches)
    assert found_seq_shard and all(s is not None for s in found_seq_shard)


def test_param_bytes_estimate_sane():
    model = build_model(get_config("qwen1.5-110b"), param_dtype=jnp.bfloat16)
    specs = model.param_specs()
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(specs))
    per_tp = shardlib.estimate_param_bytes_per_device(specs, MESH1,
                                                      fsdp=False)
    per_fsdp = shardlib.estimate_param_bytes_per_device(specs, MESH1,
                                                        fsdp=True)
    assert total > 180e9            # ~110B params bf16
    assert per_tp < total / 8       # TP sharding is effective
    assert per_fsdp < per_tp / 8    # FSDP on top


def test_batch_axes_divisibility():
    assert shardlib.batch_axes(MESH2, 256) == ("pod", "data")
    assert shardlib.batch_axes(MESH2, 32) == ("pod", "data")
    assert shardlib.batch_axes(MESH2, 16) == ("data",)
    assert shardlib.batch_axes(MESH2, 1) is None
    assert shardlib.batch_axes(MESH1, 128) == ("data",)
