"""Sharding policy unit tests (no production mesh — uses the real device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES_BY_NAME
from repro.launch import sharding as shardlib
from repro.launch.specs import input_specs, arg_shardings
from repro.models.registry import build_model


class FakeMesh:
    """Shape-only stand-in so specs can be tested without 512 devices."""
    def __init__(self, shape, names):
        self.axis_names = names
        self._shape = shape
        import numpy as _np
        self.devices = _np.empty(shape, dtype=object)

    @property
    def shape(self):
        return dict(zip(self.axis_names, self._shape))


MESH1 = FakeMesh((16, 16), ("data", "model"))
MESH2 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _specs_ok(tree_specs, mesh, pspec_fn, **kw):
    """Every pspec must divide its dim evenly."""
    def visit(path, leaf):
        spec = pspec_fn(path, leaf, mesh, **kw)
        for dim, axes in enumerate(spec):
            if axes is None:
                continue
            n = shardlib._axis_size(mesh, axes)
            assert leaf.shape[dim] % n == 0, (path, leaf.shape, spec)
        return leaf
    jax.tree_util.tree_map_with_path(visit, tree_specs)


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "granite-moe-3b-a800m",
                                  "xlstm-350m", "zamba2-1.2b",
                                  "whisper-medium", "gemma3-12b"])
@pytest.mark.parametrize("mesh", [MESH1, MESH2])
def test_param_specs_divisible(arch, mesh):
    model = build_model(get_config(arch), param_dtype=jnp.bfloat16)
    specs = model.param_specs()
    _specs_ok(specs, mesh, shardlib.param_pspec, fsdp=True)
    _specs_ok(specs, mesh, shardlib.param_pspec, fsdp=False)


@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-110b", "decode_32k"), ("gemma3-12b", "long_500k"),
    ("zamba2-1.2b", "long_500k"), ("xlstm-350m", "decode_32k"),
    ("whisper-medium", "decode_32k")])
@pytest.mark.parametrize("mesh", [MESH1, MESH2])
def test_cache_specs_divisible(arch, shape, mesh):
    shp = SHAPES_BY_NAME[shape]
    model = build_model(get_config(arch), param_dtype=jnp.bfloat16)
    caches = model.cache_specs(shp.global_batch, shp.seq_len)
    _specs_ok(caches, mesh, shardlib.cache_pspec, batch=shp.global_batch)


def test_kv_cache_seq_sharded_when_batch_one():
    model = build_model(get_config("gemma3-12b"), param_dtype=jnp.bfloat16)
    shp = SHAPES_BY_NAME["long_500k"]
    caches = model.cache_specs(1, shp.seq_len)
    found_seq_shard = []

    def visit(path, leaf):
        name = shardlib._path_names(path)[-1]
        if name == "k" and leaf.shape[-3] > 4096:   # a global-attn cache
            spec = shardlib.cache_pspec(path, leaf, MESH1, batch=1)
            found_seq_shard.append(spec[leaf.ndim - 3])
        return leaf
    jax.tree_util.tree_map_with_path(visit, caches)
    assert found_seq_shard and all(s is not None for s in found_seq_shard)


def test_param_bytes_estimate_sane():
    model = build_model(get_config("qwen1.5-110b"), param_dtype=jnp.bfloat16)
    specs = model.param_specs()
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(specs))
    per_tp = shardlib.estimate_param_bytes_per_device(specs, MESH1,
                                                      fsdp=False)
    per_fsdp = shardlib.estimate_param_bytes_per_device(specs, MESH1,
                                                        fsdp=True)
    assert total > 180e9            # ~110B params bf16
    assert per_tp < total / 8       # TP sharding is effective
    assert per_fsdp < per_tp / 8    # FSDP on top


def test_batch_axes_divisibility():
    assert shardlib.batch_axes(MESH2, 256) == ("pod", "data")
    assert shardlib.batch_axes(MESH2, 32) == ("pod", "data")
    assert shardlib.batch_axes(MESH2, 16) == ("data",)
    assert shardlib.batch_axes(MESH2, 1) is None
    assert shardlib.batch_axes(MESH1, 128) == ("data",)


# ---------------------------------------------------------------------------
# paged / int8 pool leaves (page-major rules)
# ---------------------------------------------------------------------------
PAGED_MESH = FakeMesh((2, 4), ("data", "model"))

needs8 = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="run with XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _sds(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pspecs(tree, mesh, batch):
    out = {}

    def visit(path, leaf):
        out[shardlib._path_names(path)[-1]] = shardlib.cache_pspec(
            path, leaf, mesh, batch=batch)
        return leaf
    jax.tree_util.tree_map_with_path(visit, tree)
    return out


def test_cache_pspec_paged_divisible():
    # int8 pool: (P+1=64, ps=16, KV=8, hd=32) on (data=2, model=4) —
    # page axis over data, KV heads over model, scales follow the pages
    tree = {"self": {"kp": _sds(64, 16, 8, 32, dtype=jnp.int8),
                     "vp": _sds(64, 16, 8, 32, dtype=jnp.int8),
                     "ks": _sds(64, 16, 8), "vs": _sds(64, 16, 8),
                     "pos": _sds(64, 16, dtype=jnp.int32)}}
    sp = _pspecs(tree, PAGED_MESH, batch=4)
    assert sp["kp"] == P(("data",), None, "model", None)
    assert sp["vp"] == P(("data",), None, "model", None)
    assert sp["ks"] == P(("data",), None, "model")
    assert sp["vs"] == P(("data",), None, "model")
    assert sp["pos"] == P(("data",), None)


def test_cache_pspec_paged_indivisible_replicates():
    # 2 KV heads can't split over model=4; 65 pages can't split over
    # data=2 — both must fall back to replication, never mis-shard
    tree = {"self": {"kp": _sds(65, 16, 2, 32), "vp": _sds(65, 16, 2, 32),
                     "pos": _sds(65, 16, dtype=jnp.int32)}}
    sp = _pspecs(tree, PAGED_MESH, batch=4)
    assert sp["kp"] == P(None, None, None, None)
    assert sp["pos"] == P(None, None)


def test_cache_pspec_paged_stacked_segment():
    # scanned segments carry a leading layer axis; dims located from the
    # right so the same rules apply
    tree = {"self": {"kp": _sds(2, 64, 16, 8, 32),
                     "ks": _sds(2, 64, 16, 8)}}
    sp = _pspecs(tree, PAGED_MESH, batch=4)
    assert sp["kp"] == P(None, ("data",), None, "model", None)
    assert sp["ks"] == P(None, ("data",), None, "model")


def test_cache_pspec_dense_pos_untouched_by_paged_rules():
    # dense pos (B, S) with B == batch keeps the batch/seq rules; paged
    # pos is recognized by its page-major first dim != batch
    dense = _pspecs({"self": {"pos": _sds(4, 64, dtype=jnp.int32)}},
                    PAGED_MESH, batch=4)
    assert dense["pos"] == P(("data",), "model")


@pytest.mark.parametrize("arch", ["qwen1.5-110b", "gemma3-12b"])
@pytest.mark.parametrize("kv_dtype", ["float32", "int8"])
@pytest.mark.parametrize("mesh", [MESH1, MESH2])
def test_paged_cache_specs_divisible(arch, kv_dtype, mesh):
    model = build_model(get_config(arch), param_dtype=jnp.bfloat16)
    specs = jax.eval_shape(
        lambda: model.init_paged_cache(8, 63, 16, kv_dtype=kv_dtype))
    _specs_ok(specs, mesh, shardlib.cache_pspec, batch=8)


def test_param_pspec_head_aligned_attention():
    # GQA: 2 KV heads on a model axis of 4 — wk/wv must replicate (a
    # mid-head shard splits head_dim across devices: wrong parallelism
    # and an XLA resharding hazard on the heads reshape); wq/wo with 4
    # heads shard cleanly
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="tiny-tp", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=256, tie_embeddings=True,
                      exit_layers=(1, 2)).validate()
    model = build_model(cfg)
    hd = cfg.resolved_head_dim
    seen = set()

    def visit(path, leaf):
        name = shardlib._path_names(path)[-1]
        sp = shardlib.param_pspec(path, leaf, PAGED_MESH, fsdp=False,
                                  head_dim=hd)
        if name in ("wk", "wv"):
            assert all(s is None for s in sp), (name, sp)
        elif name == "wq":
            assert sp[leaf.ndim - 1] == "model", sp
        elif name == "wo":
            assert sp[leaf.ndim - 2] == "model", sp
        else:
            return leaf
        seen.add(name)
        return leaf
    jax.tree_util.tree_map_with_path(visit, model.param_specs())
    assert seen == {"wq", "wk", "wv", "wo"}


# ---------------------------------------------------------------------------
# launch/mesh.py + estimate-vs-actual (forced multi-device lane)
# ---------------------------------------------------------------------------
def test_make_debug_mesh_clamps_to_available():
    from repro.launch.mesh import make_debug_mesh
    assert make_debug_mesh(1).devices.size == 1
    assert make_debug_mesh(10 ** 6).devices.size == len(jax.devices())


def test_make_cloud_mesh_too_few_devices():
    from repro.launch.mesh import make_cloud_mesh
    with pytest.raises(ValueError, match="device_"):
        make_cloud_mesh((64, 64))


def test_make_cloud_mesh_rejects_bad_shape():
    from repro.launch.mesh import make_cloud_mesh
    with pytest.raises(ValueError, match="pair"):
        make_cloud_mesh((2, 4, 1))
    with pytest.raises(ValueError, match="pair"):
        make_cloud_mesh((0, 2))


@needs8
def test_make_debug_mesh_device_counts():
    from repro.launch.mesh import make_debug_mesh
    assert dict(make_debug_mesh(8).shape) == {"data": 2, "model": 4}
    assert dict(make_debug_mesh(6).shape) == {"data": 3, "model": 2}
    assert dict(make_debug_mesh(3).shape) == {"data": 3, "model": 1}


@needs8
def test_pod_submeshes_split():
    from repro.launch.mesh import pod_submeshes
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    edge, cloud = pod_submeshes(mesh)
    assert edge.axis_names == ("data", "model")
    assert cloud.axis_names == ("data", "model")
    assert edge.devices.size == 4 and cloud.devices.size == 4
    eids = {d.id for d in edge.devices.flat}
    cids = {d.id for d in cloud.devices.flat}
    assert eids.isdisjoint(cids)


@needs8
def test_estimate_matches_actual_device_bytes():
    # the analytic estimate must agree with what device_put actually
    # commits per device under the same specs
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(name="tiny-tp-bytes", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=256, tie_embeddings=True,
                      exit_layers=(1, 2)).validate()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    hd = cfg.resolved_head_dim
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    placed = jax.device_put(
        params, shardlib.params_shardings(params, mesh, fsdp=False,
                                          head_dim=hd))
    dev0 = mesh.devices.flat[0]
    actual = sum(s.data.nbytes
                 for l in jax.tree.leaves(placed)
                 for s in l.addressable_shards if s.device == dev0)
    est = shardlib.estimate_param_bytes_per_device(
        model.param_specs(), mesh, fsdp=False, head_dim=hd)
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    assert actual == pytest.approx(est, rel=1e-6)
    assert actual < total           # model-axis sharding is effective
