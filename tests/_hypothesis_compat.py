"""Property-test shim: real hypothesis when installed, a tiny deterministic
fallback otherwise.

The tier-1 suite must collect and run green without optional dependencies
(ISSUE 1 satellite).  When ``hypothesis`` is available we re-export it
untouched; otherwise ``given``/``settings``/``st`` are replaced by a
minimal sampler that draws ``max_examples`` pseudo-random examples from a
fixed seed — far weaker than hypothesis (no shrinking, no database), but
it keeps the properties exercised instead of skipped.

Usage (in tests):  ``from _hypothesis_compat import given, settings, st``
"""
from __future__ import annotations

try:                                      # pragma: no cover - env dependent
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # fallback shim
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 10
    _SEED = 0xCEC0117

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda r: min_value + (max_value - min_value) * r.random())

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(values):
            vals = list(values)
            return _Strategy(lambda r: vals[r.randrange(len(vals))])

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        """Record max_examples on the (already given-wrapped) test."""
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def run(*args, **kwargs):
                n = getattr(run, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(_SEED)
                for _ in range(n):
                    drawn = {k: s.example_from(rng)
                             for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # hide the strategy-supplied params so pytest does not treat
            # them as fixtures (hypothesis does the same)
            sig = inspect.signature(fn)
            run.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return run
        return deco
