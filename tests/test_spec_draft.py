"""Draft-lifecycle property suite for multi-token speculative drafting
(``CollmConfig.spec_k``).

The invariant under test: k-token edge drafts with batched cloud
verification are *invisible in output space* — for greedy decoding, the
accept-prefix/rewind reconcile converges every stream to the exact
blocking non-speculative token sequence, for every draft length, KV
layout, backfill mode, and latency trace (as long as replies beat their
deadlines).  Finite deadlines commit whole drafts as edge tokens; the
lifecycle stays conservation-exact either way."""
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.collm import CoLLM, CollmConfig
from repro.core.netsim import NetworkParams
from repro.core.transport import AsyncSimChannel, ScriptedChannel
from repro.serving.engine import GenStats, ServingSystem, _aggregate

WIFI = NetworkParams(up_bw=3.8e6, down_bw=8e6, rtt=0.003)
MAX_NEW = 12
PROMPT_LENS = [8, 11, 9]

# blocking non-speculative baselines, one per KV layout (module-level memo:
# every equality test below compares against the same reference stream over
# the same prompts — the corpus sampler is stateful, so sample once)
_BASELINES = {}
_PROMPTS = []


def _prompts(data):
    if not _PROMPTS:
        _PROMPTS.extend(data.sample_tokens(n) for n in PROMPT_LENS)
    return list(_PROMPTS)


def _baseline(tiny_trained, layout):
    if layout not in _BASELINES:
        model, params, data = (tiny_trained["model"], tiny_trained["params"],
                               tiny_trained["data"])
        _BASELINES[layout] = ServingSystem(
            model, params, CollmConfig(theta=0.8, kv_layout=layout)
        ).generate(_prompts(data), MAX_NEW, mode="collm", num_slots=2)
    return _BASELINES[layout]


def _draft_run(tiny_trained, channel, *, k, layout="dense", backfill=False,
               fallback_after=0):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    ccfg = CollmConfig(theta=0.8, kv_layout=layout, speculative=True,
                       spec_k=k, backfill=backfill)
    return ServingSystem(model, params, ccfg).generate(
        _prompts(data), MAX_NEW, mode="collm", num_slots=2, channel=channel,
        tick_time_s=0.01, fallback_after=fallback_after)


def _check_accept_histogram(stats: GenStats, k: int) -> None:
    """Accept-length sanity: every verified draft accepts a prefix of at
    most k tokens, and the counters are the histogram's marginals."""
    assert all(0 <= a <= k for a in stats.accept_lens)
    assert stats.accepted_tokens == sum(stats.accept_lens)
    assert stats.accepted_tokens <= stats.draft_tokens


# ---------------------------------------------------------------------------
# config validation (no decode)
# ---------------------------------------------------------------------------
def test_spec_k_config_validation(tiny_trained):
    model = tiny_trained["model"]
    assert CollmConfig().spec_k == 1               # default = classic path
    CoLLM(model, CollmConfig(speculative=True, spec_k=8))   # fine
    with pytest.raises(ValueError):
        CoLLM(model, CollmConfig(speculative=True, spec_k=0))
    with pytest.raises(ValueError):
        CoLLM(model, CollmConfig(spec_k=2))        # needs speculative=True


def test_draft_counters_aggregate():
    agg = _aggregate([GenStats(draft_tokens=4, accepted_tokens=3,
                               accept_lens=[2, 1]),
                      None,
                      GenStats(draft_tokens=2, accept_lens=[0, 0])])
    assert (agg.draft_tokens, agg.accepted_tokens) == (6, 3)
    assert agg.accept_lens == [2, 1, 0, 0]


# ---------------------------------------------------------------------------
# draft streams are invisible: identical to the blocking run
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_spec_draft_matches_blocking(tiny_trained, layout, k):
    base = _baseline(tiny_trained, layout)
    r = _draft_run(tiny_trained,
                   AsyncSimChannel(WIFI, service_s=0.004), k=k,
                   layout=layout)
    assert r["tokens"] == base["tokens"]
    bs, rs = base["stats"], r["stats"]
    # the reconcile restores the blocking run's event mix exactly: every
    # rejected suffix was fully re-decoded, every accepted prefix was
    # re-labelled a cloud token
    assert (bs.tokens, bs.cloud_requests, bs.exits_l1, bs.exits_l2) == \
        (rs.tokens, rs.cloud_requests, rs.exits_l1, rs.exits_l2)
    assert rs.stall_s == 0.0 and rs.overlap_s > 0.0
    assert rs.draft_tokens > 0
    _check_accept_histogram(rs, k)


def test_spec_k1_is_the_classic_speculative_path(tiny_trained):
    """Regression anchor: spec_k=1 must BE today's speculative path — a
    config that never mentions spec_k runs token- and stat-identically to
    an explicit spec_k=1, and every verification request carries exactly
    one draft token (requests == draft_tokens == resolved groups)."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data)
    runs = []
    for ccfg in (CollmConfig(theta=0.8, speculative=True),
                 CollmConfig(theta=0.8, speculative=True, spec_k=1)):
        runs.append(ServingSystem(model, params, ccfg).generate(
            prompts, MAX_NEW, mode="collm", num_slots=2,
            channel=AsyncSimChannel(WIFI, service_s=0.004),
            tick_time_s=0.01))
    default, explicit = runs
    assert default["tokens"] == explicit["tokens"]
    d, e = default["stats"], explicit["stats"]
    assert (d.draft_tokens, d.accepted_tokens, d.accept_lens,
            d.spec_rewinds, d.deadline_misses) == \
        (e.draft_tokens, e.accepted_tokens, e.accept_lens,
         e.spec_rewinds, e.deadline_misses)
    assert default["virtual_time"] == explicit["virtual_time"]
    # one request per draft token; one accept-length entry per RESOLVED
    # group (a rewind discards its successors' in-flight groups, whose
    # replies then late-drop without a histogram entry)
    assert default["channel_stats"]["requests"] == d.draft_tokens
    # (never-polled in-flight replies at run end keep this an inequality)
    assert len(d.accept_lens) + default["late_drops"] <= d.draft_tokens
    _check_accept_histogram(d, 1)


def test_spec_draft_backfill_matches_blocking(tiny_trained):
    """Backfill mode: the flush-time drain of older uploads keeps the
    cloud KV exact, so k-token drafting converges to the same blocking
    stream there too."""
    base = _baseline(tiny_trained, "dense")
    r = _draft_run(tiny_trained,
                   AsyncSimChannel(WIFI, service_s=0.004), k=4,
                   backfill=True)
    assert r["tokens"] == base["tokens"]
    _check_accept_histogram(r["stats"], 4)


# ---------------------------------------------------------------------------
# property: equality holds over arbitrary latency traces
# ---------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.sampled_from([1, 2, 4, 8]),
       layout=st.sampled_from(["dense", "paged"]),
       backfill=st.booleans())
def test_draft_equivalence_over_latency_traces(tiny_trained, seed, k,
                                               layout, backfill):
    """Whatever the reply-latency trace, as long as no deadline fires the
    reconcile converges every greedy stream to the blocking run — the
    draft lifecycle (flush timing, wave grouping, accept/rewind order)
    can shift arbitrarily without touching output space."""
    rng = np.random.default_rng(seed)
    lat = rng.uniform(0.0, 0.12, size=16).tolist()
    base = _baseline(tiny_trained, layout)
    r = _draft_run(tiny_trained, ScriptedChannel(lat, deadline_s=math.inf),
                   k=k, layout=layout, backfill=backfill)
    assert r["tokens"] == base["tokens"]
    _check_accept_histogram(r["stats"], k)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.sampled_from([1, 2, 4, 8]))
def test_draft_lifecycle_conservation_under_deadlines(tiny_trained, seed, k):
    """Finite deadlines: whole-draft misses, partial accepts, rewinds and
    fallback may all fire, but the lifecycle stays conservation-exact —
    streams complete, every token is accounted to exactly one serving
    event, and the accept histogram's marginals match the counters."""
    rng = np.random.default_rng(seed)
    lat = rng.uniform(0.0, 0.08, size=16).tolist()
    r = _draft_run(tiny_trained, ScriptedChannel(lat, deadline_s=0.03),
                   k=k, fallback_after=3)
    agg = r["stats"]
    assert all(len(t) == MAX_NEW for t in r["tokens"])
    _check_accept_histogram(agg, k)
    served = agg.exits_l1 + agg.exits_l2 + agg.cloud_requests
    # the admission token is uncounted when it exits at the prompt's last
    # position, counted as a cloud request when the prefill served it
    n = len(PROMPT_LENS)
    assert agg.tokens - n <= served <= agg.tokens
    # every validated draft token was billed as a cloud request, and only
    # resolved groups contribute accept-length entries
    assert agg.accepted_tokens <= agg.cloud_requests
    assert len(agg.accept_lens) <= agg.draft_tokens


# ---------------------------------------------------------------------------
# deadline miss commits the whole edge draft
# ---------------------------------------------------------------------------
def test_deadline_miss_commits_whole_draft(tiny_trained):
    """Replies far slower than the deadline: every dispatched draft
    misses, its k provisional tokens all become final l2 exits, and the
    late replies drop instead of reconciling."""
    r = _draft_run(tiny_trained, ScriptedChannel([0.5], deadline_s=0.02),
                   k=4)
    st_ = r["stats"]
    assert all(len(t) == MAX_NEW for t in r["tokens"])
    assert st_.deadline_misses > 0 and st_.draft_tokens > 0
    # no reply beat its deadline: nothing was verified, no accept-length
    # histogram entries, and one late drop per missed verification group
    assert st_.accepted_tokens == 0 and st_.accept_lens == []
    assert st_.cloud_requests <= len(PROMPT_LENS)   # admission prefills only
    assert r["late_drops"] == st_.deadline_misses
    # whole-draft commits: every draft token ended as an l2 exit
    assert st_.exits_l2 >= st_.draft_tokens
