"""Content manager, transport, workload, netsim invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.content_manager import ContentManager
from repro.core.netsim import (CaseTrace, ComputeParams, ModelSplit,
                               NetworkParams, TokenTrace, simulate)
from repro.core.transport import (StatePacket, dequantize, make_packet,
                                  open_packet, packet_bytes, quantize)
from repro.core.workload import (ALPACA, XSUM, paper_calibrated_cases,
                                 split_clients)


# ---------------------------------------------------------------------------
# content manager
# ---------------------------------------------------------------------------
def _pkt(pos=0):
    return StatePacket(hidden={"data": jnp.ones((1, 1, 8), jnp.float16)},
                       pos=jnp.asarray(pos))


def test_cm_upload_take_release():
    cm = ContentManager(max_pending_per_client=3)
    for p in range(5):
        cm.upload("dev0", p, _pkt(p))
    st = cm.stats()["dev0"]
    assert st["pending"] == 3 and st["uploads_released"] == 2
    pkt = cm.take_upload("dev0", 4)
    assert pkt is not None
    st = cm.stats()["dev0"]
    # taking pos 4 releases stale 2,3
    assert st["pending"] == 0
    with pytest.raises(KeyError):
        cm.take_upload("dev0", 4)


def test_cm_backfill_take_upto():
    cm = ContentManager(max_pending_per_client=8)
    for p in range(4):
        cm.upload("d", p, _pkt(p))
    got = cm.take_uploads_upto("d", 2)
    assert [p for p, _ in got] == [0, 1, 2]
    assert cm.stats()["d"]["pending"] == 1


def test_cm_eos_clears():
    cm = ContentManager()
    cm.upload("d", 0, _pkt())
    cm.put_cache("d", {"x": 1})
    cm.end_of_sequence("d")
    assert cm.get_cache("d") is None
    assert cm.stats()["d"]["pending"] == 0


def test_cm_multi_client_isolation():
    cm = ContentManager()
    cm.upload("a", 0, _pkt())
    cm.upload("b", 0, _pkt())
    cm.take_upload("a", 0)
    assert cm.has_upload("b", 0) and not cm.has_upload("a", 0)


# ---------------------------------------------------------------------------
# transport
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fmt,bytes_per", [("float32", 4), ("float16", 2),
                                           ("int8", 1)])
def test_transport_bytes(fmt, bytes_per):
    x = jnp.ones((4, 1, 64))
    pkt = make_packet(x, fmt)
    base = 4 * 64 * bytes_per
    assert pkt.nbytes() >= base
    if fmt != "int8":
        assert packet_bytes(pkt.hidden) == base


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.01, 1000.0), seed=st.integers(0, 999))
def test_transport_roundtrip_property(scale, seed):
    import jax
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 1, 32)) * scale
    # float formats: relative error bounds
    for fmt, tol in (("float32", 0.0), ("float16", 2e-3)):
        back = dequantize(quantize(x, fmt))
        rel = float(jnp.max(jnp.abs(back - x))) / (float(jnp.max(jnp.abs(x)))
                                                   + 1e-9)
        assert rel <= tol + 1e-7, (fmt, rel)
    # int8: exact per-row bound — half a quantization step
    pkt = quantize(x, "int8")
    back = dequantize(pkt)
    bound = jnp.broadcast_to(pkt["scale"] * 0.5 + 1e-7, x.shape)
    assert bool(jnp.all(jnp.abs(back - x) <= bound))


def test_state_packet_with_states():
    x = jnp.ones((1, 1, 16))
    states = {"S": jnp.ones((1, 4, 8, 8)), "m": jnp.zeros((1, 4))}
    pkt = make_packet(x, "float16", states=states)
    h, s = open_packet(pkt)
    np.testing.assert_allclose(np.asarray(h), np.asarray(x), atol=1e-3)
    assert s["S"].shape == (1, 4, 8, 8)


# ---------------------------------------------------------------------------
# netsim qualitative invariants (the paper's claims)
# ---------------------------------------------------------------------------
def _sim(strategy, n_clients=1, theta=0.8, **kw):
    comp = ComputeParams(edge_layer_time=1.28e-3, cloud_layer_time=1.28e-3,
                         exit_head_time=1e-3)
    net = NetworkParams(up_bw=3.8e6, rtt=0.003)
    split = ModelSplit(n_layers=32, l_ee1=8, l_ee2=16, d_model=4096,
                       backfill=kw.pop("backfill", False))
    cases = paper_calibrated_cases(ALPACA, 40, seed=3)
    # paper Fig 4 semantics: every client runs the full workload
    clients = [list(cases) for _ in range(n_clients)]
    return simulate(strategy, clients, net, comp, split, theta=theta, **kw)


def test_naive_dominated_by_comm():
    r = _sim("naive", half_precision=False)
    assert r.comm_time > 5 * r.cloud_time
    assert r.total_time > _sim("cloud_llm").total_time * 3


def test_collm_beats_cloud_at_low_theta():
    assert _sim("ce_collm", theta=0.8).total_time < _sim("cloud_llm").total_time * 1.05


def test_theta_monotonicity():
    t08 = _sim("ce_collm", theta=0.8)
    t09 = _sim("ce_collm", theta=0.9)
    t10 = _sim("ce_collm", theta=1.0)
    assert t08.cloud_time < t09.cloud_time < t10.cloud_time
    assert t08.request_cloud_rate < t09.request_cloud_rate <= 1.0


def test_ablation_orderings():
    base = _sim("ce_collm", theta=0.8)
    no_fp16 = _sim("ce_collm", theta=0.8, half_precision=False)
    no_ee = _sim("ce_collm", theta=0.8, early_exit=False)
    no_cm = _sim("ce_collm", theta=0.8, content_manager=False)
    assert no_fp16.total_time > base.total_time
    assert no_fp16.transmitted_mb > base.transmitted_mb * 1.5
    assert no_ee.cloud_time > base.cloud_time * 1.5
    assert no_cm.comm_time > base.comm_time * 5


def test_multi_client_scaling():
    """Fig 4: cloud-based grows ~linearly; collm grows slower."""
    c1 = _sim("cloud_llm", n_clients=1).total_time
    c5 = _sim("cloud_llm", n_clients=5).total_time
    m1 = _sim("ce_collm", n_clients=1, theta=0.8).total_time
    m5 = _sim("ce_collm", n_clients=5, theta=0.8).total_time
    assert c5 / c1 > 3.0              # near-linear cloud scaling
    assert m5 / m1 < c5 / c1          # collm scales better
    assert m5 < c5                    # and wins under load


def test_standalone_cheapest_edge_only():
    r = _sim("standalone")
    assert r.cloud_time == 0 and r.transmitted_mb == 0
    assert r.total_time < _sim("cloud_llm").total_time
