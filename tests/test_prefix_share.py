"""Radix prefix sharing + copy-on-write pages + chunked prefill.

Two hard invariants under test:

* **Pool accounting** — under random interleavings of admission (prefix
  hits + fresh allocations), decode writes (alloc-on-write / CoW splits),
  retirement and cache eviction, every physical page is either on the
  free list or referenced, refcounts equal mappings-plus-cache holds, and
  draining every slot and the trie returns the pool to fully free.
* **Output invisibility** — prefix sharing is a pure memoization: shared
  runs emit token streams identical to unshared chunked runs (which in
  turn match monolithic prefill), across float32/int8 pools, collm /
  standalone / batched-cloud modes, and under page pressure (preemption
  interleaved with cache eviction).

The engine-level suites run on an UNTRAINED tiny model (generation is
deterministic either way) so they stay in the fast CI lane.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.core.collm import CollmConfig
from repro.core.paging import OutOfPages, PagePool, pages_needed
from repro.models.registry import build_model
from repro.serving.cloud_batcher import COPY_PAGES
from repro.serving.engine import ServingSystem

PS = 4                                # pool-level tests: tiny pages
VOCAB = 6                             # tiny vocab -> frequent collisions


# ---------------------------------------------------------------------------
# pool-level property: random share/alloc/cow/free/evict schedules
# ---------------------------------------------------------------------------
def _check_accounting(pool: PagePool):
    """Every page is free xor referenced; refcounts == mappings + cache."""
    free = set(pool._free)
    assert len(free) == len(pool._free), "free list holds duplicates"
    assert 0 not in free and 0 not in pool._ref, "trash page entered play"
    mapcount = {}
    for slot in range(pool.num_slots):
        row = [int(p) for p in pool.block_table[slot] if p > 0]
        assert sorted(row) == sorted(pool._owned[slot]), \
            f"slot {slot}: block table and owned list disagree"
        for p in row:
            mapcount[p] = mapcount.get(p, 0) + 1
    for page in range(1, pool.num_pages + 1):
        ref = pool.refcount(page)
        expect = mapcount.get(page, 0) + (1 if page in pool._cached else 0)
        assert ref == expect, f"page {page}: ref {ref} != {expect}"
        assert (page in free) == (ref == 0), \
            f"page {page}: free-list/refcount disagree (ref={ref})"
    assert pool.reclaimable_pages == sum(
        1 for p in pool._cached if pool.refcount(p) == 1)


def _admit(pool: PagePool, rng: random.Random, slot: int, prompt):
    """Engine-shaped admission: map capped prefix hits, allocate the rest,
    insert the prompt into the trie, mark computed pages filled."""
    p_len = len(prompt)
    hit = pool.match_prefix(prompt)
    cap = max(0, (p_len - 1) // pool.page_size)
    shared = list(hit.pages[:cap])
    for lp, page in enumerate(shared):
        pool.share_page(slot, lp, page)
    for lp in range(len(shared), pages_needed(p_len, pool.page_size)):
        try:
            pool.alloc(slot, lp)
        except OutOfPages:
            freed = pool.evict_prefix(1)
            if not freed:
                pool.free_slot(slot)
                return None
            pool.alloc(slot, lp)
    pool.insert_prefix(slot, prompt)
    for lp in range(len(shared), p_len // pool.page_size):
        pool.mark_filled(int(pool.block_table[slot, lp]))
    pool.insert_terminal(slot, prompt, rng.randrange(VOCAB))
    return p_len


def _decode_write(pool: PagePool, slot: int, pos: int):
    """Engine-shaped decode write at ``pos``: alloc-on-write a fresh page
    or CoW-split a shared one."""
    lp = pos // pool.page_size
    if lp >= pool.max_logical:
        return False
    page = int(pool.block_table[slot, lp])
    if page == -1:
        try:
            pool.alloc(slot, lp)
        except OutOfPages:
            freed = pool.evict_prefix(1)
            if not freed:
                return False
            pool.alloc(slot, lp)
    elif pool.is_shared(page):
        try:
            src, dst = pool.cow_page(slot, lp)
        except OutOfPages:
            if not pool.evict_prefix(1):
                return False
            src, dst = pool.cow_page(slot, lp)
        assert src != dst and not pool.is_shared(dst)
        assert int(pool.block_table[slot, lp]) == dst
    return True


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 20))
def test_pool_schedule_invariants(seed):
    """Random op schedules keep accounting exact and drain to fully free."""
    rng = random.Random(seed)
    pool = PagePool(num_pages=rng.randint(6, 24), page_size=PS,
                    num_slots=rng.randint(2, 4), max_logical=8,
                    prefix_cache=True)
    state = {}                        # slot -> decode position
    for _ in range(60):
        op = rng.random()
        idle = [s for s in range(pool.num_slots) if s not in state]
        if op < 0.4 and idle:
            slot = rng.choice(idle)
            prompt = [rng.randrange(VOCAB)
                      for _ in range(rng.randint(1, 3 * PS + 2))]
            p_len = _admit(pool, rng, slot, prompt)
            if p_len is not None:
                state[slot] = p_len
        elif op < 0.75 and state:
            slot = rng.choice(list(state))
            if _decode_write(pool, slot, state[slot]):
                state[slot] += 1
        elif op < 0.9 and state:
            slot = rng.choice(list(state))
            pool.free_slot(slot)
            del state[slot]
        else:
            pool.evict_prefix(rng.randint(1, 3))
        _check_accounting(pool)
    for slot in list(state):
        pool.free_slot(slot)
    pool.evict_prefix(pool.num_pages)
    _check_accounting(pool)
    assert pool.free_pages == pool.num_pages, "pool failed to drain"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 20))
def test_match_prefix_returns_inserted_pages(seed):
    """A filled, terminated prompt matches itself exactly: full-page hits
    point at the inserter's own pages, the terminal memoizes the whole
    prompt and its first token; a diverging prompt hits only the common
    page-aligned span."""
    rng = random.Random(seed)
    pool = PagePool(num_pages=16, page_size=PS, num_slots=2, max_logical=8,
                    prefix_cache=True)
    p_len = rng.randint(1, 3 * PS + 3)
    prompt = [rng.randrange(VOCAB) for _ in range(p_len)]
    tok = rng.randrange(VOCAB)
    for lp in range(pages_needed(p_len, PS)):
        pool.alloc(0, lp)
    pool.insert_prefix(0, prompt)
    for lp in range(p_len // PS):
        pool.mark_filled(int(pool.block_table[0, lp]))
    pool.insert_terminal(0, prompt, tok)

    hit = pool.match_prefix(prompt)
    assert list(hit.pages) == \
        [int(pool.block_table[0, lp]) for lp in range(p_len // PS)]
    assert hit.terminal is not None and hit.terminal[1] == tok
    assert hit.hit_tokens == p_len

    other = list(prompt)
    other[-1] = (other[-1] + 1) % VOCAB      # diverge at the last token
    h2 = pool.match_prefix(other)
    assert h2.terminal is None
    common = ((p_len - 1) // PS) * PS        # full chunks before divergence
    assert h2.hit_tokens == common == len(h2.pages) * PS


def test_cow_split_bookkeeping():
    """CoW repoints exactly the writer: the source keeps its remaining
    references, the copy is private, and a second write needs no copy."""
    pool = PagePool(num_pages=8, page_size=PS, num_slots=2, max_logical=4,
                    prefix_cache=True)
    page = pool.alloc(0, 0)
    pool.share_page(1, 0, page)
    assert pool.is_shared(page) and pool.refcount(page) == 2
    src, dst = pool.cow_page(1, 0)
    assert (src, int(pool.block_table[1, 0])) == (page, dst)
    assert pool.refcount(src) == 1 and pool.refcount(dst) == 1
    assert int(pool.block_table[0, 0]) == src
    with pytest.raises(ValueError):
        pool.cow_page(1, 0)                  # already private
    _check_accounting(pool)


def test_copy_pages_duplicates_all_leaves():
    """The device half of CoW copies every leaf of a paged node — K/V and
    (for int8) the scale rows — without touching other pages."""
    pages, heads, dim = 4, 2, 3
    node = {"kp": jnp.arange(pages * PS * heads * dim, dtype=jnp.float32
                             ).reshape(pages, PS, heads, dim),
            "vp": -jnp.arange(pages * PS * heads * dim, dtype=jnp.float32
                              ).reshape(pages, PS, heads, dim),
            "scale": jnp.arange(pages * PS, dtype=jnp.float32
                                ).reshape(pages, PS),
            "pos": jnp.arange(pages * PS, dtype=jnp.int32
                              ).reshape(pages, PS)}
    out = COPY_PAGES({"0": node}, jnp.int32(1), jnp.int32(3))["0"]
    for name, leaf in node.items():
        np.testing.assert_array_equal(out[name][3], leaf[1],
                                      err_msg=f"{name}: dst != src")
        np.testing.assert_array_equal(out[name][:3], leaf[:3],
                                      err_msg=f"{name}: bystander changed")


# ---------------------------------------------------------------------------
# engine-level: sharing must be invisible in output space
# ---------------------------------------------------------------------------
EPS = 8                               # engine tests: page size


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny-ee", arch_type="dense", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=128, tie_embeddings=True,
                      exit_layers=(1, 2)).validate()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return {"model": model, "params": params, "systems": {}}


def _system(tiny, **ccfg_kw) -> ServingSystem:
    key = tuple(sorted(ccfg_kw.items()))
    if key not in tiny["systems"]:
        tiny["systems"][key] = ServingSystem(
            tiny["model"], tiny["params"],
            CollmConfig(theta=0.8, kv_layout="paged", page_size=EPS,
                        **ccfg_kw))
    return tiny["systems"][key]


def _shared_prompts(seed: int, n: int = 6):
    """n prompts behind a common 2.5-page system prefix + 2 duplicates."""
    rng = np.random.RandomState(seed)
    pre = rng.randint(0, 128, size=2 * EPS + 3)
    prompts = [np.concatenate([pre, rng.randint(0, 128, size=3 + i)]
                              ).astype(np.int32) for i in range(n)]
    return prompts + [prompts[0].copy(), prompts[1].copy()]

GKW = dict(num_slots=4, max_seq=64, max_ctx=64, num_pages=48)


@pytest.mark.parametrize("kv_dtype", ["float32", "int8"])
@pytest.mark.parametrize("mode", ["collm", "standalone"])
def test_shared_streams_token_identical(tiny, mode, kv_dtype):
    """Shared == unshared-chunked == monolithic token streams, with real
    prefix hits and at least one CoW split on the partial tail page."""
    prompts = _shared_prompts(0)
    mono = _system(tiny, kv_dtype=kv_dtype).generate(
        prompts, 10, mode=mode, **GKW)
    un = _system(tiny, kv_dtype=kv_dtype, chunked_prefill=True).generate(
        prompts, 10, mode=mode, **GKW)
    sh = _system(tiny, kv_dtype=kv_dtype, chunked_prefill=True,
                 prefix_share=True).generate(prompts, 10, mode=mode, **GKW)
    assert un["tokens"] == mono["tokens"], "chunked diverges from monolithic"
    assert sh["tokens"] == un["tokens"], "sharing changed the output"
    assert sh["stats"].prefix_hit_tokens > 0
    assert sh["stats"].cow_copies >= 1
    assert sh["stats"].prefill_chunks < un["stats"].prefill_chunks
    assert sh["pool_stats"]["allocs"] < un["pool_stats"]["allocs"]
    if mode == "collm":
        assert sh["stats"].upload_bytes < un["stats"].upload_bytes


def test_second_wave_is_all_terminal(tiny):
    """Re-sent prompts hit cached terminals: zero prefill compute, same
    streams (the memoized first token must match the computed one)."""
    prompts = _shared_prompts(1)
    sys_sh = _system(tiny, chunked_prefill=True, prefix_share=True)
    r1 = sys_sh.generate(prompts, 10, mode="collm", **GKW)
    r2 = sys_sh.generate(prompts[:3], 10, mode="collm", **GKW)
    assert r2["tokens"] == r1["tokens"][:3]
    assert r2["stats"].prefill_chunks == 0
    assert r2["stats"].prefix_hit_tokens == sum(
        len(p) for p in prompts[:3])


def test_batched_cloud_dedupes_uploads(tiny):
    """generate_multi: engine-side sharing and batcher-side upload dedupe
    agree (min-hit), streams identical to the unshared batched run."""
    prompts = _shared_prompts(2)
    r_un = _system(tiny, chunked_prefill=True).generate_multi(
        prompts, 10, n_engines=4, max_seq=64)
    r_sh = _system(tiny, chunked_prefill=True, prefix_share=True
                   ).generate_multi(prompts, 10, n_engines=4, max_seq=64)
    assert r_sh["tokens"] == r_un["tokens"]
    assert r_sh["stats"].prefix_hit_tokens > 0
    assert r_sh["batcher"]["prefix_hit_tokens"] > 0
    assert r_sh["stats"].prefill_chunks < r_un["stats"].prefill_chunks


def test_prefix_share_survives_page_pressure(tiny):
    """A pool too small for the load forces preemption AND prefix-cache
    eviction; streams stay identical to an unconstrained shared run."""
    prompts = _shared_prompts(3)
    ref = _system(tiny, chunked_prefill=True, prefix_share=True).generate(
        prompts, 12, mode="collm", **GKW)
    for pre in ("recompute", "swap"):
        sysp = _system(tiny, chunked_prefill=True, prefix_share=True,
                       preemption=pre)
        r = sysp.generate(prompts, 12, mode="collm", num_slots=4,
                          max_seq=64, max_ctx=64, num_pages=12)
        assert r["tokens"] == ref["tokens"], f"{pre}: tokens diverge"
        assert r["pool_stats"]["prefix_evictions"] >= 1


def test_config_validation(tiny):
    model, params = tiny["model"], tiny["params"]
    with pytest.raises(ValueError):                    # needs paged KV
        ServingSystem(model, params, CollmConfig(chunked_prefill=True))
    with pytest.raises(ValueError):                    # needs chunked
        ServingSystem(model, params,
                      CollmConfig(prefix_share=True, kv_layout="paged"))
    sys_sh = _system(tiny, chunked_prefill=True, prefix_share=True)
    with pytest.raises(ValueError):                    # edge-resident only
        sys_sh.generate(_shared_prompts(4)[:2], 4, mode="cloud", **GKW)
