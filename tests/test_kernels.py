"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attn.ops import flash_decode, flash_decode_paged
from repro.kernels.decode_attn.ref import (decode_attn_paged_ref,
                                           decode_attn_ref)
from repro.kernels.exit_head.ops import exit_confidence
from repro.kernels.exit_head.ref import exit_head_ref
from repro.kernels.exit_quant.ops import exit_quant
from repro.kernels.exit_quant.ref import exit_quant_ref
from repro.kernels.quantize.ops import quantize_int8
from repro.kernels.quantize.ref import dequantize_int8_ref, quantize_int8_ref


# ---------------------------------------------------------------------------
# exit_head
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,d,v,bb,bv", [
    (8, 64, 512, 8, 128), (16, 128, 1024, 4, 256), (8, 256, 2048, 8, 512),
    (4, 128, 640, 4, 128), (32, 64, 4096, 16, 1024),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_exit_head_sweep(b, d, v, bb, bv, dtype):
    rng = jax.random.PRNGKey(b * d % 7)
    h = jax.random.normal(rng, (b, d)).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (v, d)) * 0.05).astype(dtype)
    ns = jax.random.normal(jax.random.PRNGKey(2), (d,)) * 0.1
    c1, t1, l1 = exit_confidence(h, w, ns, block_b=bb, block_v=bv)
    c2, t2, l2 = exit_head_ref(h, w, ns)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=tol)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=tol, atol=tol)
    assert bool(jnp.all(t1 == t2))


def test_exit_head_confidence_bounds():
    # confidence is a probability
    rng = jax.random.PRNGKey(3)
    h = jax.random.normal(rng, (8, 64)) * 10
    w = jax.random.normal(jax.random.PRNGKey(4), (512, 64))
    c, t, l = exit_confidence(h, w, jnp.zeros(64))
    assert bool(jnp.all((c > 0) & (c <= 1.0 + 1e-6)))
    assert bool(jnp.all((t >= 0) & (t < 512)))


# ---------------------------------------------------------------------------
# decode_attn
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,d,s,bs,fill,window", [
    (2, 8, 2, 64, 1024, 256, 1000, 0),
    (1, 4, 4, 32, 512, 128, 512, 0),
    (2, 16, 2, 64, 2048, 512, 700, 256),
    (3, 6, 2, 128, 768, 256, 100, 0),
    (2, 8, 8, 64, 512, 512, 512, 64),
])
def test_decode_attn_sweep(b, h, kv, d, s, bs, fill, window):
    rng = jax.random.PRNGKey(fill % 11)
    q = jax.random.normal(rng, (b, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos = jnp.where(pos < fill, pos, -1)
    cur = jnp.asarray(fill - 1, jnp.int32)
    o1 = flash_decode(q, k, v, pos, cur, window=window, block_s=bs)
    o2 = decode_attn_ref(q, k, v, pos, cur, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_decode_attn_dtypes(dtype):
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 4, 64)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 2, 64)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 2, 64)).astype(dtype)
    pos = jnp.broadcast_to(jnp.arange(256)[None], (2, 256))
    o1 = flash_decode(q, k, v, pos, jnp.asarray(255), block_s=128)
    o2 = decode_attn_ref(q, k, v, pos, jnp.asarray(255))
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# decode_attn, paged layout
# ---------------------------------------------------------------------------
def _paged_fixture(b, kvh, d, num_pages, ps, n_lp, seed, *, gaps=False):
    """Random page pool with per-row fills; returns jnp arrays + cur (B,)."""
    rng = np.random.RandomState(seed)
    kp = rng.randn(num_pages, ps, kvh, d).astype(np.float32)
    vp = rng.randn(num_pages, ps, kvh, d).astype(np.float32)
    pos = np.full((num_pages, ps), -1, np.int32)
    tbl = np.full((b, n_lp), -1, np.int32)
    cur = np.zeros((b,), np.int32)
    free = list(range(1, num_pages))
    for bi in range(b):
        fill = rng.randint(2, n_lp * ps)
        cur[bi] = fill - 1
        for lp in range(-(-fill // ps)):
            pg = free.pop()
            tbl[bi, lp] = pg
            n = min(ps, fill - lp * ps)
            pos[pg, :n] = np.arange(lp * ps, lp * ps + n)
            if gaps:      # release-mode: some positions were never written
                drop = rng.rand(n) < 0.3
                pos[pg, :n][drop] = -1
    return tuple(map(jnp.asarray, (kp, vp, pos, tbl, cur)))


@pytest.mark.parametrize("b,h,kv,d,pages,ps,n_lp,window", [
    (2, 8, 2, 64, 33, 16, 8, 0),
    (3, 4, 4, 32, 17, 8, 4, 0),
    (2, 16, 2, 64, 65, 32, 8, 48),
    (1, 6, 2, 128, 9, 16, 8, 0),
])
def test_decode_attn_paged_sweep(b, h, kv, d, pages, ps, n_lp, window):
    q = jnp.asarray(np.random.RandomState(7).randn(b, h, d), jnp.float32)
    kp, vp, pos, tbl, cur = _paged_fixture(b, kv, d, pages, ps, n_lp,
                                           seed=pages)
    o1 = flash_decode_paged(q, kp, vp, pos, tbl, cur, window=window,
                            interpret=True)
    o2 = decode_attn_paged_ref(q, kp, vp, pos, tbl, cur, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_decode_attn_paged_matches_dense_gather():
    """A fully-allocated identity-mapped page pool must reproduce the ring
    oracle exactly (same valid set, same logical order)."""
    b, h, kv, d, ps, n_lp = 2, 8, 2, 64, 16, 4
    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(b, h, d), jnp.float32)
    s = n_lp * ps
    k = jnp.asarray(rng.randn(b, s, kv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, kv, d), jnp.float32)
    fill = 50
    pos = jnp.where(jnp.arange(s)[None] < fill,
                    jnp.arange(s)[None], -1) + jnp.zeros((b, 1), jnp.int32)
    cur = jnp.asarray(fill - 1, jnp.int32)
    # identity paging: row b owns pages [1 + b*n_lp, ...)
    tbl = (1 + jnp.arange(b * n_lp, dtype=jnp.int32)).reshape(b, n_lp)
    kp = jnp.concatenate([jnp.zeros((1, ps, kv, d))] + [
        k[bi].reshape(n_lp, ps, kv, d) for bi in range(b)])
    vp = jnp.concatenate([jnp.zeros((1, ps, kv, d))] + [
        v[bi].reshape(n_lp, ps, kv, d) for bi in range(b)])
    posp = jnp.concatenate([jnp.full((1, ps), -1, jnp.int32)] + [
        pos[bi].reshape(n_lp, ps) for bi in range(b)])
    o_ring = decode_attn_ref(q, k, v, pos, cur)
    o_paged = flash_decode_paged(q, kp, vp, posp, tbl,
                                 jnp.broadcast_to(cur, (b,)), interpret=True)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_ring),
                               atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), gaps=st.booleans())
def test_decode_attn_paged_property(seed, gaps):
    """Property: kernel == oracle for random allocations, including
    release-mode gaps (pos = -1 holes inside allocated pages)."""
    b, h, kv, d, pages, ps, n_lp = 2, 4, 2, 32, 17, 8, 6
    q = jnp.asarray(np.random.RandomState(seed).randn(b, h, d), jnp.float32)
    kp, vp, pos, tbl, cur = _paged_fixture(b, kv, d, pages, ps, n_lp,
                                           seed=seed, gaps=gaps)
    o1 = flash_decode_paged(q, kp, vp, pos, tbl, cur, interpret=True)
    o2 = decode_attn_paged_ref(q, kp, vp, pos, tbl, cur)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attn, paged layout, int8 pages (in-kernel dequant)
# ---------------------------------------------------------------------------
def _quantize_pool(kp, vp):
    """Per-(slot, kv_head)-row int8 quantization of a page pool — the same
    scaling the engine applies on page write."""
    from repro.models.attention import quantize_kv_rows
    qk, sk = quantize_kv_rows(kp)
    qv, sv = quantize_kv_rows(vp)
    return qk, qv, sk, sv


@pytest.mark.parametrize("b,h,kv,d,pages,ps,n_lp,window", [
    (2, 8, 2, 64, 33, 16, 8, 0),
    (3, 4, 4, 32, 17, 8, 4, 0),
    (2, 16, 2, 64, 65, 32, 8, 48),
])
def test_decode_attn_paged_int8_sweep(b, h, kv, d, pages, ps, n_lp, window):
    """int8 pages + in-kernel dequant == the gather-dequant oracle."""
    q = jnp.asarray(np.random.RandomState(11).randn(b, h, d), jnp.float32)
    kp, vp, pos, tbl, cur = _paged_fixture(b, kv, d, pages, ps, n_lp,
                                           seed=pages + 1)
    qk, qv, sk, sv = _quantize_pool(kp, vp)
    o1 = flash_decode_paged(q, qk, qv, pos, tbl, cur, k_scale=sk, v_scale=sv,
                            window=window, interpret=True)
    o2 = decode_attn_paged_ref(q, qk, qv, pos, tbl, cur, k_scale=sk,
                               v_scale=sv, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_decode_attn_paged_int8_close_to_f32():
    """Dequantized int8 attention stays near the float32 result — the
    per-row absmax quantizer bounds the K/V perturbation, so the softmax
    output moves by O(1/127), not O(1)."""
    b, h, kv, d, pages, ps, n_lp = 2, 8, 2, 64, 33, 16, 8
    q = jnp.asarray(np.random.RandomState(13).randn(b, h, d), jnp.float32)
    kp, vp, pos, tbl, cur = _paged_fixture(b, kv, d, pages, ps, n_lp, seed=5)
    qk, qv, sk, sv = _quantize_pool(kp, vp)
    o_f32 = flash_decode_paged(q, kp, vp, pos, tbl, cur, interpret=True)
    o_i8 = flash_decode_paged(q, qk, qv, pos, tbl, cur, k_scale=sk,
                              v_scale=sv, interpret=True)
    np.testing.assert_allclose(np.asarray(o_i8), np.asarray(o_f32),
                               atol=0.15)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), gaps=st.booleans())
def test_decode_attn_paged_int8_property(seed, gaps):
    """Property: int8 kernel == oracle for random allocations + gaps."""
    b, h, kv, d, pages, ps, n_lp = 2, 4, 2, 32, 17, 8, 6
    q = jnp.asarray(np.random.RandomState(seed).randn(b, h, d), jnp.float32)
    kp, vp, pos, tbl, cur = _paged_fixture(b, kv, d, pages, ps, n_lp,
                                           seed=seed, gaps=gaps)
    qk, qv, sk, sv = _quantize_pool(kp, vp)
    o1 = flash_decode_paged(q, qk, qv, pos, tbl, cur, k_scale=sk, v_scale=sv,
                            interpret=True)
    o2 = decode_attn_paged_ref(q, qk, qv, pos, tbl, cur, k_scale=sk,
                               v_scale=sv)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,bn", [(256, 128, 64), (128, 512, 128),
                                    (512, 64, 256)])
def test_quantize_sweep(n, d, bn):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d)) * 5
    qa, sa = quantize_int8(x, block_n=bn)
    qb, sb = quantize_int8_ref(x)
    assert bool(jnp.all(qa == qb))
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from([8, 32, 64]), d=st.sampled_from([16, 64, 128]),
       scale=st.floats(0.01, 100.0), seed=st.integers(0, 2 ** 16))
def test_quantize_roundtrip_property(n, d, scale, seed):
    """Property: int8 roundtrip error bounded by scale/127 per element."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * scale
    q, s = quantize_int8(x)
    back = dequantize_int8_ref(q, s)
    bound = np.asarray(s) * 0.5 + 1e-9
    assert np.all(np.abs(np.asarray(back - x)) <= bound + 1e-6)


@settings(max_examples=20, deadline=None)
@given(b=st.sampled_from([4, 8]), v=st.sampled_from([256, 512]),
       seed=st.integers(0, 2 ** 16))
def test_exit_head_property(b, v, seed):
    """Property: kernel and oracle agree on confidence/argmax for random
    inputs; confidence equals softmax max prob."""
    d = 64
    h = jax.random.normal(jax.random.PRNGKey(seed), (b, d))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (v, d)) * 0.1
    c1, t1, _ = exit_confidence(h, w, jnp.zeros(d), block_b=b, block_v=v // 2)
    c2, t2, _ = exit_head_ref(h, w, jnp.zeros(d))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)
    assert bool(jnp.all(t1 == t2))


# ---------------------------------------------------------------------------
# exit_quant (fused exit head + wire quantize)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,d,v,bb,bv", [
    (8, 64, 512, 8, 256),
    (16, 128, 1024, 8, 512),
    (4, 32, 256, 4, 128),
])
def test_exit_quant_sweep(b, d, v, bb, bv):
    h = jax.random.normal(jax.random.PRNGKey(b + v), (b, d)) * 3
    w = jax.random.normal(jax.random.PRNGKey(1), (v, d)) * 0.05
    ns = jax.random.normal(jax.random.PRNGKey(2), (d,)) * 0.1
    ker = exit_quant(h, w, ns, block_b=bb, block_v=bv, interpret=True)
    ref = exit_quant_ref(h, w, ns)
    np.testing.assert_allclose(np.asarray(ker[0]), np.asarray(ref[0]),
                               atol=1e-5)                       # confidence
    assert bool(jnp.all(ker[1] == ref[1]))                      # token
    np.testing.assert_allclose(np.asarray(ker[2]), np.asarray(ref[2]),
                               atol=1e-4)                       # logsumexp
    assert bool(jnp.all(ker[3] == ref[3]))                      # int8 data
    np.testing.assert_allclose(np.asarray(ker[4]), np.asarray(ref[4]),
                               rtol=1e-6)                       # scale


def test_exit_quant_ref_is_two_launch_composition():
    """The fused oracle == exit_head_ref + quantize_int8_ref verbatim (it
    must quantize the RAW pre-norm hidden, not the exit head's normalized
    view)."""
    b, d, v = 8, 64, 512
    h = jax.random.normal(jax.random.PRNGKey(9), (b, d)) * 2
    w = jax.random.normal(jax.random.PRNGKey(10), (v, d)) * 0.05
    ns = jnp.zeros((d,))
    conf, tok, lse, q, s = exit_quant_ref(h, w, ns)
    c2, t2, l2 = exit_head_ref(h, w, ns)
    q2, s2 = quantize_int8_ref(h)
    assert bool(jnp.all(tok == t2)) and bool(jnp.all(q == q2))
    np.testing.assert_allclose(np.asarray(conf), np.asarray(c2), rtol=1e-7)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-7)


def test_exit_quant_fallback_on_indivisible_shapes():
    """Shapes the tiling can't cover fall back to the oracle, same outputs."""
    b, d, v = 5, 48, 300                    # 5 % 4 != 0, 300 % 128 != 0
    h = jax.random.normal(jax.random.PRNGKey(3), (b, d))
    w = jax.random.normal(jax.random.PRNGKey(4), (v, d)) * 0.05
    ns = jnp.zeros((d,))
    out = exit_quant(h, w, ns, block_b=4, block_v=128, interpret=True)
    ref = exit_quant_ref(h, w, ns)
    for a, r in zip(out, ref):
        assert a.shape == r.shape and a.dtype == r.dtype
        assert bool(jnp.all(a == r))


@settings(max_examples=15, deadline=None)
@given(b=st.sampled_from([4, 8]), v=st.sampled_from([256, 512]),
       seed=st.integers(0, 2 ** 16))
def test_exit_quant_property(b, v, seed):
    """Property: fused kernel agrees with BOTH unfused kernels on random
    inputs — exit decision with exit_head, packet with quantize."""
    d = 64
    h = jax.random.normal(jax.random.PRNGKey(seed), (b, d)) * 4
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (v, d)) * 0.1
    conf, tok, _, q, s = exit_quant(h, w, jnp.zeros(d), block_b=b,
                                    block_v=v // 2, interpret=True)
    c2, t2, _ = exit_head_ref(h, w, jnp.zeros(d))
    q2, s2 = quantize_int8_ref(h)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(c2), atol=1e-5)
    assert bool(jnp.all(tok == t2)) and bool(jnp.all(q == q2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)


def test_fused_exit_upload_matches_edge_step_decision():
    """CoLLM.fused_exit_upload == evaluate_exit(exit_logits) + the
    transport int8 quantizer, packet layout included."""
    from repro.configs.base import ModelConfig
    from repro.core.collm import CoLLM, CollmConfig
    from repro.core.exits import evaluate_exit
    from repro.core.transport import dequantize, quantize
    from repro.models.registry import build_model

    cfg = ModelConfig(name="tiny-ee", arch_type="dense", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=128, tie_embeddings=True,
                      exit_layers=(1, 2)).validate()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    collm = CoLLM(model, CollmConfig(theta=0.8))
    hid = jax.random.normal(jax.random.PRNGKey(5), (3, 1, cfg.d_model))
    for use_kernel in (False, True):
        conf, tok, pkt = collm.fused_exit_upload(params, hid,
                                                 use_kernel=use_kernel,
                                                 interpret=True)
        dec = evaluate_exit(model.exit_logits(params, collm.l_ee1, hid))
        ref_pkt = quantize(hid, "int8")
        np.testing.assert_allclose(np.asarray(conf),
                                   np.asarray(dec.confidence.reshape(-1)),
                                   atol=1e-5)
        assert bool(jnp.all(tok == dec.token.reshape(-1)))
        assert pkt["data"].shape == ref_pkt["data"].shape
        assert pkt["scale"].shape == ref_pkt["scale"].shape
        assert bool(jnp.all(pkt["data"] == ref_pkt["data"]))
        np.testing.assert_allclose(np.asarray(pkt["scale"]),
                                   np.asarray(ref_pkt["scale"]), rtol=1e-6)
        # the packet opens through the standard transport dequantizer
        back = dequantize(pkt)
        assert back.shape == hid.shape
