"""CE-CoLLM system invariants (the paper's correctness claims).

Key invariant (Table 2 θ=1.0 rows): with the threshold never met, fused
co-inference reproduces the undivided model EXACTLY (fp32 wire)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.collm import CoLLM, CollmConfig
from repro.core.exits import evaluate_exit, first_confident_exit


def _greedy_full(co, model, params, prompt, steps):
    caches = model.init_cache(prompt.shape[0], 64)
    x, _, caches, _ = model.prefill(params, {"tokens": prompt}, caches)
    tok = jnp.argmax(model.logits(params, x[:, -1:])[:, 0], -1).astype(jnp.int32)
    toks = [tok]
    s = prompt.shape[1]
    for t in range(steps):
        tok, _, caches = co.full_step(params, tok[:, None], caches,
                                      jnp.asarray(s + t, jnp.int32))
        toks.append(tok)
    return jnp.stack(toks, 1)


def _fused_decode(co, model, params, prompt, steps):
    st = co.init_fused_state(prompt.shape[0], 64)
    _, h1, st["edge"] = co.edge_prefill(params, {"tokens": prompt},
                                        st["edge"])
    logits, st["cloud"] = co.cloud_prefill(params, h1, st["cloud"])
    tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
    toks = [tok]
    infos = []
    s = prompt.shape[1]
    for t in range(steps):
        tok, info, st = co.fused_step(params, tok[:, None], st,
                                      jnp.asarray(s + t, jnp.int32))
        toks.append(tok)
        infos.append(info)
    return jnp.stack(toks, 1), infos


@pytest.mark.parametrize("backfill", [False, True])
def test_theta1_exact_equivalence(tiny_trained, backfill):
    model, params = tiny_trained["model"], tiny_trained["params"]
    prompt = jnp.asarray(tiny_trained["data"].prompts(2, 10))
    co = CoLLM(model, CollmConfig(theta=1.1, wire_format="float32",
                                  backfill=backfill))
    base = _greedy_full(co, model, params, prompt, 12)
    got, infos = _fused_decode(co, model, params, prompt, 12)
    assert bool(jnp.all(got == base))
    assert all(bool(i["need_cloud"]) for i in infos)


def test_fp16_wire_close(tiny_trained):
    model, params = tiny_trained["model"], tiny_trained["params"]
    prompt = jnp.asarray(tiny_trained["data"].prompts(2, 10))
    co32 = CoLLM(model, CollmConfig(theta=1.1, wire_format="float32"))
    co16 = CoLLM(model, CollmConfig(theta=1.1, wire_format="float16"))
    a, _ = _fused_decode(co32, model, params, prompt, 12)
    b, _ = _fused_decode(co16, model, params, prompt, 12)
    # paper Table 3: fp16 transport does not change predictions
    assert float((a == b).mean()) > 0.9


def test_adaptive_exits_reduce_cloud(tiny_trained):
    """Cloud compute is gated PER ROW: an exited row is never served by the
    cloud that step (release-mode KV gaps stay per-row, matching the
    sequential ContentManager semantics)."""
    model, params = tiny_trained["model"], tiny_trained["params"]
    prompt = jnp.asarray(tiny_trained["data"].prompts(2, 10))
    co = CoLLM(model, CollmConfig(theta=0.5))
    toks, infos = _fused_decode(co, model, params, prompt, 16)
    row_steps = 2 * len(infos)
    n_cloud_rows = sum(int(i["need_rows"].sum()) for i in infos)
    n_exits = sum(int(i["exited"].sum()) for i in infos)
    assert n_exits > 0, "trained tiny model should exit sometimes at θ=0.5"
    assert n_cloud_rows < row_steps
    # release mode: a row needs cloud exactly when it did not exit
    assert n_cloud_rows + n_exits == row_steps
    assert bool(jnp.all(toks >= 0))


def test_standalone_is_last_exit_greedy(tiny_trained):
    model, params = tiny_trained["model"], tiny_trained["params"]
    prompt = jnp.asarray(tiny_trained["data"].prompts(1, 10))
    co = CoLLM(model, CollmConfig(theta=0.8))
    caches = co.init_edge_cache(1, 64)
    _, _, caches = co.edge_prefill(params, {"tokens": prompt}, caches)
    tok, d, caches = co.standalone_step(params, prompt[:, -1:], caches,
                                        jnp.asarray(9, jnp.int32))
    assert tok.shape == (1,)
    assert bool(jnp.all(d.confidence > 0))


def test_exit_selection_logic():
    d1 = evaluate_exit(jnp.asarray([[0.0, 5.0, 0.0], [1.0, 1.0, 1.0]]))
    d2 = evaluate_exit(jnp.asarray([[9.0, 0.0, 0.0], [9.0, 0.0, 0.0]]))
    tok, exited, idx = first_confident_exit({1: d1, 2: d2}, theta=0.9)
    # row 0: exit 1 confident (softmax ~0.986) -> token 1 at exit 0
    assert int(tok[0]) == 1 and bool(exited[0]) and int(idx[0]) == 0
    # row 1: exit1 uniform (conf 1/3) -> falls to exit 2 (conf ~0.9998)
    assert int(tok[1]) == 0 and bool(exited[1]) and int(idx[1]) == 1
    tok2, exited2, idx2 = first_confident_exit({1: d1, 2: d2}, theta=1.01)
    assert not bool(exited2.any()) and bool(jnp.all(idx2 == 2))


def test_edge_cloud_partition_covers_model(tiny_trained):
    model = tiny_trained["model"]
    co = CoLLM(model, CollmConfig())
    edge_layers = set()
    for si in co.edge_segs:
        s = model.segments[si]
        edge_layers.update(range(s.start, s.end))
    cloud_layers = set()
    for si in co.cloud_segs:
        s = model.segments[si]
        cloud_layers.update(range(s.start, s.end))
    n = model.cfg.n_layers
    assert edge_layers == set(range(co.l_ee2))
    assert cloud_layers == set(range(co.l_ee1, n))
    # overlap region (paper: "remaining LLM with some overlap")
    assert edge_layers & cloud_layers == set(range(co.l_ee1, co.l_ee2))
