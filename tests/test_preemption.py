"""Optimistic paged-KV admission with preemption & swap.

The hard invariant under test: preemption is **invisible in output
space** — whatever oversubscription level, victim policy, or forced
preemption schedule the scheduler runs under, every stream's greedy token
stream is identical to an un-preempted run, and the ``PagePool`` ends
with every page back on the free list.

The engine-level suites run on an UNTRAINED tiny model (generation is
deterministic either way) so they stay in the fast CI lane; one
trained-model equivalence test is marked ``slow``.
"""
import math
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.core.collm import CollmConfig
from repro.core.paging import (PREEMPT_POLICIES, TRASH_PAGE, OutOfPages,
                               PagePool, SwapPool, VictimCandidate,
                               pages_needed, select_victim)
from repro.core.transport import ScriptedChannel
from repro.models.registry import build_model
from repro.serving.engine import ServingSystem

PS = 16                               # CollmConfig.page_size default


# ---------------------------------------------------------------------------
# shared untrained tiny model + memoized systems (one CoLLM per config so
# hypothesis examples never re-trace the jitted steps)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny-ee", arch_type="dense", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=128, tie_embeddings=True,
                      exit_layers=(1, 2)).validate()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return {"model": model, "params": params, "systems": {}}


def _system(tiny, **ccfg_kw) -> ServingSystem:
    key = tuple(sorted(ccfg_kw.items()))
    if key not in tiny["systems"]:
        tiny["systems"][key] = ServingSystem(
            tiny["model"], tiny["params"], CollmConfig(**ccfg_kw))
    return tiny["systems"][key]


def _prompts(seed: int, n: int, lo: int = 6, hi: int = 14):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, 128, size=rng.randint(lo, hi + 1))
            for _ in range(n)]


# ---------------------------------------------------------------------------
# the tentpole property: oversubscription x policy x forced schedules
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 20),
       policy=st.sampled_from(PREEMPT_POLICIES),
       pre=st.sampled_from(("recompute", "swap")),
       mode=st.sampled_from(("collm", "standalone")))
def test_preempted_streams_token_identical(seed, policy, pre, mode, tiny):
    """Random oversubscription levels x random preemption policies x
    random forced-preemption schedules -> token streams identical to the
    un-preempted sync run, and the pool drains back to fully free."""
    rng = random.Random(seed)
    n_streams = rng.randint(3, 5)
    num_slots = 2
    max_new = rng.randint(6, 14)
    prompts = _prompts(seed, n_streams)
    worst = max(pages_needed(len(p) + max_new, PS) for p in prompts)
    # pool between "one worst-case stream" (max oversubscription, natural
    # preemption every few pages) and "every slot worst-case" (only the
    # forced schedule preempts); drawn from a small set so the paged cache
    # shapes — and the compiled graphs — are shared across examples
    num_pages = rng.choice([worst, worst + 1, 2 * worst])
    schedule = [(rng.randint(1, 3 * max_new), rng.randrange(num_slots))
                for _ in range(rng.randint(0, 4))]

    ref = _system(tiny, theta=0.8, kv_layout="paged")
    r_ref = ref.generate(prompts, max_new, mode=mode, num_slots=num_slots,
                         max_seq=40)

    sysp = _system(tiny, theta=0.8, kv_layout="paged", preemption=pre,
                   preempt_policy=policy)
    r = sysp.generate(prompts, max_new, mode=mode, num_slots=num_slots,
                      max_seq=40, num_pages=num_pages,
                      preempt_schedule=schedule)
    assert r["tokens"] == r_ref["tokens"]
    for sched in sysp._schedulers.values():
        if sched.pool is not None:
            assert sched.pool.free_pages == sched.pool.num_pages
            assert not sched._preempted
    st_ = r["stats"]
    assert st_.tokens == r_ref["stats"].tokens


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 20))
def test_forced_preemption_dense_layout(seed, tiny):
    """Recompute-mode preemption is layout-agnostic: forced schedules on
    the dense engine re-prefill into the slot ring and stay invisible."""
    rng = random.Random(seed)
    max_new = rng.randint(6, 12)
    prompts = _prompts(seed, 4)
    schedule = [(rng.randint(1, 2 * max_new), rng.randrange(2))
                for _ in range(rng.randint(1, 4))]
    ref = _system(tiny, theta=0.8)
    r_ref = ref.generate(prompts, max_new, mode="collm", num_slots=2,
                         max_seq=40)
    sysp = _system(tiny, theta=0.8, preemption="recompute")
    r = sysp.generate(prompts, max_new, mode="collm", num_slots=2,
                      max_seq=40, preempt_schedule=schedule)
    assert r["tokens"] == r_ref["tokens"]


@pytest.mark.parametrize("kw,mode,pre", [
    (dict(theta=0.8), "collm", "recompute"),
    (dict(theta=0.8), "collm", "swap"),
    (dict(theta=0.8, backfill=True), "collm", "recompute"),
    (dict(theta=1.0), "collm", "swap"),   # every token cloud-served
    (dict(theta=0.8), "standalone", "recompute"),
    (dict(theta=0.8), "cloud", "swap"),   # undivided-model baseline rows
])
def test_natural_preemption_all_modes(tiny, kw, mode, pre):
    """A pool at ~half the worst-case demand forces real (not scheduled)
    preemptions in every serving mode; streams stay token-identical and
    the pool drains."""
    prompts = _prompts(7, 3, lo=8, hi=12)
    max_new = 12
    base = _system(tiny, kv_layout="paged", **kw)
    rb = base.generate(prompts, max_new, mode=mode, num_slots=2, max_seq=40)
    sysp = _system(tiny, kv_layout="paged", preemption=pre, **kw)
    r = sysp.generate(prompts, max_new, mode=mode, num_slots=2, max_seq=40,
                      num_pages=3)
    assert r["tokens"] == rb["tokens"]
    sched = next(iter(sysp._schedulers.values()))
    assert sched.preemptions > 0
    assert r["stats"].preemptions == sched.preemptions
    assert sched.pool.free_pages == sched.pool.num_pages
    if pre == "swap":
        assert sched.swap.stats.swapped_out == sched.preemptions
        assert len(sched.swap) == 0       # every snapshot swapped back in


def test_speculative_preemption(tiny):
    """Forced preemption composes with speculative decode: provisional
    tokens past the earliest unvalidated position are rewound into the
    checkpoint and re-speculated identically after resume."""
    prompts = _prompts(11, 3, lo=8, hi=12)
    ref = _system(tiny, theta=0.8, speculative=True)
    r_ref = ref.generate(prompts, 10, mode="collm", num_slots=2, max_seq=40)
    sysp = _system(tiny, theta=0.8, speculative=True,
                   preemption="recompute")
    r = sysp.generate(prompts, 10, mode="collm", num_slots=2, max_seq=40,
                      preempt_schedule=[(3, 0), (6, 1)])
    assert r["tokens"] == r_ref["tokens"]


def test_watermark_holds_back_admission(tiny):
    """With a watermark, admission leaves headroom pages untouched, but
    the streams still finish token-identically."""
    prompts = _prompts(5, 4, lo=8, hi=12)
    base = _system(tiny, theta=0.8, kv_layout="paged")
    rb = base.generate(prompts, 10, mode="collm", num_slots=2, max_seq=40)
    sysp = _system(tiny, theta=0.8, kv_layout="paged",
                   preemption="recompute")
    r = sysp.generate(prompts, 10, mode="collm", num_slots=2, max_seq=40,
                      num_pages=4, watermark=1)
    assert r["tokens"] == rb["tokens"]


def test_preemption_config_validation(tiny):
    with pytest.raises(ValueError, match="paged"):
        _system(tiny, theta=0.8, preemption="swap").generate(
            _prompts(0, 1), 4, mode="collm")
    with pytest.raises(ValueError, match="greedy"):
        _system(tiny, theta=0.8, kv_layout="paged",
                preemption="recompute").generate(
            _prompts(0, 1), 4, mode="collm", sampler="topk", top_k=4)
    with pytest.raises(ValueError, match="preempt_policy"):
        _system(tiny, theta=0.8, kv_layout="paged", preemption="recompute",
                preempt_policy="nope").generate(
            _prompts(0, 1), 4, mode="collm")
    with pytest.raises(ValueError, match="preemption enabled"):
        _system(tiny, theta=0.8, kv_layout="paged").generate(
            _prompts(0, 1), 4, mode="collm", preempt_schedule=[(1, 0)])


# ---------------------------------------------------------------------------
# preemption x cloud batcher (multi-engine, in-flight requests)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pre,backfill", [
    ("recompute", False), ("swap", False),
    # backfill x swap is the lazy-flush corner: a queued-but-uncomputed
    # backfill entry holds the only copy of ring positions re-decode
    # never re-uploads — CloudBatcher.swap_out must flush before its
    # page snapshot or the resumed stream reads a gap
    ("recompute", True), ("swap", True),
])
def test_preempted_inflight_cloud_request(tiny, pre, backfill):
    """A stream preempted with a cloud reply in flight: the late reply is
    dropped by the slot-generation guard, the CloudBatcher row is
    released, and the stream re-registers on resume — with no leaked
    pooled cloud rows and token streams equal to independent sync runs."""
    prompts = _prompts(3, 3, lo=8, hi=12)
    max_new = 12
    refsys = _system(tiny, theta=0.8, backfill=backfill)
    ref = [refsys.generate([p], max_new, mode="collm", num_slots=1)
           ["tokens"][0] for p in prompts]

    sysm = _system(tiny, theta=0.8, kv_layout="paged", preemption=pre,
                   backfill=backfill)
    chans = [ScriptedChannel([0.05], deadline_s=math.inf) for _ in range(3)]
    r = sysm.generate_multi(prompts, max_new, cloud_batch=True,
                            channels=chans, tick_time_s=0.01,
                            preempt_schedules=[[(4, 0)], None, [(6, 0)]])
    assert r["tokens"] == ref
    # the preempted engines' in-flight replies were dropped, not applied
    assert r["late_drops"] >= 1
    # every cloud row back in the pool (release on preempt AND on finish)
    assert sysm.cloud.cm.cloud_slots_free() == 3
    b = r["batcher"]
    if pre == "swap":
        assert b["swaps"] >= 1
    else:
        assert b["restores"] >= 1


def test_swap_out_flushes_queued_backfill_entries(tiny):
    """Lazy-flush corner: a queued-but-uncomputed backfill entry has
    consumed uploads (ring positions re-decode will never re-upload)
    without writing their KV.  ``CloudBatcher.swap_out`` must flush
    before snapshotting, or the resumed stream reads a gap where the
    un-preempted run had KV."""
    from repro.core.collm import CoLLM
    from repro.core.content_manager import ContentManager
    from repro.core.transport import StatePacket, quantize
    from repro.serving.cloud_batcher import CloudBatcher

    model, params = tiny["model"], tiny["params"]
    collm = CoLLM(model, CollmConfig(theta=0.8, kv_layout="paged",
                                     backfill=True, preemption="swap"))
    cm = ContentManager()
    batcher = CloudBatcher(collm, params, cm, num_slots=2, max_seq=40)
    prompt = jnp.asarray(_prompts(1, 1, lo=8, hi=8)[0][None, :])
    p_len = prompt.shape[1]
    _, h1_seq, _ = collm.edge_prefill(params, {"tokens": prompt},
                                      collm.init_edge_cache(1, p_len))
    batcher.admit("edge-0", h1_seq, p_len, p_len + 8)

    rng = np.random.RandomState(0)
    d = model.cfg.d_model
    for p in (p_len, p_len + 1):       # two early-exited positions pending
        cm.upload("edge-0", p, StatePacket(
            hidden=quantize(jnp.asarray(rng.randn(1, 1, d), jnp.float32),
                            "float16")))
    _, _, consumed = batcher.submit("edge-0", p_len + 1, backfill=True)
    assert len(consumed) == 2 and batcher._pending    # queued, unflushed

    snap = batcher.swap_out("edge-0")
    assert not batcher._pending                       # flushed, not dropped
    assert batcher.stats.steps >= 1
    markers = set()

    def collect(node):
        if isinstance(node, dict):
            if "kp" in node:
                markers.update(np.asarray(node["pos"]).ravel().tolist())
            else:
                for v in node.values():
                    collect(v)

    collect(snap["pages"])
    # the snapshot must carry the ring positions' KV markers
    assert {p_len, p_len + 1} <= markers

    batcher.swap_in("edge-0", snap)
    slot = cm.cloud_slot("edge-0")
    tbl = batcher.pool.block_table[slot]
    assert (tbl >= 0).sum() == len(snap["logical"])   # pages re-bound


def test_preempted_batcher_rows_not_leaked_across_runs(tiny):
    """Two back-to-back preempting multi-runs on one system: the second
    run re-acquires rows/pages cleanly (nothing leaked by run 1)."""
    prompts = _prompts(9, 3, lo=8, hi=12)
    sysm = _system(tiny, theta=0.8, kv_layout="paged",
                   preemption="recompute")
    outs = []
    for _ in range(2):
        chans = [ScriptedChannel([0.03], deadline_s=math.inf)
                 for _ in range(3)]
        r = sysm.generate_multi(prompts, 10, cloud_batch=True,
                                channels=chans, tick_time_s=0.01,
                                preempt_schedules=[[(3, 0)], [(5, 0)], None])
        outs.append(r["tokens"])
        assert sysm.cloud.cm.cloud_slots_free() == 3
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# PagePool allocator properties
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_pagepool_random_ops_invariants(seed):
    """Random alloc/free/preempt sequences: no physical page is ever
    double-allocated, ``free + in_use == num_pages`` holds after every
    op, and the trash page is never handed out."""
    rng = random.Random(seed)
    num_pages = rng.randint(2, 12)
    ps = rng.choice([4, 8, 16])
    num_slots = rng.randint(1, 4)
    max_logical = rng.randint(2, 8)
    pool = PagePool(num_pages, ps, num_slots, max_logical,
                    watermark=rng.randint(0, num_pages - 1))
    owned = {s: set() for s in range(num_slots)}
    for _ in range(rng.randint(10, 60)):
        op = rng.random()
        slot = rng.randrange(num_slots)
        if op < 0.6:
            lp = rng.randrange(max_logical)
            before = pool.block_table[slot, lp]
            try:
                page = pool.alloc(slot, lp)
            except OutOfPages:
                assert pool.free_pages == 0
                continue
            assert page != TRASH_PAGE
            if before == -1:
                assert all(page not in o for o in owned.values())
                owned[slot].add(page)
            else:
                assert page == before          # idempotent re-map
        else:
            freed = pool.free_slot(slot)
            assert set(freed) == owned[slot]
            owned[slot] = set()
        # conservation + table/ledger agreement after every op
        in_use = sum(len(o) for o in owned.values())
        assert pool.free_pages + in_use == pool.num_pages
        assert pool.pages_in_use() == in_use
        for s in range(num_slots):
            tbl = pool.block_table[s]
            assert set(tbl[tbl >= 0].tolist()) == owned[s]
            assert pool.owned_pages(s) == len(owned[s])
    # full drain
    for s in range(num_slots):
        pool.free_slot(s)
    assert pool.free_pages == pool.num_pages


def test_select_victim_policies():
    cands = [VictimCandidate(slot=0, admit_seq=5, owned_pages=3),
             VictimCandidate(slot=1, admit_seq=2, owned_pages=1),
             VictimCandidate(slot=2, admit_seq=9, owned_pages=2)]
    assert select_victim(cands, "youngest") == 2      # max admit_seq
    assert select_victim(cands, "fewest-pages") == 1  # min owned
    assert select_victim(cands, "lru") == 1           # oldest arrival
    # page-less slots free nothing and are never victims
    starved = [VictimCandidate(slot=0, admit_seq=1, owned_pages=0)]
    with pytest.raises(OutOfPages):
        select_victim(starved, "youngest")
    with pytest.raises(ValueError, match="policy"):
        select_victim(cands, "coinflip")


def test_swap_pool_roundtrip_accounting():
    sp = SwapPool()
    snap = {"a": np.zeros((4, 2), np.float32), "b": [np.ones(3, np.int32)]}
    sp.put(0, snap)
    assert len(sp) == 1 and 0 in sp
    assert sp.stats.bytes_out == 4 * 2 * 4 + 3 * 4
    with pytest.raises(KeyError):
        sp.put(0, snap)                    # keys are single-use
    got = sp.take(0)
    assert got is snap and len(sp) == 0
    assert sp.stats.held == 0


def test_swapped_slot_cannot_read_stale_pages(tiny_ee_cfg):
    """Regression: preempt stream A (swap out), give its pages to stream
    B, resume A into different pages — A's gather sees exactly its own
    K/V and positions, never B's, and vice versa."""
    from repro.models.attention import init_paged_attn_cache, paged_gather, \
        paged_scatter_prefill, paged_reset_pages
    from repro.serving.cloud_batcher import GATHER_PAGES, WRITE_PAGES, \
        _pad_pages

    rng = np.random.RandomState(0)
    ps, num_pages = 8, 4
    pool = PagePool(num_pages, ps, 2, 4)
    kvh, hd = tiny_ee_cfg.n_kv_heads, tiny_ee_cfg.resolved_head_dim
    cache = init_paged_attn_cache(tiny_ee_cfg, num_pages, ps)

    def row(n):
        return {"k": jnp.asarray(rng.randn(1, n, kvh, hd), jnp.float32),
                "v": jnp.asarray(rng.randn(1, n, kvh, hd), jnp.float32),
                "pos": jnp.arange(n, dtype=jnp.int32)[None]}

    len_a = 2 * ps                                   # A fills two pages
    row_a = row(len_a)
    pages_a = [pool.alloc(0, lp) for lp in range(2)]
    cache = paged_scatter_prefill(cache, row_a, jnp.asarray(pages_a))

    # preempt A: swap its pages to host, free + invalidate on device
    phys = jnp.asarray(_pad_pages(np.asarray(pages_a, np.int32)))
    snap = jax.device_get(GATHER_PAGES({0: cache}, phys))
    freed = pool.free_slot(0)
    cache = paged_reset_pages(cache, jnp.asarray(freed))

    # B takes over (reuses A's physical pages)
    len_b = ps + 3
    row_b = row(len_b)
    pages_b = [pool.alloc(1, lp) for lp in range(2)]
    assert set(pages_b) == set(freed)
    cache = paged_scatter_prefill(cache, row_b, jnp.asarray(pages_b))

    # A resumes into the remaining pages (B keeps its own)
    pages_a2 = [pool.alloc(0, lp) for lp in range(2)]
    assert not set(pages_a2) & set(pages_b)
    phys2 = jnp.asarray(_pad_pages(np.asarray(pages_a2, np.int32)))
    cache = WRITE_PAGES({0: cache}, phys2, snap)[0]

    for slot, rw, ln in ((0, row_a, len_a), (1, row_b, len_b)):
        tbl = jnp.asarray(pool.block_table[slot:slot + 1, :2])
        k, _, kpos = paged_gather(cache, tbl)
        kpos = np.asarray(kpos[0])
        valid = kpos >= 0
        assert valid.sum() == ln
        assert np.array_equal(np.sort(kpos[valid]), np.arange(ln))
        np.testing.assert_array_equal(np.asarray(k[0])[valid],
                                      np.asarray(rw["k"][0]))


# ---------------------------------------------------------------------------
# trained-model confidence pass (slow lane)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("pre", ["recompute", "swap"])
def test_preemption_trained_model_equivalence(tiny_trained, pre):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = [data.sample_tokens(n) for n in (8, 11, 9, 12, 10)]
    dense = ServingSystem(model, params, CollmConfig(theta=0.8,
                                                     kv_layout="paged"))
    d = dense.generate(prompts, 14, mode="collm", num_slots=3)
    sysp = ServingSystem(model, params, CollmConfig(
        theta=0.8, kv_layout="paged", preemption=pre))
    p = sysp.generate(prompts, 14, mode="collm", num_slots=3, num_pages=4)
    assert p["tokens"] == d["tokens"]
    sched = next(iter(sysp._schedulers.values()))
    assert sched.preemptions > 0
    assert sched.pool.free_pages == sched.pool.num_pages


# ---------------------------------------------------------------------------
# int8 pages through the swap path
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_int8_swap_roundtrip_exact(seed, tiny_ee_cfg):
    """Property: swapping an int8 slot out and back reproduces the EXACT
    pre-preemption quantized pages — int8 data, fp32 scales, and positions
    all bit-identical, so preemption can never re-quantize (and therefore
    never drift) a stream's KV."""
    from repro.core.paging import SwapPool
    from repro.models.attention import init_paged_attn_cache, \
        paged_reset_pages, paged_scatter_prefill
    from repro.serving.cloud_batcher import GATHER_PAGES, WRITE_PAGES, \
        _pad_pages

    rng = np.random.RandomState(seed)
    ps, num_pages, n_lp = 8, 6, 3
    pool = PagePool(num_pages, ps, 2, n_lp)
    kvh, hd = tiny_ee_cfg.n_kv_heads, tiny_ee_cfg.resolved_head_dim
    cache = init_paged_attn_cache(tiny_ee_cfg, num_pages, ps,
                                  kv_dtype="int8")

    n = int(rng.randint(ps + 1, n_lp * ps))
    pages = [pool.alloc(0, lp) for lp in range(pages_needed(n, ps))]
    row = {"k": jnp.asarray(rng.randn(1, n, kvh, hd) * 2, jnp.float32),
           "v": jnp.asarray(rng.randn(1, n, kvh, hd) * 2, jnp.float32),
           "pos": jnp.arange(n, dtype=jnp.int32)[None]}
    cache = paged_scatter_prefill(cache, row, jnp.asarray(pages))

    phys = jnp.asarray(_pad_pages(np.asarray(pages, np.int32)))
    before = jax.device_get(GATHER_PAGES({0: cache}, phys))
    assert before[0]["kp"].dtype == np.int8          # swapped bytes are int8
    assert before[0]["ks"].dtype == np.float32       # scales ride along

    swap = SwapPool()
    swap.put("slot0", before)
    freed = pool.free_slot(0)
    cache = paged_reset_pages(cache, jnp.asarray(freed))
    # the reset invalidated every freed position (data is masked via
    # pos = -1 rather than zeroed — same contract as the float32 pool)
    cleared = jax.device_get(GATHER_PAGES({0: cache}, phys))[0]
    assert (cleared["pos"] == -1).all()

    snap = swap.take("slot0")
    # resume into a different permutation of pages (worst case reuse)
    pages2 = [pool.alloc(0, lp) for lp in range(pages_needed(n, ps))]
    phys2 = jnp.asarray(_pad_pages(np.asarray(pages2, np.int32)))
    cache = WRITE_PAGES({0: cache}, phys2, snap)[0]
    after = jax.device_get(GATHER_PAGES({0: cache}, phys2))[0]
    for key in ("kp", "vp", "ks", "vs", "pos"):
        np.testing.assert_array_equal(after[key], snap[0][key])
    # billed swap traffic reflects the quantized layout: int8 data + fp32
    # scales, not the float32 page size
    f32_pages = jax.device_get(GATHER_PAGES(
        {0: init_paged_attn_cache(tiny_ee_cfg, num_pages, ps)}, phys))
    assert swap.stats.bytes_out < 0.5 * SwapPool._nbytes(f32_pages)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2 ** 20))
def test_int8_swap_preemption_token_identical(seed, tiny):
    """int8 paged streams under forced swap preemption == the un-preempted
    int8 run (the swap stores quantized pages verbatim, so preemption adds
    zero additional quantization error)."""
    rng = random.Random(seed)
    max_new = rng.randint(6, 12)
    prompts = _prompts(seed, 4)
    worst = max(pages_needed(len(p) + max_new, PS) for p in prompts)
    schedule = [(rng.randint(1, 2 * max_new), rng.randrange(2))
                for _ in range(rng.randint(1, 4))]

    ref = _system(tiny, theta=0.8, kv_layout="paged", kv_dtype="int8")
    r_ref = ref.generate(prompts, max_new, mode="collm", num_slots=2,
                         max_seq=40)
    sysp = _system(tiny, theta=0.8, kv_layout="paged", kv_dtype="int8",
                   preemption="swap")
    r = sysp.generate(prompts, max_new, mode="collm", num_slots=2,
                      max_seq=40, num_pages=2 * worst,
                      preempt_schedule=schedule)
    assert r["tokens"] == r_ref["tokens"]
    for sched in sysp._schedulers.values():
        if sched.pool is not None:
            assert sched.pool.free_pages == sched.pool.num_pages


# ---------------------------------------------------------------------------
# preemption x multi-token drafting (spec_k > 1, draft in flight)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pre,kv_kw", [
    ("recompute", {}),
    ("recompute", {"kv_layout": "paged"}),
    ("swap", {"kv_layout": "paged"}),
    ("swap", {"kv_layout": "paged", "kv_dtype": "int8"}),
])
def test_draft_inflight_preemption(tiny, pre, kv_kw):
    """Preempt a slot with a k-token draft outstanding (buffered AND
    dispatched): the checkpoint rewinds to the validated prefix, the
    resumed stream re-drafts identically, and the final tokens equal the
    un-preempted blocking run — with every page back on the free list and
    every pending upload drained."""
    prompts = _prompts(13, 3, lo=8, hi=12)
    max_new = 10
    ref = _system(tiny, theta=0.8, **kv_kw).generate(
        prompts, max_new, mode="collm", num_slots=2, max_seq=40)

    sysp = _system(tiny, theta=0.8, speculative=True, spec_k=4,
                   preemption=pre, **kv_kw)
    # 0.05s replies at 0.01s ticks: drafts flush at k=4 and stay in
    # flight across the forced preemption points
    r = sysp.generate(prompts, max_new, mode="collm", num_slots=2,
                      max_seq=40, preempt_schedule=[(4, 0), (7, 1)],
                      channel=ScriptedChannel([0.05], deadline_s=math.inf),
                      tick_time_s=0.01)
    assert r["tokens"] == ref["tokens"]
    st_ = r["stats"]
    assert st_.preemptions >= 1 and st_.draft_tokens > 0
    assert all(0 <= a <= 4 for a in st_.accept_lens)
    assert st_.accepted_tokens == sum(st_.accept_lens)
    for sched in sysp._schedulers.values():
        if sched.pool is not None:
            assert sched.pool.free_pages == sched.pool.num_pages
        assert not sched._preempted
    # no upload-ring entries leaked: end_of_sequence drained every client
    assert all(c["pending"] == 0 for c in r["cm_stats"].values())


@pytest.mark.parametrize("pre", ["recompute", "swap"])
def test_draft_inflight_preemption_batcher(tiny, pre):
    """Draft-in-flight preemption across the shared CloudBatcher: the
    preempted engine's verification reply late-drops, its pooled cloud
    row is released and re-acquired, and no cloud slot leaks."""
    prompts = _prompts(17, 3, lo=8, hi=12)
    max_new = 10
    refsys = _system(tiny, theta=0.8)
    ref = [refsys.generate([p], max_new, mode="collm", num_slots=1)
           ["tokens"][0] for p in prompts]

    sysm = _system(tiny, theta=0.8, kv_layout="paged", speculative=True,
                   spec_k=4, preemption=pre)
    chans = [ScriptedChannel([0.05], deadline_s=math.inf) for _ in range(3)]
    r = sysm.generate_multi(prompts, max_new, cloud_batch=True,
                            channels=chans, tick_time_s=0.01,
                            preempt_schedules=[[(5, 0)], None, [(7, 0)]])
    assert r["tokens"] == ref
    st_ = r["stats"]
    assert st_.preemptions >= 1 and st_.draft_tokens > 0
    assert st_.accepted_tokens == sum(st_.accept_lens)
    # every pooled cloud row back on the free list, all uploads drained
    assert sysm.cloud.cm.cloud_slots_free() == 3
    assert all(c["pending"] == 0 for c in r["cm_stats"].values())
    b = r["batcher"]
    # recompute checkpoints often hold ZERO consumed cloud packets here —
    # the preempt rewinds the whole unvalidated draft, so nothing below
    # the resume point needs replay (restores may be 0); swap always
    # snapshots the row's pages
    if pre == "swap":
        assert b["swaps"] >= 1
