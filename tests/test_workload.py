"""Workload generators: confidence-trace calibration, the open-loop
arrival layer, and the split_clients fan-out guard (docs/fleet_sim.md)."""
import dataclasses

import numpy as np
import pytest

from repro.core import workload
from repro.core.netsim import CaseTrace, TokenTrace
from repro.core.workload import (ALPACA, XSUM, ArrivalProcess,
                                 arrival_times, paper_calibrated_cases,
                                 split_clients, stamp_arrivals)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_paper_cases_seed_deterministic():
    a = paper_calibrated_cases(ALPACA, 20, seed=7)
    b = paper_calibrated_cases(ALPACA, 20, seed=7)
    c = paper_calibrated_cases(ALPACA, 20, seed=8)
    assert [x.prompt_len for x in a] == [x.prompt_len for x in b]
    assert all(t1.conf2 == t2.conf2
               for x, y in zip(a, b) for t1, t2 in zip(x.tokens, y.tokens))
    assert [x.prompt_len for x in a] != [x.prompt_len for x in c]


def test_arrival_times_seed_deterministic():
    proc = ArrivalProcess(rate=10.0, kind="gamma", cv2=4.0,
                          diurnal_amp=0.4, diurnal_period_s=2.0)
    assert arrival_times(proc, 50, seed=3) == arrival_times(proc, 50, seed=3)
    assert arrival_times(proc, 50, seed=3) != arrival_times(proc, 50, seed=4)


# ---------------------------------------------------------------------------
# confidence exceedance calibration
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("profile", [ALPACA, XSUM], ids=["alpaca", "xsum"])
def test_sample_conf_exceedance_matches_profile(profile):
    """P(conf2 >= 0.8) and P(conf2 >= 0.9) of the sampled traces must
    match the Table 2 calibration within sampling noise."""
    cases = paper_calibrated_cases(profile, 60, seed=0)
    confs = np.array([t.conf2 for c in cases for t in c.tokens])
    assert len(confs) >= 3000
    assert abs((confs >= 0.8).mean() - profile.p2_ge_08) < 0.03
    assert abs((confs >= 0.9).mean() - profile.p2_ge_09) < 0.03


# ---------------------------------------------------------------------------
# arrival process moments
# ---------------------------------------------------------------------------
def test_poisson_interarrival_moments():
    t = arrival_times(ArrivalProcess(rate=20.0), 4000, seed=1)
    gaps = np.diff([0.0] + t)
    assert abs(gaps.mean() - 1 / 20.0) < 0.005          # mean = 1/rate
    cv2 = gaps.var() / gaps.mean() ** 2
    assert abs(cv2 - 1.0) < 0.15                        # exponential: cv2=1


def test_gamma_interarrival_burstiness():
    t = arrival_times(ArrivalProcess(rate=20.0, kind="gamma", cv2=4.0),
                      4000, seed=1)
    gaps = np.diff([0.0] + t)
    assert abs(gaps.mean() - 1 / 20.0) < 0.01
    cv2 = gaps.var() / gaps.mean() ** 2
    assert 2.5 < cv2 < 6.0          # bursty: cv2 ~ 4 within sampling noise


def test_diurnal_modulation_shifts_density():
    """With a diurnal ramp, more arrivals land in the sin>0 half-period
    than the sin<0 half-period; peak density ~ (1+amp)/(1-amp) trough."""
    proc = ArrivalProcess(rate=50.0, diurnal_amp=0.8, diurnal_period_s=1.0)
    t = np.asarray(arrival_times(proc, 4000, seed=2))
    phase = np.mod(t, 1.0)
    up = ((phase >= 0.0) & (phase < 0.5)).sum()      # sin >= 0 half
    down = ((phase >= 0.5) & (phase < 1.0)).sum()
    assert up > 1.5 * down
    # exact time-rescaling: Lambda(t_k) is a unit-rate renewal sequence,
    # so its mean gap is ~1
    lam = np.array([proc._cum_intensity(x) for x in t])
    lgaps = np.diff(np.concatenate([[0.0], lam]))
    assert abs(lgaps.mean() - 1.0) < 0.05


def test_invert_roundtrips_cum_intensity():
    proc = ArrivalProcess(rate=3.0, diurnal_amp=0.5, diurnal_period_s=7.0)
    for target in (0.1, 1.0, 12.3, 400.0):
        t = proc._invert(target)
        assert proc._cum_intensity(t) == pytest.approx(target, abs=1e-6)


def test_arrival_times_sorted_nonnegative():
    proc = ArrivalProcess(rate=5.0, kind="gamma", cv2=2.0,
                          diurnal_amp=0.3)
    t = arrival_times(proc, 200, seed=0)
    assert all(x >= 0 for x in t)
    assert t == sorted(t)
    assert arrival_times(proc, 0) == []


@pytest.mark.parametrize("kw", [
    {"rate": 0.0},
    {"rate": -1.0},
    {"rate": 1.0, "kind": "weibull"},
    {"rate": 1.0, "cv2": 0.0},
    {"rate": 1.0, "diurnal_amp": 1.0},
    {"rate": 1.0, "diurnal_amp": -0.1},
    {"rate": 1.0, "diurnal_period_s": 0.0},
])
def test_arrival_process_validation(kw):
    with pytest.raises(ValueError):
        ArrivalProcess(**kw)


# ---------------------------------------------------------------------------
# split_clients guard + arrival stamping
# ---------------------------------------------------------------------------
def _cases(n):
    return [CaseTrace(prompt_len=4 + i, tokens=[TokenTrace(0.5, 0.9)])
            for i in range(n)]


def test_split_clients_round_robin():
    out = split_clients(_cases(7), 3)
    assert [len(x) for x in out] == [3, 2, 2]
    assert out[1][0].prompt_len == 5          # case 1 -> client 1


def test_split_clients_caps_oversized_fleet():
    """More clients than cases used to return silently empty per-client
    lists; now the fan-out caps at len(cases) and every list is busy."""
    out = split_clients(_cases(3), 8)
    assert len(out) == 3
    assert all(len(x) == 1 for x in out)


def test_split_clients_rejects_degenerate_inputs():
    with pytest.raises(ValueError):
        split_clients(_cases(3), 0)
    with pytest.raises(ValueError):
        split_clients([], 2)


def test_stamp_arrivals_copies_with_timestamps():
    cases = _cases(3)
    stamped = stamp_arrivals(cases, [0.5, 1.25, 9.0])
    assert [c.arrival_t for c in stamped] == [0.5, 1.25, 9.0]
    assert all(c.arrival_t == 0.0 for c in cases)        # originals intact
    assert stamped[0].prompt_len == cases[0].prompt_len
    with pytest.raises(ValueError):
        stamp_arrivals(cases, [0.1])                      # too few times


def test_case_trace_arrival_default_is_closed_loop():
    assert dataclasses.fields(CaseTrace)[-1].name == "arrival_t"
    assert CaseTrace(prompt_len=1, tokens=[]).arrival_t == 0.0
    assert workload.traces_from_confidences([2], [[(0.1, 0.9)]])[0] \
        .arrival_t == 0.0
