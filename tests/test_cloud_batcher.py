"""Cross-engine cloud batching + the wire-accounting bugfix sweep.

Covers: the ``CloudServicePoint`` (per-request FIFO vs batched service in
virtual time), the ``CloudBatcher`` (K clients through one pooled masked
cloud step emit token-identical streams to K independent runs, all
collm variants x both KV layouts), the batched-beats-FIFO makespan at
N>=4 with netsim agreeing on the knee, and regressions for the three
wire-accounting fixes: per-row ``StatePacket.pos`` billing, backfill
requests billing consumed uploads exactly once, and channel virtual-time
reset between runs."""
import math

import numpy as np
import pytest

from repro.core.collm import CollmConfig
from repro.core.content_manager import ContentManager
from repro.core.netsim import (CaseTrace, ComputeParams, ModelSplit,
                               NetworkParams, TokenTrace, simulate)
from repro.core.transport import (TOKEN_BYTES, AsyncSimChannel,
                                  CloudServicePoint, ScriptedChannel,
                                  StatePacket, SyncChannel,
                                  hidden_wire_bytes, quantize)
from repro.serving.engine import ServingSystem

WIFI = NetworkParams(up_bw=3.8e6, down_bw=8e6, rtt=0.003)


def _prompts(data, lens):
    return [data.sample_tokens(n) for n in lens]


def _independent(model, params, ccfg, prompts, max_new):
    """Each client decoded alone on a blocking SyncChannel — the reference
    the multi-client engine must match token-for-token."""
    sys0 = ServingSystem(model, params, ccfg)
    return [sys0.generate([p], max_new, mode="collm", num_slots=1)
            ["tokens"][0] for p in prompts]


# ---------------------------------------------------------------------------
# bugfix 1: StatePacket.nbytes bills pos per row
# ---------------------------------------------------------------------------
def test_statepacket_bills_per_row_positions():
    import jax.numpy as jnp
    hidden = quantize(jnp.zeros((4, 1, 16), jnp.float32), "float16")
    base = StatePacket(hidden=hidden).nbytes()
    # scalar position: one int32 on the wire
    assert StatePacket(hidden=hidden, pos=jnp.asarray(7)).nbytes() == base + 4
    assert StatePacket(hidden=hidden, pos=5).nbytes() == base + 4
    # batched upload: a (B,) per-row position vector bills every entry
    pos = jnp.arange(4, dtype=jnp.int32)
    assert StatePacket(hidden=hidden, pos=pos).nbytes() == base + 4 * 4


def test_statepacket_bills_int8_scales_per_leaf():
    """int8 packets carry one fp32 scale tensor PER quantized leaf — a
    recurrent ``states`` tree with K leaves ships K scale tensors, and
    ``nbytes`` must bill them all explicitly (the wire_breakdown audit),
    not fold them into the data payload."""
    import jax.numpy as jnp
    from repro.core.transport import quantize_tree

    b, d = 4, 16
    hidden = quantize(jnp.zeros((b, 1, d), jnp.float32), "int8")
    # hybrid-style recurrent snapshot: two boundary layers, two leaves each
    states = {"layer0": {"c": jnp.zeros((b, 8, d)), "n": jnp.zeros((b, d))},
              "layer3": {"c": jnp.zeros((b, 8, d)), "n": jnp.zeros((b, d))}}
    qstates = quantize_tree(states, "int8")
    pkt = StatePacket(hidden=hidden, states=qstates,
                      pos=jnp.arange(b, dtype=jnp.int32))

    bd = pkt.wire_breakdown()
    # data: int8 payloads, one byte per element
    data_elems = b * 1 * d + 2 * (b * 8 * d + b * d)
    assert bd["data"] == data_elems
    # scales: fp32, one per row of each quantized leaf — 1 hidden leaf +
    # 4 states leaves, each with its own (rows, 1) scale tensor
    scale_elems = b * 1 + 2 * (b * 8 + b)
    assert bd["scale"] == 4 * scale_elems
    assert bd["pos"] == 4 * b
    assert pkt.nbytes() == bd["data"] + bd["scale"] + bd["pos"]
    # float16 states carry no scales at all
    pkt16 = StatePacket(hidden=quantize(jnp.zeros((b, 1, d)), "float16"),
                        states=quantize_tree(states, "float16"))
    assert pkt16.wire_breakdown()["scale"] == 0


# ---------------------------------------------------------------------------
# bugfix 2: backfill requests bill consumed uploads exactly once
# ---------------------------------------------------------------------------
def test_backfill_request_bills_uploads_once(tiny_trained):
    """Uploads are billed at upload time (notify_upload); the request that
    consumes them — one upload, or a whole backfill ring — is a token-sized
    control message.  Channel-level wire accounting must therefore be
    exactly: notified upload bytes + TOKEN_BYTES per request, matching how
    netsim prices the same trace (hidden bytes per upload + TOKEN_BYTES
    per request)."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompt = data.sample_tokens(9)
    ch = SyncChannel()
    sysq = ServingSystem(model, params,
                         CollmConfig(theta=0.8, backfill=True))
    r = sysq.generate_sequential([prompt], 10, mode="collm", channel=ch)
    st = r["stats"]
    prompt_bytes = hidden_wire_bytes(model.cfg.d_model, "float16",
                                     seq=len(prompt))
    # st.upload_bytes = prompt upload + per-token packets; the channel saw
    # the per-token packets (notified) + TOKEN_BYTES framing per request —
    # nothing double-billed, nothing the backfill ring consumed for free
    assert ch.stats.bytes_up == (st.upload_bytes - prompt_bytes
                                 + TOKEN_BYTES * ch.stats.requests)
    assert ch.stats.requests > 0
    # every consumed upload reached the content manager with the same bytes
    cm_bytes = r["cm_stats"]["edge-0"]["bytes_received"]
    assert cm_bytes == st.upload_bytes - prompt_bytes
    # netsim parity: a per-token packet is the hidden payload plus its
    # int32 position; requests are TOKEN_BYTES in both accountings
    per_tok = hidden_wire_bytes(model.cfg.d_model, "float16") + 4
    assert cm_bytes == per_tok * (st.tokens - 1)


# ---------------------------------------------------------------------------
# bugfix 3: channels forget virtual time between runs
# ---------------------------------------------------------------------------
def test_async_channel_reset_clears_virtual_state():
    ch = AsyncSimChannel(WIFI, service_s=0.01)
    first = ch.arrival_of(ch.submit(slot=0, reply=0, now=0.0, nbytes_up=64))
    for i in range(20):        # pile up link + service backlog
        ch.submit(slot=0, reply=i, now=0.0, nbytes_up=10_000)
    ch.poll(math.inf)
    ch.reset()
    again = ch.arrival_of(ch.submit(slot=0, reply=0, now=0.0, nbytes_up=64))
    assert again == pytest.approx(first)
    assert ch.in_flight() == 1        # reset dropped nothing live afterwards


def test_reused_channel_gives_identical_traces(tiny_trained):
    """BatchScheduler.run resets the channel: a second generate() through
    the same AsyncSimChannel must price the identical request trace
    identically instead of inheriting the first run's virtual backlog."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [9, 10])
    ch = AsyncSimChannel(WIFI, service_s=0.004)
    times = []
    for _ in range(2):
        r = ServingSystem(model, params, CollmConfig(theta=0.8)).generate(
            prompts, 8, mode="collm", num_slots=2, channel=ch,
            tick_time_s=0.01)
        times.append(r["virtual_time"])
    assert times[0] == pytest.approx(times[1])


# ---------------------------------------------------------------------------
# CloudServicePoint: FIFO vs batched service
# ---------------------------------------------------------------------------
def test_service_point_rejects_window_without_batching():
    """A window with max_batch=1 would delay every request and coalesce
    nothing — strictly worse than FIFO, so it must fail loudly."""
    with pytest.raises(ValueError):
        CloudServicePoint(0.01, batch_window_s=0.005)
    with pytest.raises(ValueError):
        CloudServicePoint(0.01, max_batch=0)


def test_service_point_fifo_serializes():
    svc = CloudServicePoint(0.01)
    assert svc.service(0.0) == pytest.approx(0.01)
    assert svc.service(0.0) == pytest.approx(0.02)   # queues behind
    assert svc.service(0.05) == pytest.approx(0.06)  # idle gap, no batch
    assert svc.batches == 3 and svc.requests == 3
    assert svc.busy_s == pytest.approx(0.03)


def test_service_point_batches_within_window():
    svc = CloudServicePoint(0.01, batch_window_s=0.005, max_batch=3)
    d0 = svc.service(0.0)
    assert d0 == pytest.approx(0.015)                # window + one service
    assert svc.service(0.004) == pytest.approx(d0)   # joins, same completion
    assert svc.service(0.005) == pytest.approx(d0)   # batch full at 3
    d1 = svc.service(0.005)                          # 4th opens a new batch
    assert d1 == pytest.approx(max(0.005 + 0.005, d0) + 0.01)
    assert svc.batches == 2
    assert svc.busy_s == pytest.approx(0.02)         # one service per batch
    # a late-window straggler after the window closed opens its own batch
    assert svc.service(1.0) == pytest.approx(1.015)
    assert svc.batches == 3


def test_service_point_variable_service_extends_batch():
    svc = CloudServicePoint(0.01, batch_window_s=0.01, max_batch=4)
    d0 = svc.service(0.0, 0.01)
    d1 = svc.service(0.001, 0.03)    # costlier member stretches completion
    assert d1 == pytest.approx(d0 + 0.02)
    assert svc.busy_s == pytest.approx(0.03)


# ---------------------------------------------------------------------------
# multi-client equivalence: K clients through the CloudBatcher
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.parametrize("backfill", [False, True])
def test_multi_client_matches_independent_runs(tiny_trained, layout,
                                               backfill):
    """K clients, each its own engine, served by one CloudBatcher over a
    pooled batch-major cloud cache: greedy streams must be token-identical
    to K independent single-client runs (release and backfill semantics,
    dense and paged cloud KV)."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8, 11, 9])
    ccfg = CollmConfig(theta=0.8, kv_layout=layout, backfill=backfill)
    ref = _independent(model, params, ccfg, prompts, 8)
    r = ServingSystem(model, params, ccfg).generate_multi(
        prompts, 8, cloud_batch=True)
    assert r["tokens"] == ref
    assert r["batcher"]["requests"] > 0
    # per-client accounting survived the pooling
    assert r["stats"].tokens == 8 * len(prompts)


@pytest.mark.parametrize("mode", ["standalone", "cloud"])
def test_multi_client_other_modes(tiny_trained, mode):
    """standalone/cloud modes never touch the cloud channel: the
    multi-engine driver must reproduce independent runs without a
    batcher."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 8])
    ccfg = CollmConfig(theta=0.8)
    sys0 = ServingSystem(model, params, ccfg)
    ref = [sys0.generate([p], 8, mode=mode, num_slots=1)["tokens"][0]
           for p in prompts]
    r = ServingSystem(model, params, ccfg).generate_multi(
        prompts, 8, mode=mode, cloud_batch=True)
    assert r["tokens"] == ref
    assert "batcher" not in r


def test_more_clients_than_engines_refill(tiny_trained):
    """5 streams over 2 engines: cloud slots are released at retirement
    and reassigned to queued streams; every stream matches its
    independent run."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8, 9, 10, 8, 11])
    ccfg = CollmConfig(theta=0.8)
    ref = _independent(model, params, ccfg, prompts, 6)
    r = ServingSystem(model, params, ccfg).generate_multi(
        prompts, 6, n_engines=2, cloud_batch=True)
    assert r["tokens"] == ref


def test_speculative_multi_client_reconciles(tiny_trained):
    """Speculative decode through the batcher: provisional tokens +
    rewind-on-mismatch (with queued-request cancellation and pooled-cache
    invalidation) still converge to the blocking streams."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8, 10, 9])
    ref = _independent(model, params, CollmConfig(theta=0.8), prompts, 8)
    svc = CloudServicePoint(0.004, batch_window_s=0.002, max_batch=3)
    chans = [AsyncSimChannel(WIFI, service=svc) for _ in prompts]
    r = ServingSystem(model, params,
                      CollmConfig(theta=0.8, speculative=True)
                      ).generate_multi(prompts, 8, cloud_batch=True,
                                       channels=chans, tick_time_s=0.01)
    assert r["tokens"] == ref
    assert r["stats"].stall_s == 0.0


def test_deadline_misses_cancel_batcher_entries(tiny_trained):
    """Replies far slower than the deadline: streams complete on
    edge-committed tokens, and the retiring streams' queued batcher
    entries are cancelled instead of computing into freed slots."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [9, 10])
    chans = [ScriptedChannel([0.5], deadline_s=0.02) for _ in prompts]
    r = ServingSystem(model, params, CollmConfig(theta=0.8)).generate_multi(
        prompts, 8, cloud_batch=True, channels=chans, tick_time_s=0.005)
    assert all(len(t) == 8 for t in r["tokens"])
    assert r["stats"].deadline_misses > 0
    b = r["batcher"]
    # every queued request either computed in a wave or was cancelled
    assert b["steps"] * 1 <= b["requests"]
    assert b["cancelled"] > 0


# ---------------------------------------------------------------------------
# the knee: batched cloud beats per-request FIFO at N>=4
# ---------------------------------------------------------------------------
def test_batched_cloud_beats_fifo_at_four_clients(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    n = 4
    prompts = _prompts(data, [10] * n)
    ccfg = CollmConfig(theta=0.8)
    ref = _independent(model, params, ccfg, prompts, 10)
    runs = {}
    for batched in (False, True):
        svc = CloudServicePoint(
            0.008, batch_window_s=0.004 if batched else 0.0,
            max_batch=n if batched else 1)
        chans = [AsyncSimChannel(WIFI, service=svc) for _ in range(n)]
        r = ServingSystem(model, params, ccfg).generate_multi(
            prompts, 10, cloud_batch=batched, channels=chans,
            tick_time_s=0.01)
        assert r["tokens"] == ref
        runs[batched] = (r, svc)
    r_b, svc_b = runs[True]
    r_f, svc_f = runs[False]
    assert r_b["virtual_time"] < r_f["virtual_time"]
    # the separating quantity: one masked step serves several requests
    assert r_b["batcher"]["mean_batch"] > 1.0
    assert svc_b.busy_s < svc_f.busy_s


def test_netsim_agrees_on_the_batched_knee():
    """The simulator prices the cloud through the same CloudServicePoint:
    enabling the batching knobs must lower both the makespan and the
    cloud busy time of a saturated N-client ce_collm trace, and the
    default knobs must keep the historical FIFO accounting."""
    n, toks = 6, 24
    cases = [[CaseTrace(prompt_len=12,
                        tokens=[TokenTrace(0.0, 0.0)] * toks)]
             for _ in range(n)]      # every token requests the cloud
    net = NetworkParams()
    comp = ComputeParams(edge_layer_time=1e-4, cloud_layer_time=1e-3)
    split = ModelSplit(n_layers=8, l_ee1=2, l_ee2=4, d_model=128)
    fifo = simulate("ce_collm", cases, net, comp, split, theta=0.8)
    batched = simulate("ce_collm", cases, net, comp, split, theta=0.8,
                       cloud_batch_window=0.004, cloud_max_batch=n)
    assert fifo.cloud_requests == batched.cloud_requests == n * toks
    assert batched.total_time < fifo.total_time
    assert batched.cloud_time < fifo.cloud_time
    # FIFO busy time is the historical per-request sum
    svc_c = (split.n_layers - split.l_ee1) * comp.cloud_layer_time
    prefill = (12 * (split.n_layers - split.l_ee1)
               * comp.cloud_layer_time * comp.prefill_discount)
    assert fifo.cloud_time == pytest.approx(n * (toks * svc_c + prefill))


# ---------------------------------------------------------------------------
# ContentManager cloud slot pool
# ---------------------------------------------------------------------------
def test_cloud_slot_pool_lifecycle():
    cm = ContentManager()
    cm.init_cloud_slots(2)
    a = cm.assign_cloud_slot("a")
    b = cm.assign_cloud_slot("b")
    assert {a, b} == {0, 1}
    assert cm.assign_cloud_slot("a") == a          # idempotent
    assert cm.cloud_slots_free() == 0
    with pytest.raises(RuntimeError):
        cm.assign_cloud_slot("c")
    assert cm.release_cloud_slot("a") == a
    assert cm.cloud_slot("a") is None
    assert cm.assign_cloud_slot("c") == a          # recycled
    assert cm.release_cloud_slot("nobody") is None


# ---------------------------------------------------------------------------
# wire accounting: k-token draft verification requests
# ---------------------------------------------------------------------------
def test_draft_request_bytes_unit():
    """A k-token verification request is k token ids of control traffic —
    the k hidden rows were already billed by their per-tick uploads.
    ``draft_request_bytes`` is the single source of truth, and k=1 must
    cost exactly the classic speculative request."""
    from repro.core.transport import draft_request_bytes
    assert draft_request_bytes(1) == TOKEN_BYTES
    for k in (2, 4, 8):
        assert draft_request_bytes(k) == k * TOKEN_BYTES


@pytest.mark.parametrize("backfill", [False, True])
@pytest.mark.parametrize("k", [1, 4])
def test_draft_request_bills_k_tokens_once(tiny_trained, backfill, k):
    """Channel-level accounting with drafting: uploaded hidden rows are
    billed once at notify time (the draft buffer holds packets at the
    engine — they must never be re-billed at flush), and each
    verification request adds exactly its k token ids up and k verified
    ids down.  Holds identically in backfill mode, where the flush-time
    ring drain rides the SAME request (no extra control message, no
    re-billed hiddens)."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [9, 11])
    ch = SyncChannel()
    ccfg = CollmConfig(theta=0.8, speculative=True, spec_k=k,
                       backfill=backfill)
    r = ServingSystem(model, params, ccfg).generate(
        prompts, 10, mode="collm", num_slots=2, channel=ch)
    st = r["stats"]
    assert st.draft_tokens > 0
    prompt_bytes = sum(hidden_wire_bytes(model.cfg.d_model, "float16",
                                         seq=len(p)) for p in prompts)
    # bytes_up = notified per-token uploads + k token ids per request;
    # the admission prompt upload never crosses this channel
    assert ch.stats.bytes_up == (st.upload_bytes - prompt_bytes
                                 + TOKEN_BYTES * st.draft_tokens)
    # every reply ships its group's k verified ids back down
    assert ch.stats.bytes_down == TOKEN_BYTES * st.draft_tokens
    # the content manager received each uploaded packet exactly once
    cm_bytes = sum(c["bytes_received"] for c in r["cm_stats"].values())
    assert cm_bytes == st.upload_bytes - prompt_bytes


def test_draft_resubmit_after_cancel_not_double_billed(tiny_trained):
    """Rewinds cancel in-flight draft groups and the rejected suffix is
    re-decoded, re-uploaded and re-verified: the re-submitted positions
    are new wire events on BOTH sides of the ledger, so the equality
    bytes_up == uploads + k·TOKEN_BYTES·requests must survive an entire
    rewind-heavy run (any double- or zero-billing on cancel/re-submit
    breaks it)."""
    import jax
    model = tiny_trained["model"]
    # UNTRAINED params: the exit heads disagree with the full model almost
    # everywhere, so the run is rewind-heavy by construction (the trained
    # model's l_ee2 head agrees with the cloud and never rewinds)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, model.cfg.vocab_size, size=n)
               for n in (8, 10, 9)]
    ch = AsyncSimChannel(WIFI, service_s=0.004)
    ccfg = CollmConfig(theta=0.8, speculative=True, spec_k=4)
    r = ServingSystem(model, params, ccfg).generate(
        prompts, 12, mode="collm", num_slots=2, channel=ch,
        tick_time_s=0.01)
    st = r["stats"]
    assert st.spec_rewinds > 0          # the run actually exercised cancels
    prompt_bytes = sum(hidden_wire_bytes(model.cfg.d_model, "float16",
                                         seq=len(p)) for p in prompts)
    assert ch.stats.bytes_up == (st.upload_bytes - prompt_bytes
                                 + TOKEN_BYTES * st.draft_tokens)
    assert ch.stats.bytes_down == TOKEN_BYTES * st.draft_tokens
