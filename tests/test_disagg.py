"""Two-tier (disaggregated) runtime: live decode across edge/cloud programs.

On this 1-device box both tiers map to the same device mesh — the tier
split, wire quantization, device_put transfer, and per-tier caches are
still fully exercised."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.collm import CoLLM, CollmConfig
from repro.core.disagg import TwoTierRuntime
from repro.launch.mesh import make_debug_mesh


@pytest.mark.parametrize("wire", ["float32", "float16"])
def test_two_tier_decode_matches_full_model(tiny_trained, wire):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    mesh = make_debug_mesh(1)
    rt = TwoTierRuntime(model, CollmConfig(theta=1.1, wire_format=wire),
                        mesh, mesh)
    rt.build(params, params)
    prompt = jnp.asarray(data.sample_tokens(10)[None, :])
    toks, info = rt.decode(prompt, 10, max_seq=64)
    assert info["wire_bytes"] > 0

    # full-model greedy reference
    co = CoLLM(model, CollmConfig())
    caches = model.init_cache(1, 64)
    x, _, caches, _ = model.prefill(params, {"tokens": prompt}, caches)
    tok = jnp.argmax(model.logits(params, x[:, -1:])[:, 0], -1).astype(jnp.int32)
    ref = [int(tok[0])]
    for t in range(9):
        tok, _, caches = co.full_step(params, tok[:, None], caches,
                                      jnp.asarray(10 + t, jnp.int32))
        ref.append(int(tok[0]))
    if wire == "float32":
        assert toks == ref                       # exact at theta>1 + fp32
    else:
        agree = sum(a == b for a, b in zip(toks, ref)) / len(ref)
        assert agree >= 0.8                      # fp16 wire: near-identical


def test_two_tier_adaptive_reduces_wire(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    mesh = make_debug_mesh(1)
    rt = TwoTierRuntime(model, CollmConfig(theta=0.5, wire_format="float16"),
                        mesh, mesh)
    rt.build(params, params)
    prompt = jnp.asarray(data.sample_tokens(10)[None, :])
    toks, info = rt.decode(prompt, 12, max_seq=64)
    assert len(toks) == 12
    # uploads still happen every token (parallel upload), but cloud compute
    # is skipped for exited tokens — wire bytes equal per-token uploads
    d = model.cfg.d_model
    assert info["wire_bytes"] == 11 * d * 2      # fp16 per generated step
