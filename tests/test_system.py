"""End-to-end system test: train a tiny EE-LLM, serve it in all three
deployment modes, feed measured partition times into the network simulator,
and check the paper's headline claims hold on OUR stack."""
import jax.numpy as jnp
import numpy as np

from repro.core.collm import CollmConfig
from repro.core.netsim import (ComputeParams, ModelSplit, NetworkParams,
                               simulate)
from repro.core.workload import traces_from_confidences, split_clients
from repro.serving.engine import ServingSystem, token_agreement


def test_end_to_end_paper_pipeline(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = [data.sample_tokens(10) for _ in range(3)]

    # 1. serve in co-inference mode, record real confidences + timings
    sys08 = ServingSystem(model, params, CollmConfig(theta=0.8))
    r = sys08.generate(prompts, 20, mode="collm")
    st = r["stats"]
    assert 0.0 <= st.request_rate <= 1.0
    assert len(st.confidences) > 0

    # 2. agreement with the undivided model stays high (paper ROUGE-L>0.9)
    base = ServingSystem(model, params, CollmConfig(theta=1.0)).generate(
        prompts, 20, mode="cloud")
    ags = [token_agreement(a, b)
           for a, b in zip(r["tokens"], base["tokens"])]
    assert np.mean(ags) > 0.5   # tiny model; paper-scale models exceed 0.9

    # 3. replay the measured confidence traces through the simulator
    per_client = [[], [], []]
    for i, c in enumerate(st.confidences):
        per_client[i % 3].append(c)
    cases = traces_from_confidences([10] * len(prompts),
                                    [c for c in per_client if c])
    cfg = model.cfg
    comp = ComputeParams(edge_layer_time=1e-3, cloud_layer_time=1e-3,
                         exit_head_time=5e-4)
    split = ModelSplit(n_layers=cfg.n_layers, l_ee1=cfg.exit_layers[0],
                       l_ee2=cfg.exit_layers[-1], d_model=cfg.d_model)
    net = NetworkParams()
    res_collm = simulate("ce_collm", split_clients(cases, 1), net, comp,
                         split, theta=0.8)
    res_cloud = simulate("cloud_llm", split_clients(cases, 1), net, comp,
                         split)
    res_naive = simulate("naive", split_clients(cases, 1), net, comp, split,
                         half_precision=False)
    # the paper's core qualitative claims on our measured traces:
    assert res_naive.total_time > res_cloud.total_time          # naive loses
    assert res_collm.cloud_time < res_cloud.cloud_time          # cloud offload
    assert res_collm.transmitted_mb < res_naive.transmitted_mb / 10
