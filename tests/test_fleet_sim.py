"""Fleet simulation: adaptive controllers, open-loop arrival replay
through the engine, and the netsim arrival honoring (docs/fleet_sim.md).

Fast lane: controller unit tests (pure virtual-time arithmetic).
Slow lane (``tiny_trained``): open-loop ``generate``/``generate_multi``
replay — TTFT/SLO accounting, idle-engine tolerance, and adaptive
control staying token-invisible."""
import pytest

from repro.core.netsim import (CaseTrace, ComputeParams, ModelSplit,
                               NetworkParams, TokenTrace, simulate)
from repro.core.transport import CloudServicePoint
from repro.core.workload import ArrivalProcess, arrival_times
from repro.serving.adaptive import (AdaptiveConfig, AdaptiveController,
                                    FluidCapacity, ResumeCostModel,
                                    WindowController)


# ---------------------------------------------------------------------------
# WindowController
# ---------------------------------------------------------------------------
def _svc(window=0.004, max_batch=4, service=0.008):
    return CloudServicePoint(service, batch_window_s=window,
                             max_batch=max_batch)


def test_window_controller_warmup_keeps_static_window():
    ctrl = WindowController(min_obs=4)
    svc = _svc()
    for k in range(4):
        assert ctrl.observe(0.01 * k, svc) == svc.batch_window_s
    assert ctrl.adjustments == 0


def test_window_controller_sparse_arrivals_drop_window_to_zero():
    ctrl = WindowController(min_obs=2)
    svc = _svc(service=0.008)
    # 100ms gaps: rate 10/s, rate*service = 0.08 << 1 -> pure latency tax
    last = None
    for k in range(8):
        last = ctrl.observe(0.1 * k, svc)
    assert last == 0.0
    assert ctrl.mean_gap_s == pytest.approx(0.1, rel=0.05)


def test_window_controller_dense_arrivals_size_window_to_batch():
    ctrl = WindowController(min_obs=2, max_window_s=0.016)
    svc = _svc(max_batch=4, service=0.008)
    # 2ms gaps: rate 500/s, rate*service = 4 >= 1 -> coalesce
    last = None
    for k in range(12):
        last = ctrl.observe(0.002 * k, svc)
    assert last == pytest.approx((svc.max_batch - 1) * 0.002, rel=0.1)
    ctrl2 = WindowController(min_obs=2, max_window_s=0.003)
    for k in range(12):
        last = ctrl2.observe(0.002 * k, svc)
    assert last == 0.003                       # clamped to max_window_s


def test_window_controller_ignores_out_of_order_ready_times():
    ctrl = WindowController(min_obs=2)
    svc = _svc()
    ctrl.observe(0.10, svc)
    ctrl.observe(0.08, svc)        # out-of-order uplink interleave
    assert ctrl.mean_gap_s == 0.0  # negative gap carries no information
    ctrl.observe(0.12, svc)
    assert ctrl.mean_gap_s > 0.0


def test_service_point_consults_controller_and_resets_it():
    class Fixed:
        def __init__(self):
            self.calls, self.resets = 0, 0

        def observe(self, ready_t, svc):
            self.calls += 1
            return 0.123

        def reset(self):
            self.resets += 1

    ctrl = Fixed()
    svc = CloudServicePoint(0.008, batch_window_s=0.004, max_batch=2,
                            window_controller=ctrl)
    resets0 = ctrl.resets            # __init__ resets once already
    svc.service(0.0)
    assert ctrl.calls == 1 and svc.batch_window_s == 0.123
    svc.reset()
    assert svc.batch_window_s == 0.004        # static knob restored
    assert ctrl.resets == resets0 + 1


def test_window_controller_validation():
    with pytest.raises(ValueError):
        WindowController(max_window_s=0.0)
    with pytest.raises(ValueError):
        WindowController(ewma=0.0)


# ---------------------------------------------------------------------------
# ResumeCostModel + FluidCapacity
# ---------------------------------------------------------------------------
def test_resume_cost_crossover():
    rc = ResumeCostModel(d0_s=0.004, d1_s=2e-4, host_bw=1e8)
    assert rc.recompute_s(0) == 0.004
    assert rc.recompute_s(100) == pytest.approx(0.024)
    assert rc.swap_s(1_000_000) == pytest.approx(0.02)
    # short context, heavy KV -> recompute; long context, light KV -> swap
    assert not rc.prefer_swap(10, 10_000_000)
    assert rc.prefer_swap(1000, 1_000_000)


def test_fluid_capacity_curve_and_gate():
    cap = FluidCapacity(m_total=256, b_tokens=4, d0_s=0.004, d1_s=1e-3)
    assert cap.batch_time_s(0) == 0.004
    assert cap.batch_time_s(100) == 0.008      # clamped at b_tokens
    assert cap.throughput(0) == 0.0
    assert cap.throughput(4) == pytest.approx(4 / 0.008)
    assert cap.can_admit(resident_tokens=100, active_streams=2,
                         new_tokens=100)
    assert not cap.can_admit(200, 2, 100)      # memory curve exceeded
    assert not cap.can_admit(0, 4, 10)         # batch budget full


# ---------------------------------------------------------------------------
# AdaptiveController (watermark AIMD)
# ---------------------------------------------------------------------------
class _Pool:
    def __init__(self, num_pages=40, page_size=8, num_slots=4,
                 watermark=0):
        self.num_pages, self.page_size = num_pages, page_size
        self.num_slots, self.watermark = num_slots, watermark


def _controller(**cfg_kw):
    cfg = AdaptiveConfig(interval_ticks=2, quiet_intervals=2, **cfg_kw)
    ctrl = AdaptiveController(cfg)
    pool = _Pool()
    ctrl.attach(pool, ResumeCostModel())
    return ctrl, pool


def test_aimd_raises_watermark_under_pressure():
    ctrl, pool = _controller()
    ctrl.on_tick(2, pool, preemptions=3, oops=1)
    assert pool.watermark == 4                 # +max(1, 4 events) ... wait
    ctrl.on_tick(3, pool, preemptions=3, oops=1)   # mid-interval: no-op
    assert pool.watermark == 4
    ctrl.on_tick(4, pool, preemptions=30, oops=0)
    assert pool.watermark == 10                # clamped at 25% of 40 pages
    assert ctrl.watermark_raises == 2


def test_aimd_decays_watermark_after_quiet_intervals():
    ctrl, pool = _controller()
    ctrl.on_tick(2, pool, preemptions=2, oops=0)
    assert pool.watermark == 2
    ctrl.on_tick(4, pool, 2, 0)        # quiet 1
    ctrl.on_tick(6, pool, 2, 0)        # quiet 2 -> decay
    assert pool.watermark == 1
    ctrl.on_tick(8, pool, 2, 0)
    ctrl.on_tick(10, pool, 2, 0)       # decay to floor
    ctrl.on_tick(12, pool, 2, 0)
    ctrl.on_tick(14, pool, 2, 0)
    assert pool.watermark == 0         # never below the attach-time floor
    assert ctrl.watermark_decays == 2


def test_adaptive_attach_derives_fluid_capacity_from_pool():
    ctrl, pool = _controller()
    assert ctrl.capacity.m_total == pool.num_pages * pool.page_size
    assert ctrl.capacity.b_tokens == pool.num_slots
    assert ctrl.admit_ok(0, 0, 10)
    assert not ctrl.admit_ok(pool.num_pages * pool.page_size, 0, 1)
    assert ctrl.gate_holds == 1
    row = ctrl.as_row()
    assert row["gate_holds"] == 1


def test_adaptive_gate_can_be_disabled():
    ctrl, pool = _controller(gate_admission=False)
    assert ctrl.admit_ok(10 ** 9, 10 ** 9, 10 ** 9)
    assert ctrl.gate_holds == 0


# ---------------------------------------------------------------------------
# netsim honors case arrival stamps
# ---------------------------------------------------------------------------
def _netsim_args():
    net = NetworkParams(up_bw=4e6, down_bw=8e6, rtt=0.003)
    comp = ComputeParams(edge_layer_time=1e-3, cloud_layer_time=1e-3)
    split = ModelSplit(n_layers=12, l_ee1=4, l_ee2=6, d_model=256)
    return net, comp, split


def test_netsim_waits_for_case_arrival():
    net, comp, split = _netsim_args()
    toks = [TokenTrace(0.95, 0.99)] * 3
    closed = [[CaseTrace(prompt_len=8, tokens=list(toks))]]
    stamped = [[CaseTrace(prompt_len=8, tokens=list(toks), arrival_t=5.0)]]
    r0 = simulate("standalone", closed, net, comp, split)
    r1 = simulate("standalone", stamped, net, comp, split)
    assert r1.total_time >= 5.0
    assert r1.total_time == pytest.approx(5.0 + r0.total_time, rel=1e-6)
    assert r1.tokens == r0.tokens


# ---------------------------------------------------------------------------
# engine open-loop replay (slow lane)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served(tiny_trained):
    from repro.core.collm import CollmConfig
    from repro.serving.engine import ServingSystem
    model, params = tiny_trained["model"], tiny_trained["params"]
    data = tiny_trained["data"]
    prompts = [data.sample_tokens(8) for _ in range(4)]
    return {"mk": lambda ccfg=None: ServingSystem(
                model, params, ccfg or CollmConfig(theta=0.8)),
            "prompts": prompts, "data": data}


def test_open_loop_arrivals_gate_admission_and_ttft(served):
    sysv = served["mk"]()
    prompts = served["prompts"]
    arr = [0.0, 0.2, 0.4, 3.0]
    r = sysv.generate(prompts, 6, num_slots=2, tick_time_s=0.01,
                      arrivals=arr, slo_ttft_s=5.0, slo_tpot_s=5.0)
    st = r["stats"]
    assert len(st.ttft_s) == len(prompts)
    assert all(t >= 0.0 for t in st.ttft_s)
    assert len(st.token_lat_s) == sum(len(t) - 1 for t in r["tokens"])
    # the last request arrives at t=3.0: the makespan must cover it
    assert r["virtual_time"] >= 3.0
    assert st.slo_total == len(prompts) and st.slo_met == st.slo_total
    assert st.slo_attainment == 1.0
    # arrivals are timing-only: tokens match the closed-loop replay
    r0 = sysv.generate(prompts, 6, num_slots=2, tick_time_s=0.01)
    assert r["tokens"] == r0["tokens"]


def test_open_loop_arrival_idle_gap_counts_as_idle(served):
    sysv = served["mk"]()
    prompts = served["prompts"][:1]
    r = sysv.generate(prompts, 4, num_slots=1, tick_time_s=0.01,
                      arrivals=[2.0])
    # nothing ran before t=2: the whole gap is idle, TTFT starts at 2.0
    assert r["virtual_time"] >= 2.0
    assert r["stats"].ttft_s[0] < 1.0


def test_slo_miss_counted(served):
    sysv = served["mk"]()
    prompts = served["prompts"][:2]
    # impossible TPOT target: every stream must miss
    r = sysv.generate(prompts, 6, num_slots=2, tick_time_s=0.01,
                      arrivals=[0.0, 0.0], slo_tpot_s=1e-9)
    st = r["stats"]
    assert st.slo_total == 2 and st.slo_met == 0
    assert st.slo_attainment == 0.0


def test_generate_multi_tolerates_idle_engines(served):
    sysv = served["mk"]()
    prompts = served["prompts"][:2]
    # 4 engines, 2 prompts: engines 2/3 never see a request
    r = sysv.generate_multi(prompts, 5, n_engines=4, tick_time_s=0.01,
                            arrivals=[0.0, 0.5])
    assert all(t is not None and len(t) == 5 for t in r["tokens"])
    assert r["virtual_time"] >= 0.5
    ref = sysv.generate_multi(prompts, 5, n_engines=2, tick_time_s=0.01)
    assert r["tokens"] == ref["tokens"]


def test_adaptive_control_is_token_invisible(served):
    from repro.core.collm import CollmConfig
    ccfg = CollmConfig(theta=0.8, kv_layout="paged", preemption="swap")
    max_new = 8
    prompts = [served["data"].sample_tokens(12) for _ in range(4)]
    arr = arrival_times(ArrivalProcess(rate=40.0, kind="gamma", cv2=4.0),
                        len(prompts), seed=0)
    ps = ccfg.page_size
    worst = max((len(p) + max_new - 1) // ps + 1 for p in prompts)
    pages = max(worst, int(0.6 * 2 * worst))
    rc = ResumeCostModel(host_bw=2e7)
    kw = dict(num_slots=2, num_pages=pages, tick_time_s=0.01,
              arrivals=arr, resume_cost=rc)
    r_ad = served["mk"](ccfg).generate(prompts, max_new,
                                       adaptive=AdaptiveConfig(), **kw)
    r_st = served["mk"](CollmConfig(theta=0.8, kv_layout="paged",
                                    preemption="recompute")
                        ).generate(prompts, 8, **kw)
    assert r_ad["tokens"] == r_st["tokens"]
    assert r_ad["adaptive"] is not None
    assert r_st["adaptive"] is None


def test_adaptive_requires_paged_pool(served):
    with pytest.raises(ValueError, match="paged"):
        served["mk"]().generate(served["prompts"][:1], 4,
                                adaptive=AdaptiveConfig())
