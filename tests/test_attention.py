"""Attention paths: direct == chunked == banded; ring-cache decode ==
teacher forcing; sliding windows; prefix-LM masks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.attention import (_banded_attention, _chunked_attention,
                                    _direct_attention, attention_forward,
                                    decode_attention, init_attention,
                                    init_attn_cache)


def _cfg(**kw):
    base = dict(name="t", arch_type="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    base.update(kw)
    return ModelConfig(**base).validate()


def _qkv(seed, b=2, s=64, h=4, kv=2, d=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, s, h, d)),
            jax.random.normal(ks[1], (b, s, kv, d)),
            jax.random.normal(ks[2], (b, s, kv, d)))


@pytest.mark.parametrize("window,prefix", [(0, 0), (16, 0), (0, 8)])
def test_direct_vs_chunked(window, prefix):
    q, k, v = _qkv(0)
    pos = jnp.arange(64)
    o1 = _direct_attention(q, k, v, pos, pos, causal=True, window=window,
                           prefix_len=prefix, scale=0.25)
    o2 = _chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                            prefix_len=prefix, scale=0.25, q_chunk=16,
                            kv_chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_banded_matches_masked_window():
    q, k, v = _qkv(1, s=128)
    pos = jnp.arange(128)
    o1 = _direct_attention(q, k, v, pos, pos, causal=True, window=32,
                           prefix_len=0, scale=0.25)
    o2 = _banded_attention(q, k, v, pos, pos, window=32, scale=0.25,
                           q_chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_window_ring_cache_decode():
    """Decode through a ring cache (window < total length) matches the
    teacher-forced banded forward at every position."""
    cfg = _cfg(sliding_window=16)
    rng = jax.random.PRNGKey(2)
    params = init_attention(rng, cfg)
    s_total = 48
    x = jax.random.normal(jax.random.PRNGKey(3), (1, s_total, cfg.d_model))
    full, _ = attention_forward(params, cfg, x, window=16)

    cache = init_attn_cache(cfg, 1, s_total, window=16)
    outs = []
    for t in range(s_total):
        o, cache = decode_attention(params, cfg, x[:, t:t + 1], cache,
                                    jnp.asarray(t, jnp.int32), window=16)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=2e-4)


def test_prefill_then_decode_full_cache():
    cfg = _cfg()
    params = init_attention(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 20, cfg.d_model))
    full, _ = attention_forward(params, cfg, x)
    cache = init_attn_cache(cfg, 2, 32)
    _, cache = attention_forward(params, cfg, x[:, :19], cache=cache)
    o, cache = decode_attention(params, cfg, x[:, 19:20], cache,
                                jnp.asarray(19, jnp.int32))
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-4)


def test_cross_attention_no_mask():
    cfg = _cfg(qkv_bias=True)
    params = init_attention(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 8, cfg.d_model))
    enc = jax.random.normal(jax.random.PRNGKey(8), (2, 24, cfg.d_model))
    o, _ = attention_forward(params, cfg, x, enc_out=enc, causal=False,
                             use_rope=False)
    assert o.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(o)))
