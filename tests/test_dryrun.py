"""Dry-run integration: one real lower+compile on the production mesh via a
subprocess (XLA_FLAGS must be set before jax import, so in-process is not
an option).  Uses the cheapest (arch, shape) combo to stay fast."""
import json
import os
import subprocess
import sys

import pytest

# end-to-end subprocess compile: slow lane (pytest -m "not slow" skips it)
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.timeout(600)
def test_bench_spec_k_sweep(tmp_path):
    """``throughput_bench --spec-k`` end to end: the drafting sweep runs,
    ``--check`` holds (k-token drafts cut the virtual makespan at 8 slots
    on the high-RTT link), and the ``--json`` rows carry the acceptance
    rate."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(REPO, "src"), REPO])
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out_json = tmp_path / "spec.json"
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks",
                                      "throughput_bench.py"),
         "--spec-k", "4", "--check", "--clients", "8", "--max-new", "12",
         "--json", str(out_json)],
        env=env, capture_output=True, text=True, timeout=590)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rows = {r["spec_k"]: r for r in json.loads(out_json.read_text())}
    assert set(rows) == {1, 4}
    assert rows[4]["virtual_s"] < rows[1]["virtual_s"]
    assert rows[4]["requests"] < rows[1]["requests"]
    for r in rows.values():
        assert r["tokens_equal"]
        assert 0.0 < r["accept_rate"] <= 1.0
        assert 0.0 <= r["mean_accept_len"] <= r["spec_k"]


@pytest.mark.timeout(120)
def test_serve_spec_k_needs_speculative():
    """The launcher rejects --spec-k without --speculative instead of
    silently ignoring the draft length."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--spec-k", "4"],
        env=env, capture_output=True, text=True, timeout=110)
    assert out.returncode != 0
    assert "--spec-k needs --speculative" in out.stderr


@pytest.mark.timeout(600)
def test_dryrun_single_combo(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-350m",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=590)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    path = tmp_path / "xlstm-350m_decode_32k_16x16.json"
    rec = json.loads(path.read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["cost_analysis"]["flops"] > 0
    ma = rec["memory_analysis"]
    per_dev = ma["argument_size_in_bytes"] + ma["temp_size_in_bytes"]
    assert per_dev < 16 << 30       # fits v5e HBM
