"""Dry-run integration: one real lower+compile on the production mesh via a
subprocess (XLA_FLAGS must be set before jax import, so in-process is not
an option).  Uses the cheapest (arch, shape) combo to stay fast."""
import json
import os
import subprocess
import sys

import pytest

# end-to-end subprocess compile: slow lane (pytest -m "not slow" skips it)
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.timeout(600)
def test_dryrun_single_combo(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-350m",
         "--shape", "decode_32k", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=590)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    path = tmp_path / "xlstm-350m_decode_32k_16x16.json"
    rec = json.loads(path.read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["cost_analysis"]["flops"] > 0
    ma = rec["memory_analysis"]
    per_dev = ma["argument_size_in_bytes"] + ma["temp_size_in_bytes"]
    assert per_dev < 16 << 30       # fits v5e HBM
