"""Host serving engine: multi-client co-inference vs cloud baseline."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.collm import CollmConfig
from repro.serving.engine import ServingSystem, token_agreement


def test_agreement_theta1(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = [data.sample_tokens(10) for _ in range(2)]
    sys1 = ServingSystem(model, params,
                         CollmConfig(theta=1.0, wire_format="float32"))
    rc = sys1.generate(prompts, 15, mode="collm")
    rb = sys1.generate(prompts, 15, mode="cloud")
    for a, b in zip(rc["tokens"], rb["tokens"]):
        assert token_agreement(a, b) == 1.0
    assert rc["stats"].request_rate == 1.0


def test_request_rate_monotone_in_theta(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = [data.sample_tokens(10) for _ in range(2)]
    rates = []
    for theta in (0.5, 0.9, 1.0):
        s = ServingSystem(model, params, CollmConfig(theta=theta))
        r = s.generate(prompts, 15, mode="collm")
        rates.append(r["stats"].request_rate)
    assert rates[0] <= rates[1] <= rates[2] == 1.0


def test_standalone_no_cloud(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = [data.sample_tokens(10)]
    s = ServingSystem(model, params, CollmConfig(theta=0.8))
    r = s.generate(prompts, 10, mode="standalone")
    assert r["stats"].cloud_requests == 0
    assert len(r["tokens"][0]) == 10


def test_backfill_not_worse(tiny_trained):
    """Beyond-paper exact-KV backfill: agreement with the undivided model is
    at least as good as the paper's release-mode at the same theta."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = [data.sample_tokens(10) for _ in range(3)]
    base = ServingSystem(model, params, CollmConfig(theta=1.0)).generate(
        prompts, 15, mode="cloud")
    rel = ServingSystem(model, params, CollmConfig(theta=0.6)).generate(
        prompts, 15, mode="collm")
    bf = ServingSystem(model, params,
                       CollmConfig(theta=0.6, backfill=True)).generate(
        prompts, 15, mode="collm")
    ag_rel = np.mean([token_agreement(a, b) for a, b in
                      zip(rel["tokens"], base["tokens"])])
    ag_bf = np.mean([token_agreement(a, b) for a, b in
                     zip(bf["tokens"], base["tokens"])])
    assert ag_bf >= ag_rel - 0.05


def test_content_manager_stats_flow(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    s = ServingSystem(model, params, CollmConfig(theta=0.8))
    r = s.generate([data.sample_tokens(8)], 12, mode="collm")
    cm = r["cm_stats"]["edge-0"]
    assert cm["uploads_received"] == 11     # one per generated step
    assert r["stats"].upload_bytes > 0
