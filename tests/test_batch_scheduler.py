"""Continuous-batching engine: equivalence with the sequential per-client
path, slot refill, EOS handling, sampler wiring, and content-manager
invariants the scheduler relies on."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.collm import CollmConfig
from repro.core.content_manager import ContentManager
from repro.core.transport import StatePacket
from repro.serving.engine import ServingSystem


def _prompts(data, lens):
    return [data.sample_tokens(n) for n in lens]


# ---------------------------------------------------------------------------
# batched vs sequential equivalence (the tentpole's correctness contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("theta", [0.8, 1.0])
def test_batched_equals_sequential_collm(tiny_trained, theta):
    """Greedy continuous batching must emit token-for-token identical
    streams to the seed per-client loop — more requests than slots, mixed
    prompt lengths, so refill and per-row positions are exercised."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8, 11, 9, 12, 10])
    ccfg = CollmConfig(theta=theta)
    seq = ServingSystem(model, params, ccfg).generate_sequential(
        prompts, 14, mode="collm")
    bat = ServingSystem(model, params, ccfg).generate(
        prompts, 14, mode="collm", num_slots=3)
    assert bat["tokens"] == seq["tokens"]
    ss, bs = seq["stats"], bat["stats"]
    assert (ss.cloud_requests, ss.exits_l1, ss.exits_l2) == \
        (bs.cloud_requests, bs.exits_l1, bs.exits_l2)
    assert ss.upload_bytes == bs.upload_bytes


@pytest.mark.parametrize("mode", ["standalone", "cloud"])
def test_batched_equals_sequential_other_modes(tiny_trained, mode):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 8, 12])
    ccfg = CollmConfig(theta=0.8)
    seq = ServingSystem(model, params, ccfg).generate_sequential(
        prompts, 10, mode=mode)
    bat = ServingSystem(model, params, ccfg).generate(
        prompts, 10, mode=mode, num_slots=2)
    assert bat["tokens"] == seq["tokens"]


def test_batched_backfill_equals_sequential(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 9, 11])
    ccfg = CollmConfig(theta=0.8, backfill=True)
    seq = ServingSystem(model, params, ccfg).generate_sequential(
        prompts, 12, mode="collm")
    bat = ServingSystem(model, params, ccfg).generate(
        prompts, 12, mode="collm", num_slots=2)
    assert bat["tokens"] == seq["tokens"]


def test_eos_frees_slot_for_refill(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 10, 10])
    s = ServingSystem(model, params, CollmConfig(theta=0.8))
    base = s.generate(prompts, 12, mode="collm", num_slots=1)
    eos = base["tokens"][0][2]
    cut = ServingSystem(model, params, CollmConfig(theta=0.8)).generate(
        prompts, 12, mode="collm", num_slots=1, eos_id=eos)
    # stream 0 stops at the first eos occurrence; later requests still served
    first_eos = base["tokens"][0].index(eos)
    assert cut["tokens"][0] == base["tokens"][0][:first_eos + 1]
    assert all(len(t) >= 1 for t in cut["tokens"])


def test_temperature_sampler_wired(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 10])
    s = ServingSystem(model, params, CollmConfig(theta=0.8))
    r1 = s.generate(prompts, 10, mode="collm", num_slots=2,
                    sampler="temperature", temperature=1.0, top_k=0, seed=1)
    r2 = s.generate(prompts, 10, mode="collm", num_slots=2,
                    sampler="temperature", temperature=1.0, top_k=0, seed=2)
    assert all(len(t) == 10 for t in r1["tokens"])
    # different seeds should diverge somewhere on a 256-vocab model
    assert r1["tokens"] != r2["tokens"]


def test_batched_cm_accounting(tiny_trained):
    """Per-client upload accounting survives batching: one upload per
    decode step per client, cleared at end of sequence."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    s = ServingSystem(model, params, CollmConfig(theta=0.8))
    r = s.generate(_prompts(data, [8, 8]), 12, mode="collm", num_slots=2)
    for dev in ("edge-0", "edge-1"):
        cm = r["cm_stats"][dev]
        assert cm["uploads_received"] == 11
        assert cm["pending"] == 0
    assert r["stats"].upload_bytes > 0


# ---------------------------------------------------------------------------
# content manager invariants (stale invalidation / overflow release)
# ---------------------------------------------------------------------------
def _pkt(pos=0):
    return StatePacket(hidden={"data": np.ones((1, 1, 8), np.float16)},
                       pos=pos)


def test_take_upload_invalidates_stale():
    cm = ContentManager(max_pending_per_client=8)
    for p in range(5):
        cm.upload("dev", p, _pkt(p))
    cm.take_upload("dev", 3)
    st = cm.stats()["dev"]
    # positions 0..2 are stale once pos 3 is served; only pos 4 survives
    assert st["uploads_consumed"] == 1
    assert st["uploads_released"] == 3
    assert st["pending"] == 1
    assert cm.has_upload("dev", 4)
    assert not cm.has_upload("dev", 2)


def test_upload_overflow_releases_oldest():
    cm = ContentManager(max_pending_per_client=2)
    for p in range(5):
        cm.upload("dev", p, _pkt(p))
    st = cm.stats()["dev"]
    assert st["pending"] == 2
    assert st["uploads_released"] == 3
    assert cm.has_upload("dev", 3) and cm.has_upload("dev", 4)
    assert not cm.has_upload("dev", 0)


def test_batched_take_matches_sequential_take():
    cm = ContentManager(max_pending_per_client=8)
    items = []
    for dev in ("a", "b"):
        for p in range(3):
            items.append((dev, p, _pkt(p)))
    cm.upload_batch(items)
    pkts = cm.take_upload_batch([("a", 2), ("b", 1)])
    assert [int(np.asarray(p.pos)) for p in pkts] == [2, 1]
    # client a: 0,1 stale-released; client b: 0 released, 2 still pending
    assert cm.stats()["a"]["pending"] == 0
    assert cm.stats()["b"]["pending"] == 1
    rings = cm.take_uploads_upto_batch([("b", 2)])
    assert [p for p, _ in rings[0]] == [2]
