"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model<=512, <=4 experts) runs one forward and
one train step on CPU with correct shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ASSIGNED, get_smoke_config
from repro.models.registry import build_model
from repro.training.optim import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step


def _batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (b, s), 0, cfg.vocab_size),
             "mask": jnp.ones((b, s), jnp.float32)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            rng, (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.vision_tokens:
        batch["patches"] = jax.random.normal(
            rng, (b, cfg.vision_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)

    out = model.forward_train(params, batch)
    want_s = s + (cfg.vision_tokens or 0)
    assert out["logits"].shape == (b, want_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out["logits"])))
    for l, xl in out["exit_logits"].items():
        assert xl.shape == (b, want_s, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(xl)))

    step = make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=2,
                                              total_steps=10))
    opt = init_adamw(params)
    params2, opt2, mets = step(params, opt, batch)
    assert bool(jnp.isfinite(mets["loss"]))
    assert bool(jnp.isfinite(mets["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    b, s = 2, 12
    batch = _batch(cfg, rng, b, s)
    batch.pop("labels"), batch.pop("mask")
    out = model.forward_train(params, batch)
    ref = out["logits"][:, -1]

    caches = model.init_cache(b, 64)
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :-1]
    _, _, caches, _ = model.prefill(params, pb, caches)
    pos = jnp.asarray((cfg.vision_tokens or 0) + s - 1, jnp.int32)
    xh, _, _ = model.decode_step(params, batch["tokens"][:, -1:], caches, pos)
    got = model.logits(params, xh)[:, 0]
    assert float(jnp.max(jnp.abs(got - ref))) < 2e-4
