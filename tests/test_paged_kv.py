"""Block-paged KV cache: dense-vs-paged equivalence through the batched
engine, the lifted per-slot context bound, page-reuse safety (no stale K/V
leaks), out-of-pages admission back-pressure, and PagePool accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.collm import CoLLM, CollmConfig
from repro.core.paging import PagePool, pages_needed
from repro.models.attention import (init_paged_attn_cache, paged_gather,
                                    paged_reset_pages, paged_scatter_prefill)
from repro.serving.engine import ServingSystem


def _prompts(data, lens):
    return [data.sample_tokens(n) for n in lens]


def _systems(model, params, **ccfg_kw):
    dense = ServingSystem(model, params, CollmConfig(**ccfg_kw))
    paged = ServingSystem(model, params,
                          CollmConfig(kv_layout="paged", **ccfg_kw))
    return dense, paged


# ---------------------------------------------------------------------------
# dense vs paged equivalence (the tentpole's correctness contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("theta", [0.8, 1.0])
def test_paged_equals_dense_collm(tiny_trained, theta):
    """Greedy decode must be token-for-token identical across KV layouts —
    more requests than slots, mixed prompt lengths, so slot retirement
    frees pages that later admissions reuse."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8, 11, 9, 12, 10])
    dense, paged = _systems(model, params, theta=theta)
    d = dense.generate(prompts, 14, mode="collm", num_slots=3)
    p = paged.generate(prompts, 14, mode="collm", num_slots=3)
    assert p["tokens"] == d["tokens"]
    ds, ps = d["stats"], p["stats"]
    assert (ds.cloud_requests, ds.exits_l1, ds.exits_l2) == \
        (ps.cloud_requests, ps.exits_l1, ps.exits_l2)


@pytest.mark.parametrize("mode", ["standalone", "cloud"])
def test_paged_equals_dense_other_modes(tiny_trained, mode):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 8, 12])
    dense, paged = _systems(model, params, theta=0.8)
    d = dense.generate(prompts, 10, mode=mode, num_slots=2)
    p = paged.generate(prompts, 10, mode=mode, num_slots=2)
    assert p["tokens"] == d["tokens"]


def test_paged_equals_dense_hybrid_arch():
    """Hybrid (zamba2-style) smoke model: paged attention nodes coexist
    with dense recurrent state in one cache tree — exercises the
    mixed-node merge in ``CoLLM._caches_where_rows`` (recurrent leaves
    still where-merged per row, paged nodes passed through) and the
    exact-length (non-bucketed) prefill scatter path."""
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.models.registry import build_model

    cfg = reduced(get_config("zamba2-1.2b"), n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n) for n in (9, 12, 8)]
    dense, paged = _systems(model, params, theta=0.8)
    d = dense.generate(prompts, 12, mode="collm", num_slots=2)
    p = paged.generate(prompts, 12, mode="collm", num_slots=2)
    assert p["tokens"] == d["tokens"]


def test_paged_backfill_equals_dense(tiny_trained):
    """Backfill rings drain straight into pages (exact cloud KV)."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 9, 11])
    dense, paged = _systems(model, params, theta=0.8, backfill=True)
    d = dense.generate(prompts, 12, mode="collm", num_slots=2)
    p = paged.generate(prompts, 12, mode="collm", num_slots=2)
    assert p["tokens"] == d["tokens"]


# ---------------------------------------------------------------------------
# the unlock: per-slot context beyond the old max_seq ring
# ---------------------------------------------------------------------------
def test_long_stream_exceeds_old_slot_bound(tiny_trained):
    """A 16-slot paged pool holding 32 pages x 16 tokens — exactly the
    memory of 16 dense max_seq=32 rings — serves one stream whose context
    (48 + 24 = 72) exceeds that old per-slot bound, emitting the same
    tokens as a dense engine that pays for max_seq=128 rings."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = [data.sample_tokens(48)] + _prompts(data, [8] * 5)
    dense, paged = _systems(model, params, theta=0.8)
    d = dense.generate(prompts, 24, mode="collm", num_slots=16, max_seq=128)
    p = paged.generate(prompts, 24, mode="collm", num_slots=16, max_seq=32,
                       max_ctx=128, num_pages=32)
    assert p["tokens"] == d["tokens"]
    dsched = next(iter(dense._schedulers.values()))
    psched = next(iter(paged._schedulers.values()))
    # pool memory is num_pages x page_size, not B x max_ctx
    assert psched.kv_cache_bytes() < dsched.kv_cache_bytes()
    assert psched.pool.stats.high_water <= psched.pool.num_pages
    assert psched.pool.free_pages == psched.pool.num_pages   # all retired


# ---------------------------------------------------------------------------
# page reuse never leaks stale K/V
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_page_reuse_no_stale_leak_property(seed, tiny_ee_cfg):
    """Free + reallocate a retired stream's pages: the new stream's gather
    must see only its own positions (everything else pos = -1), so stream
    A's K/V can never appear in stream B's attention window."""
    rng = np.random.RandomState(seed)
    ps, num_pages, n_lp = 8, 6, 3
    pool = PagePool(num_pages, ps, 2, n_lp)
    cache = init_paged_attn_cache(tiny_ee_cfg, num_pages, ps)

    len_a = int(rng.randint(ps + 1, n_lp * ps))      # stream A spans pages
    pages_a = [pool.alloc(0, lp) for lp in range(pages_needed(len_a, ps))]
    kvh, hd = tiny_ee_cfg.n_kv_heads, tiny_ee_cfg.resolved_head_dim
    row = {
        "k": jnp.asarray(rng.randn(1, len_a, kvh, hd), jnp.float32),
        "v": jnp.asarray(rng.randn(1, len_a, kvh, hd), jnp.float32),
        "pos": jnp.arange(len_a, dtype=jnp.int32)[None],
    }
    cache = paged_scatter_prefill(cache, row, jnp.asarray(pages_a))

    freed = pool.free_slot(0)
    assert sorted(freed) == sorted(pages_a)
    cache = paged_reset_pages(cache, jnp.asarray(freed))

    len_b = int(rng.randint(1, len_a))               # B shorter than A
    pages_b = [pool.alloc(1, lp) for lp in range(pages_needed(len_b, ps))]
    assert set(pages_b) <= set(freed)                # genuinely reused
    row_b = {
        "k": jnp.asarray(rng.randn(1, len_b, kvh, hd), jnp.float32),
        "v": jnp.asarray(rng.randn(1, len_b, kvh, hd), jnp.float32),
        "pos": jnp.arange(len_b, dtype=jnp.int32)[None],
    }
    cache = paged_scatter_prefill(cache, row_b, jnp.asarray(pages_b))

    tbl = jnp.asarray(pool.block_table[1:2])
    k, v, kpos = paged_gather(cache, tbl)
    kpos = np.asarray(kpos[0])
    valid = kpos >= 0
    # every visible entry belongs to stream B; stream A's longer tail
    # (positions len_b..len_a-1) must be gone
    assert valid.sum() == len_b
    assert np.array_equal(np.sort(kpos[valid]), np.arange(len_b))
    np.testing.assert_array_equal(
        np.asarray(k[0])[valid], np.asarray(row_b["k"][0]))


def test_page_reuse_engine_deterministic(tiny_trained):
    """Re-running the same requests through one scheduler reuses the freed
    pages of the first run; outputs must be identical both times."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [9, 12, 8, 10])
    paged = ServingSystem(model, params,
                          CollmConfig(theta=0.8, kv_layout="paged"))
    r1 = paged.generate(prompts, 12, mode="collm", num_slots=2)
    r2 = paged.generate(prompts, 12, mode="collm", num_slots=2)
    assert r1["tokens"] == r2["tokens"]


# ---------------------------------------------------------------------------
# out-of-pages admission back-pressure
# ---------------------------------------------------------------------------
def test_out_of_pages_backpressure(tiny_trained):
    """A pool far smaller than the request load must delay admissions (not
    crash, not corrupt): every stream completes with the dense tokens and
    the pool never oversubscribes."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8] * 6)
    dense, paged = _systems(model, params, theta=0.8)
    d = dense.generate(prompts, 24, mode="collm", num_slots=4, max_seq=40)
    # 4 pages x 16 tokens: one stream needs 2 pages -> at most 2 in flight
    p = paged.generate(prompts, 24, mode="collm", num_slots=4, max_seq=40,
                       num_pages=4)
    assert p["tokens"] == d["tokens"]
    sched = next(iter(paged._schedulers.values()))
    assert sched.pool.stats.high_water <= 4
    assert sched.pool.free_pages == 4


def test_impossible_request_raises(tiny_trained):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    paged = ServingSystem(model, params,
                          CollmConfig(theta=0.8, kv_layout="paged"))
    with pytest.raises(ValueError, match="pages"):
        # needs more pages than the whole pool ever has
        paged.generate(_prompts(data, [8]), 60, mode="collm", num_slots=2,
                       max_seq=16, max_ctx=80, num_pages=2)


# ---------------------------------------------------------------------------
# fused single-graph step on the paged layout
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("theta", [0.8, 1.0])
def test_fused_step_paged_matches_dense(tiny_trained, theta):
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    b, max_seq, steps = 2, 32, 6
    tok0 = jnp.asarray(np.stack([data.sample_tokens(1) for _ in range(b)]))
    outs = {}
    for layout in ("dense", "paged"):
        ccfg = CollmConfig(theta=theta, backfill=True, kv_layout=layout)
        collm = CoLLM(tiny_trained["model"], ccfg)
        state = collm.init_fused_state(b, max_seq)
        step = jax.jit(collm.fused_step)
        tok, toks = tok0, []
        for i in range(steps):
            nxt, _, state = step(params, tok, state, jnp.asarray(i))
            toks.append(np.asarray(nxt))
            tok = nxt[:, None].astype(jnp.int32)
        outs[layout] = np.stack(toks)
    np.testing.assert_array_equal(outs["dense"], outs["paged"])


# ---------------------------------------------------------------------------
# PagePool accounting
# ---------------------------------------------------------------------------
def test_page_pool_accounting():
    from repro.core.paging import OutOfPages
    pool = PagePool(6, 4, 2, 8)
    assert pool.can_admit(24) and not pool.can_admit(25)
    p0 = pool.alloc(0, 0)
    assert p0 != 0                                   # trash page never handed out
    assert pool.alloc(0, 0) == p0                    # idempotent re-map
    assert pool.free_pages == 5 and pool.owned_pages(0) == 1
    for lp in range(1, 6):
        pool.alloc(0, lp)
    assert pool.free_pages == 0 and not pool.can_admit(1)
    with pytest.raises(OutOfPages):
        pool.alloc(1, 0)                             # empty free list
    freed = pool.free_slot(0)
    assert len(freed) == 6 and pool.free_pages == 6
    assert np.all(pool.block_table[0] == -1)


def test_page_pool_watermark():
    """The watermark holds pages back from admission but never from
    alloc-on-write."""
    pool = PagePool(6, 4, 2, 8, watermark=2)
    assert pool.available_pages == 4
    assert pool.can_admit(16) and not pool.can_admit(17)
    for lp in range(6):                              # decode ignores watermark
        pool.alloc(0, lp)
    assert pool.free_pages == 0


# ---------------------------------------------------------------------------
# int8 quantized pages (kv_dtype="int8")
# ---------------------------------------------------------------------------
def test_int8_cache_layout_and_roundtrip(tiny_ee_cfg):
    """int8 pools carry per-row fp32 scales next to the pages; the
    prefill-scatter -> gather round trip dequantizes to within the per-row
    absmax bound (|err| <= scale/2)."""
    rng = np.random.RandomState(0)
    ps, num_pages = 8, 6
    pool = PagePool(num_pages, ps, 2, 3)
    cache = init_paged_attn_cache(tiny_ee_cfg, num_pages, ps,
                                  kv_dtype="int8")
    assert cache["kp"].dtype == jnp.int8 and cache["vp"].dtype == jnp.int8
    kvh, hd = tiny_ee_cfg.n_kv_heads, tiny_ee_cfg.resolved_head_dim
    assert cache["ks"].shape == (num_pages + 1, ps, kvh)
    assert cache["vs"].dtype == jnp.float32

    n = 19
    pages = [pool.alloc(0, lp) for lp in range(pages_needed(n, ps))]
    row = {
        "k": jnp.asarray(rng.randn(1, n, kvh, hd) * 3, jnp.float32),
        "v": jnp.asarray(rng.randn(1, n, kvh, hd) * 3, jnp.float32),
        "pos": jnp.arange(n, dtype=jnp.int32)[None],
    }
    cache = paged_scatter_prefill(cache, row, jnp.asarray(pages))
    tbl = jnp.asarray(pool.block_table[0:1])
    k, v, kpos = paged_gather(cache, tbl)
    valid = np.asarray(kpos[0]) >= 0
    assert valid.sum() == n
    k_got = np.asarray(k[0])[valid]
    k_want = np.asarray(row["k"][0])
    bound = np.abs(k_want).max(axis=-1, keepdims=True) / 127.0 * 0.5 + 1e-6
    assert np.all(np.abs(k_got - k_want) <= bound)


def test_int8_requires_paged_layout(tiny_trained):
    model, params = tiny_trained["model"], tiny_trained["params"]
    with pytest.raises(ValueError, match="paged"):
        CoLLM(model, CollmConfig(kv_dtype="int8"))        # dense ring
    with pytest.raises(ValueError, match="kv_dtype"):
        CoLLM(model, CollmConfig(kv_dtype="int4", kv_layout="paged"))


def test_int8_engine_bounded_exit_drift(tiny_trained):
    """int8 paged serving completes every stream and its exit-tier mix
    stays near the float32 run (the docs/kv_paging.md accuracy gate: int8
    perturbs logits near theta, it must not change WHICH tier answers by
    much).  Also asserts the int8 pool genuinely shrinks device bytes."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8, 11, 9, 12, 10])
    max_new = 14
    runs = {}
    for dt in ("float32", "int8"):
        sysd = ServingSystem(model, params,
                             CollmConfig(theta=0.8, kv_layout="paged",
                                         kv_dtype=dt))
        runs[dt] = (sysd.generate(prompts, max_new, mode="collm",
                                  num_slots=3),
                    next(iter(sysd._schedulers.values())))
    r32, s32 = runs["float32"]
    r8, s8 = runs["int8"]
    assert all(len(t) == max_new for t in r8["tokens"])
    total = len(prompts) * max_new
    rate = lambda r: (r["stats"].exits_l1 + r["stats"].exits_l2) / total
    assert abs(rate(r8) - rate(r32)) <= 0.15
    # attention pages dominate the tiny model's pool: int8 data + fp32
    # scales cut it well below the float32 pool
    assert s8.kv_cache_bytes() < 0.5 * s32.kv_cache_bytes()


def test_int8_engine_deterministic(tiny_trained):
    """Same requests, same int8 pool, twice -> identical streams (the
    quantize-on-write path is deterministic and page reuse resets scales
    along with data)."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [9, 12, 8, 10])
    sysd = ServingSystem(model, params,
                         CollmConfig(theta=0.8, kv_layout="paged",
                                     kv_dtype="int8"))
    r1 = sysd.generate(prompts, 12, mode="collm", num_slots=2)
    r2 = sysd.generate(prompts, 12, mode="collm", num_slots=2)
    assert r1["tokens"] == r2["tokens"]
