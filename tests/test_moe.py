"""MoE invariants: shard_map dispatch == local dispatch == decode gather
(at no-drop capacity); drop behaviour bounded; router normalization."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.launch.mesh import make_debug_mesh
from repro.launch.sharding import ShardingPolicy, use_policy
from repro.models.moe import (_moe_forward_local, _moe_forward_shardmap,
                              init_moe, moe_forward, moe_forward_decode)


def _cfg(cf=8.0, e=4, k=2):
    return ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                       moe=MoEConfig(num_experts=e, top_k=k, expert_d_ff=96,
                                     capacity_factor=cf)).validate()


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    return cfg, params, x


def test_shardmap_matches_local(setup):
    cfg, params, x = setup
    mesh = make_debug_mesh(1)
    policy = ShardingPolicy(mesh, batch=2, seq_parallel=False)
    out_l, aux_l = _moe_forward_local(params, cfg, x)
    with use_policy(policy):
        out_s, aux_s = _moe_forward_shardmap(params, cfg, x, policy)
    np.testing.assert_allclose(np.asarray(out_l), np.asarray(out_s),
                               atol=2e-5)
    assert abs(float(aux_l) - float(aux_s)) < 1e-5


def test_forward_matches_decode_at_no_drop(setup):
    cfg, params, x = setup
    out_f, _ = _moe_forward_local(params, cfg, x)
    out_d = jnp.concatenate(
        [moe_forward_decode(params, cfg, x[:, t:t + 1])
         for t in range(x.shape[1])], axis=1)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                               atol=2e-5)


def test_dispatch_path_selection(setup):
    cfg, params, x = setup
    # no policy active -> local path (identical results by definition)
    out1, _ = moe_forward(params, cfg, x)
    out2, _ = _moe_forward_local(params, cfg, x)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_drops_bounded_at_tight_capacity():
    cfg = _cfg(cf=0.5)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    out, aux = _moe_forward_local(params, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    # dropped tokens produce zero update, so norm is below no-drop norm
    cfg2 = _cfg(cf=8.0)
    out2, _ = _moe_forward_local(params, cfg2, x)
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(out2)) + 1e-4


def test_aux_loss_balanced_router_lower():
    """Property: a perfectly balanced router has aux ~= coef (its minimum)."""
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(3), cfg)
    # force balanced routing with uniform router weights
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, 64))
    _, aux_uniform = _moe_forward_local(params, cfg, x)
    params["router"] = jnp.ones_like(params["router"]) * 5.0  # degenerate
    _, aux_skew = _moe_forward_local(params, cfg, x)
    assert float(aux_uniform) <= float(aux_skew) + 1e-6
