"""GLA core invariants: chunked == recurrent == step-chain, both gate
families (mLSTM exponential-gate stabilized; Mamba2 bounded gates)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.gla import (chunked_gla, gla_decode_step, init_gla_state,
                              recurrent_gla)


def _inputs(seed, b=2, h=2, s=32, dk=8, dv=4, mlstm=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, h, s, dk))
    k = jax.random.normal(ks[1], (b, h, s, dk))
    v = jax.random.normal(ks[2], (b, h, s, dv))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (b, h, s)) + 1.0)
    li = jax.random.normal(ks[4], (b, h, s)) * (3.0 if mlstm else 1.0)
    if not mlstm:
        li = jnp.minimum(li, 0.0)
    return q, k, v, lf, li


@pytest.mark.parametrize("normalize", [True, False])
@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_equals_recurrent(normalize, chunk):
    q, k, v, lf, li = _inputs(0, mlstm=normalize)
    y1, s1 = recurrent_gla(q, k, v, lf, li, normalize=normalize)
    y2, s2 = chunked_gla(q, k, v, lf, li, normalize=normalize, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s1["S"]), np.asarray(s2["S"]),
                               atol=5e-4)


@pytest.mark.parametrize("normalize", [True, False])
def test_decode_chain_equals_recurrent(normalize):
    q, k, v, lf, li = _inputs(1, mlstm=normalize)
    st = init_gla_state(2, 2, 8, 4)
    ys = []
    for t in range(q.shape[2]):
        y, st = gla_decode_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                lf[:, :, t], li[:, :, t], st,
                                normalize=normalize)
        ys.append(y)
    yd = jnp.stack(ys, axis=2)
    y1, s1 = recurrent_gla(q, k, v, lf, li, normalize=normalize)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(y1), atol=5e-4)
    np.testing.assert_allclose(np.asarray(st["S"]), np.asarray(s1["S"]),
                               atol=5e-4)


def test_streaming_state_continuation():
    """Running two halves with carried state == running the whole sequence
    (this is exactly what the edge->cloud SSM state upload relies on)."""
    q, k, v, lf, li = _inputs(2, s=32)
    y_full, s_full = chunked_gla(q, k, v, lf, li, normalize=True, chunk=8)
    y_a, s_a = chunked_gla(q[:, :, :16], k[:, :, :16], v[:, :, :16],
                           lf[:, :, :16], li[:, :, :16], normalize=True,
                           chunk=8)
    y_b, s_b = chunked_gla(q[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
                           lf[:, :, 16:], li[:, :, 16:], normalize=True,
                           chunk=8, state=s_a)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 2)),
                               np.asarray(y_full), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s_b["S"]), np.asarray(s_full["S"]),
                               atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), chunk=st.sampled_from([4, 8, 16]),
       normalize=st.booleans())
def test_gla_property_chunk_invariance(seed, chunk, normalize):
    q, k, v, lf, li = _inputs(seed, s=16, mlstm=normalize)
    y1, _ = chunked_gla(q, k, v, lf, li, normalize=normalize, chunk=chunk)
    y2, _ = chunked_gla(q, k, v, lf, li, normalize=normalize, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3)


def test_mlstm_no_nan_extreme_gates():
    """Stabilizer keeps exponential input gates finite."""
    q, k, v, lf, li = _inputs(3)
    li = li * 20.0   # huge input gates
    y, s = chunked_gla(q, k, v, lf, li, normalize=True, chunk=8)
    assert bool(jnp.all(jnp.isfinite(y)))
    y2, _ = recurrent_gla(q, k, v, lf, li, normalize=True)
    # gates at 20x scale: the normalizer cancels the huge exponents, but
    # fusion order differs between forms — allow a few ulps more
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=5e-3)
