import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig


def pytest_collection_modifyitems(config, items):
    """Split the suite into lanes: anything that trains the shared tiny
    model (the ``tiny_trained`` session fixture) is ``slow`` — the fast CI
    lane (``pytest -m "not slow"``) runs the rest in minutes.  Explicit
    ``@pytest.mark.slow`` marks still apply to tests that are heavy
    without the fixture (see README §Tests)."""
    for item in items:
        if "tiny_trained" in getattr(item, "fixturenames", ()):
            item.add_marker(pytest.mark.slow)


@pytest.fixture(scope="session")
def tiny_ee_cfg() -> ModelConfig:
    return ModelConfig(name="tiny-ee", arch_type="dense", n_layers=4,
                       d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                       vocab_size=256, tie_embeddings=True,
                       exit_layers=(1, 2)).validate()


@pytest.fixture(scope="session")
def tiny_trained(tiny_ee_cfg):
    """A briefly-trained tiny EE model shared across serving tests."""
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.models.registry import build_model
    from repro.training.optim import AdamWConfig, init_adamw
    from repro.training.train_step import make_train_step

    model = build_model(tiny_ee_cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=300)
    opt = init_adamw(params)
    step = jax.jit(make_train_step(model, opt_cfg))
    data = SyntheticCorpus(DataConfig(vocab_size=256, seq_len=64,
                                      batch_size=8, kind="markov"))
    first = last = None
    for b in data.batches(80):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, mets = step(params, opt, batch)
        if first is None:
            first = float(mets["loss"])
        last = float(mets["loss"])
    return {"model": model, "params": params, "data": data,
            "first_loss": first, "last_loss": last}
