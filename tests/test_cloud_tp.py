"""Cloud tensor-parallel serving (docs/sharding.md).

Acceptance matrix for the mesh-aware execution layer: on a forced
8-host-device ``(data=2, model=4)`` mesh, sharded cloud steps must be
token-identical to the single-device path across {dense, paged} x
{f32, int8} x {spec_k 1, 4}, plus prefix sharing and preemption — and
N engines driving one CoLLM must never re-trace a step.

Run the multi-device tests with

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest -q tests/test_cloud_tp.py

(they skip on fewer than 8 devices; the single-device-default tests run
anywhere).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.collm import CollmConfig
from repro.launch import sharding as shardlib
from repro.models.registry import build_model
from repro.serving.engine import ServingSystem
from repro.serving.mesh_exec import mesh_context

CLOUD_MESH = (2, 4)

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def tp():
    # untrained tiny GQA model: 4 heads shard over model=4, 2 KV heads
    # exercise the head-aligned replication rule
    cfg = ModelConfig(name="tiny-ee-tp", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=256, tie_embeddings=True,
                      exit_layers=(1, 2)).validate()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=n).astype(np.int32)
               for n in (7, 12, 9)]
    return {"cfg": cfg, "model": model, "params": params,
            "prompts": prompts, "rng": rng}


def _system(tp, **ckw):
    return ServingSystem(tp["model"], tp["params"],
                         CollmConfig(theta=0.85, **ckw))


# ---------------------------------------------------------------------------
# token identity: sharded cloud steps == single device
# ---------------------------------------------------------------------------
@needs_mesh
@pytest.mark.parametrize("ckw", [
    {},                                                       # dense f32
    {"kv_layout": "paged"},                                   # paged f32
    {"kv_layout": "paged", "kv_dtype": "int8"},               # int8 pages
    {"speculative": True, "spec_k": 4},                       # drafts
    {"kv_layout": "paged", "kv_dtype": "int8",
     "speculative": True, "spec_k": 4},                       # everything
], ids=["dense", "paged", "int8", "spec4", "int8-spec4"])
def test_tp_generate_multi_token_identity(tp, ckw):
    r0 = _system(tp, **ckw).generate_multi(tp["prompts"], 8)
    r1 = _system(tp, cloud_mesh=CLOUD_MESH, **ckw).generate_multi(
        tp["prompts"], 8)
    assert r1["tokens"] == r0["tokens"]


@needs_mesh
def test_tp_prefix_share_token_identity(tp):
    ps = 8
    rng = np.random.default_rng(7)
    sysp = rng.integers(0, 256, size=2 * ps + ps // 2).astype(np.int32)
    prompts = [np.concatenate(
        [sysp, rng.integers(0, 256, size=n).astype(np.int32)])
        for n in (5, 7)]
    ckw = dict(kv_layout="paged", page_size=ps, chunked_prefill=True,
               prefix_share=True)
    r0 = _system(tp, **ckw).generate(prompts, 8)
    r1 = _system(tp, cloud_mesh=CLOUD_MESH, **ckw).generate(prompts, 8)
    assert r1["tokens"] == r0["tokens"]
    assert r1["stats"].prefix_hit_tokens > 0
    assert r1["stats"].prefix_hit_tokens == r0["stats"].prefix_hit_tokens


@needs_mesh
def test_tp_preemption_token_identity(tp):
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, 256, size=n).astype(np.int32) for n in (7, 9)]
    ckw = dict(kv_layout="paged", preemption="recompute")
    r0 = _system(tp, **ckw).generate(prompts, 8, num_slots=2,
                                     preempt_schedule=[(2, 0)])
    r1 = _system(tp, cloud_mesh=CLOUD_MESH, **ckw).generate(
        prompts, 8, num_slots=2, preempt_schedule=[(2, 0)])
    assert r1["tokens"] == r0["tokens"]
    assert r1["stats"].preemptions == 1


# ---------------------------------------------------------------------------
# trace discipline: one trace per step per CoLLM, stable across runs
# ---------------------------------------------------------------------------
@needs_mesh
def test_tp_no_retrace_across_runs(tp):
    sys_tp = _system(tp, cloud_mesh=CLOUD_MESH)
    r1 = sys_tp.generate_multi(tp["prompts"], 8)
    mc = mesh_context(sys_tp.collm)
    first = dict(mc.trace_counts)
    assert first.get("cloud_step_masked") == 1
    r2 = sys_tp.generate_multi(tp["prompts"], 8)
    assert dict(mc.trace_counts) == first    # second fleet: zero new traces
    assert r2["tokens"] == r1["tokens"]


# ---------------------------------------------------------------------------
# placement: per-device param bytes match the analytic estimate
# ---------------------------------------------------------------------------
@needs_mesh
def test_tp_param_bytes_shrink(tp):
    sys_tp = _system(tp, cloud_mesh=CLOUD_MESH)
    mc = mesh_context(sys_tp.collm)
    assert mc.active and dict(mc.mesh.shape) == {"data": CLOUD_MESH[0],
                                                 "model": CLOUD_MESH[1]}
    dev0 = mc.mesh.devices.flat[0]
    actual = sum(s.data.nbytes
                 for l in jax.tree.leaves(sys_tp.params)
                 for s in l.addressable_shards if s.device == dev0)
    est = shardlib.estimate_param_bytes_per_device(
        tp["model"].param_specs(), mc.mesh, fsdp=False,
        head_dim=tp["cfg"].resolved_head_dim)
    total = sum(l.size * l.dtype.itemsize
                for l in jax.tree.leaves(tp["params"]))
    assert actual == pytest.approx(est, rel=1e-6)
    # most weight is model-axis sharded (wk/wv + norms replicate)
    assert actual < 0.6 * total


# ---------------------------------------------------------------------------
# single-device default stays zero-cost; config validation fails loudly
# ---------------------------------------------------------------------------
def test_single_device_default_is_inert(tp):
    sys_ = _system(tp)
    mc = mesh_context(sys_.collm)
    assert not mc.active
    assert mc.policy is None
    assert sys_.params is tp["params"]       # no device_put, no copy


def test_cloud_mesh_too_many_devices_raises(tp):
    with pytest.raises(ValueError, match="device_count"):
        _system(tp, cloud_mesh=(64, 64))


def test_cloud_mesh_bad_shape_raises(tp):
    with pytest.raises(ValueError, match="pair"):
        _system(tp, cloud_mesh=(0, 4))
