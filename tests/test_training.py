"""Training substrate: loss decreases, optimizer schedule, checkpointing,
multi-exit loss composition, MoE aux loss."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.loss import cross_entropy, multi_exit_loss
from repro.training.optim import AdamWConfig, global_norm, init_adamw, schedule


def test_loss_decreases(tiny_trained):
    assert tiny_trained["last_loss"] < tiny_trained["first_loss"] * 0.85


def test_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # end of warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)  # min lr
    assert all(a >= b - 1e-12 for a, b in zip(lrs[1:], lrs[2:]))


def test_cross_entropy_masked():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.zeros((1, 4), jnp.int32)
    m1 = jnp.ones((1, 4))
    m0 = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
    full = float(cross_entropy(logits, labels, m1))
    half = float(cross_entropy(logits, labels, m0))
    assert full == pytest.approx(np.log(8), rel=1e-5)
    assert half == pytest.approx(full, rel=1e-5)


def test_multi_exit_loss_weights():
    logits = jnp.zeros((1, 4, 8))
    out = {"logits": logits, "exit_logits": {1: logits, 2: logits},
           "aux_loss": jnp.asarray(0.5), "prefix_len": 0}
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.ones((1, 4))
    l = multi_exit_loss(out, labels, mask, exit_weight=0.3)
    want = np.log(8) * (1 + 0.3 * 2) + 0.5
    assert float(l["loss"]) == pytest.approx(want, rel=1e-5)


def test_checkpoint_roundtrip(tmp_path, tiny_trained):
    params = tiny_trained["params"]
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, params, extra={"step": 80})
    loaded, extra = load_checkpoint(path, params)
    assert extra["step"] == 80
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)


def test_moe_aux_loss_nonzero():
    import dataclasses
    from repro.configs.registry import get_smoke_config
    from repro.models.registry import build_model
    cfg = get_smoke_config("olmoe-1b-7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    out = model.forward_train(params, batch)
    assert float(out["aux_loss"]) > 0
