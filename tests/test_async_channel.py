"""Async cloud channel: transport-level unit tests, sync-vs-async token
equivalence across modes and KV layouts, the latency-aware early exit
(deadline miss -> edge-committed token, property-tested over latency
traces), speculative reconcile, and reply-reordering safety across slot
refill (a retired slot's late reply must be dropped, never applied to its
successor)."""
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.collm import CollmConfig
from repro.core.netsim import NetworkParams
from repro.core.netsim import _hidden_bytes as netsim_hidden_bytes
from repro.core.transport import (TOKEN_BYTES, AsyncSimChannel, CloudChannel,
                                  ScriptedChannel, SyncChannel,
                                  hidden_wire_bytes)
from repro.serving.engine import GenStats, ServingSystem, _aggregate

WIFI = NetworkParams(up_bw=3.8e6, down_bw=8e6, rtt=0.003)


def _prompts(data, lens):
    return [data.sample_tokens(n) for n in lens]


# ---------------------------------------------------------------------------
# channel unit tests (no model)
# ---------------------------------------------------------------------------
def test_sync_channel_immediate():
    ch = SyncChannel()
    h = ch.submit(slot=3, seq=7, pos=5, reply="r", now=2.5, nbytes_up=8)
    assert ch.in_flight() == 1 and ch.arrival_of(h) == 2.5
    (rep,) = ch.poll(2.5)
    assert (rep.slot, rep.seq, rep.pos, rep.reply) == (3, 7, 5, "r")
    assert rep.deadline_t == math.inf
    assert ch.in_flight() == 0 and ch.poll(math.inf) == []


def test_async_sim_channel_fifo_and_links():
    ch = AsyncSimChannel(WIFI, service_s=0.005, deadline_s=0.5)
    h1 = ch.submit(slot=0, pos=0, reply=1, now=0.0, nbytes_up=8,
                   nbytes_down=8)
    h2 = ch.submit(slot=1, pos=0, reply=2, now=0.0, nbytes_up=8,
                   nbytes_down=8)
    # nothing arrives instantly; the shared cloud FIFO serializes service
    assert ch.poll(1e-4) == []
    assert ch.arrival_of(h2) > ch.arrival_of(h1) > 0.0
    reps = ch.poll(1.0)
    assert [r.reply for r in reps] == [1, 2]
    assert all(r.deadline_t == 0.5 for r in reps)
    assert ch.stats.requests == 2 and ch.stats.replies == 2
    # uploads occupy the per-slot uplink: a later request on the same slot
    # queues behind them
    ch2 = AsyncSimChannel(WIFI)
    ha = ch2.submit(slot=0, reply=0, now=0.0, nbytes_up=8)
    base_arrival = ch2.arrival_of(ha)
    ch2.poll(math.inf)
    ch3 = AsyncSimChannel(WIFI)
    ch3.notify_upload(0, 10_000_000, 0.0)          # big upload in the way
    hb = ch3.submit(slot=0, reply=0, now=0.0, nbytes_up=8)
    assert ch3.arrival_of(hb) > base_arrival


def test_scripted_channel_replays_trace():
    ch = ScriptedChannel([0.1, 0.3], deadline_s=0.2)
    ch.submit(reply="a", now=0.0)
    ch.submit(reply="b", now=0.0)
    assert [r.reply for r in ch.poll(0.15)] == ["a"]
    assert ch.next_arrival() == pytest.approx(0.3)
    assert [r.reply for r in ch.poll(0.35)] == ["b"]


def test_reply_billing_happens_at_poll_not_submit():
    """Regression (docs/fleet_sim.md): flight time and downlink bytes used
    to be billed at ``submit`` — a request dropped by ``reset`` or the
    end-of-run drain then counted virtual flight it never flew."""
    ch = AsyncSimChannel(WIFI, service_s=0.005)
    ch.submit(slot=0, reply="r", now=0.0, nbytes_up=8, nbytes_down=64)
    assert ch.stats.requests == 1 and ch.stats.bytes_up == 8
    # nothing delivered yet: the reply side must be unbilled
    assert ch.stats.replies == 0
    assert ch.stats.bytes_down == 0
    assert ch.stats.flight_s == 0.0
    ch.reset()                          # run teardown with a stale reply
    assert ch.stats.dropped == 1 and ch.in_flight() == 0
    assert ch.stats.bytes_down == 0 and ch.stats.flight_s == 0.0
    assert ch.stats.replies == 0
    assert ch.stats.as_row()["dropped"] == 1


def test_partial_poll_bills_only_delivered_replies():
    ch = ScriptedChannel([0.1, 0.4])
    ch.submit(reply="a", now=0.0, nbytes_down=10)
    ch.submit(reply="b", now=0.0, nbytes_down=1000)
    assert [r.reply for r in ch.poll(0.2)] == ["a"]
    assert ch.stats.replies == 1
    assert ch.stats.bytes_down == 10                 # only "a" delivered
    assert ch.stats.flight_s == pytest.approx(0.1)
    assert ch.drop_in_flight() == 1                  # "b" dies unbilled
    assert ch.stats.dropped == 1
    assert ch.stats.bytes_down == 10
    assert ch.stats.flight_s == pytest.approx(0.1)
    assert ch.poll(math.inf) == []                   # nothing left over


def test_wire_accounting_single_source_of_truth():
    """netsim prices hidden/token packets with transport's helpers — the
    simulator and the engine can never disagree on transmitted MB."""
    from repro.core import netsim
    assert netsim.TOKEN_BYTES is TOKEN_BYTES
    for d in (64, 128, 4096):
        assert netsim_hidden_bytes(d, True) == hidden_wire_bytes(d, "float16")
        assert netsim_hidden_bytes(d, False) == hidden_wire_bytes(d, "float32")
    # int8 carries a per-position fp32 scale
    assert hidden_wire_bytes(128, "int8", seq=3) == 3 * 128 + 3 * 4


def test_genstats_edge_cases():
    assert GenStats().request_rate == 0.0          # zero-token stream
    st0 = GenStats(tokens=4, cloud_requests=2, deadline_misses=1)
    assert st0.request_rate == 0.5                 # misses are not requests
    agg = _aggregate([st0, None, GenStats(tokens=1, deadline_misses=2,
                                          overlap_s=0.5)])
    assert (agg.tokens, agg.cloud_requests, agg.deadline_misses) == (5, 2, 3)
    assert agg.overlap_s == 0.5                    # new counters aggregate


# ---------------------------------------------------------------------------
# sync-vs-async token equivalence (all modes, both KV layouts)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_async_inf_deadline_matches_sync_collm(tiny_trained, layout):
    """With an infinite deadline the async channel only delays replies —
    stalled rows wait while others decode — so greedy streams must be
    token-for-token identical to the blocking SyncChannel engine."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8, 11, 9, 12])
    ccfg = CollmConfig(theta=0.8, kv_layout=layout)
    base = ServingSystem(model, params, ccfg).generate(
        prompts, 12, mode="collm", num_slots=2)
    ch = AsyncSimChannel(WIFI, service_s=0.004)
    r = ServingSystem(model, params, ccfg).generate(
        prompts, 12, mode="collm", num_slots=2, channel=ch,
        tick_time_s=0.01)
    assert r["tokens"] == base["tokens"]
    bs, rs = base["stats"], r["stats"]
    assert (bs.cloud_requests, bs.exits_l1, bs.exits_l2) == \
        (rs.cloud_requests, rs.exits_l1, rs.exits_l2)
    assert rs.deadline_misses == 0
    assert r["virtual_time"] > 0 and rs.stall_s > 0


@pytest.mark.parametrize("mode", ["standalone", "cloud"])
def test_async_channel_other_modes_unchanged(tiny_trained, mode):
    """standalone/cloud modes never cross the hidden-state channel — an
    async channel must not change their streams."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 8])
    ccfg = CollmConfig(theta=0.8)
    base = ServingSystem(model, params, ccfg).generate(
        prompts, 10, mode=mode, num_slots=2)
    r = ServingSystem(model, params, ccfg).generate(
        prompts, 10, mode=mode, num_slots=2,
        channel=AsyncSimChannel(WIFI), tick_time_s=0.01)
    assert r["tokens"] == base["tokens"]


def test_overlap_beats_blocking_virtual_time(tiny_trained):
    """Same WiFi-class latencies: overlapping edge decode with in-flight
    cloud steps must lower the virtual makespan vs the blocking drain."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10] * 8)
    ccfg = CollmConfig(theta=0.8)
    runs = {}
    for overlap in (False, True):
        r = ServingSystem(model, params, ccfg).generate(
            prompts, 12, mode="collm", num_slots=4,
            channel=AsyncSimChannel(WIFI, service_s=0.004),
            tick_time_s=0.01, overlap=overlap)
        runs[overlap] = r
    assert runs[True]["tokens"] == runs[False]["tokens"]
    assert runs[True]["virtual_time"] < runs[False]["virtual_time"]
    # overlap_s is the separating counter: stalled time hidden behind the
    # pool's decoding — identically 0 when the whole pool blocks
    assert runs[True]["stats"].overlap_s > runs[False]["stats"].overlap_s
    assert runs[False]["stats"].overlap_s == 0.0


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_speculative_matches_blocking(tiny_trained, layout):
    """Latency hiding with full reconcile: provisional edge tokens +
    rewind-on-mismatch must converge to the exact blocking stream (the
    speculation is invisible in the final output), with zero stall time."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [8, 11, 9])
    base = ServingSystem(
        model, params, CollmConfig(theta=0.8, kv_layout=layout)).generate(
        prompts, 12, mode="collm", num_slots=2)
    ccfg = CollmConfig(theta=0.8, kv_layout=layout, speculative=True)
    r = ServingSystem(model, params, ccfg).generate(
        prompts, 12, mode="collm", num_slots=2,
        channel=AsyncSimChannel(WIFI, service_s=0.004), tick_time_s=0.01)
    assert r["tokens"] == base["tokens"]
    bs, rs = base["stats"], r["stats"]
    assert (bs.tokens, bs.cloud_requests, bs.exits_l1, bs.exits_l2) == \
        (rs.tokens, rs.cloud_requests, rs.exits_l1, rs.exits_l2)
    assert rs.stall_s == 0.0 and rs.overlap_s > 0.0


# ---------------------------------------------------------------------------
# latency-aware early exit (deadline miss -> edge token)
# ---------------------------------------------------------------------------
def test_deadline_miss_commits_edge_tokens(tiny_trained):
    """Replies far slower than the deadline: every below-θ token must be
    served by the edge exit head (no stalls, streams complete), and the
    late replies must be dropped, not applied."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 9, 11])
    ccfg = CollmConfig(theta=0.8)
    r = ServingSystem(model, params, ccfg).generate(
        prompts, 12, mode="collm", num_slots=2,
        channel=ScriptedChannel([0.5], deadline_s=0.02), tick_time_s=0.005)
    st = r["stats"]
    assert all(len(t) == 12 for t in r["tokens"])
    assert st.deadline_misses > 0
    # decode-time tokens never came from the cloud (only the admission
    # first token may have been served by the cloud prefill)
    assert st.cloud_requests <= len(prompts)
    assert st.deadline_misses + st.exits_l1 + st.exits_l2 >= 11 * len(prompts)
    assert r["late_drops"] == st.deadline_misses


def test_reply_arriving_past_deadline_is_a_miss(tiny_trained):
    """Arrival and deadline crossed within one virtual-clock advance: the
    deadline fired first, so the reply must be dropped and the edge token
    committed — even though the engine sees both events at once."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 9])
    # latency 8 ms, deadline 5 ms, tick 10 ms: every request's deadline
    # AND arrival land inside the same tick
    r = ServingSystem(model, params, CollmConfig(theta=0.8)).generate(
        prompts, 10, mode="collm", num_slots=2,
        channel=ScriptedChannel([0.008], deadline_s=0.005),
        tick_time_s=0.01)
    st = r["stats"]
    assert all(len(t) == 10 for t in r["tokens"])
    assert st.deadline_misses > 0
    assert st.cloud_requests <= len(prompts)   # admission prefill only


def test_fallback_after_switches_to_standalone(tiny_trained):
    """The paper's unstable-link story: consecutive deadline misses flip a
    stream to standalone mode — it stops uploading and serves itself."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    prompts = _prompts(data, [10, 9])
    r = ServingSystem(model, params, CollmConfig(theta=0.8)).generate(
        prompts, 14, mode="collm", num_slots=2,
        channel=ScriptedChannel([0.5], deadline_s=0.01), tick_time_s=0.005,
        fallback_after=2)
    st = r["stats"]
    assert st.fallbacks >= 1
    assert all(len(t) == 14 for t in r["tokens"])
    # once fallen back, rows submit no further requests: fewer channel
    # requests than below-θ decode positions
    assert r["channel_stats"]["requests"] < 13 * len(prompts)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_deadline_miss_property_over_latency_traces(tiny_trained, seed):
    """Hypothesis over random latency traces: whatever the trace, the
    engine never stalls forever and never invents or loses tokens —
    every stream completes to max_new and every emitted token is either a
    confident exit, a cloud reply that beat its deadline, or a
    deadline-missed edge commit."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    rng = np.random.default_rng(seed)
    lat = rng.uniform(0.0, 0.08, size=16).tolist()
    prompts = _prompts(data, [8, 10, 9])
    max_new = 8
    ch = ScriptedChannel(lat, deadline_s=0.03)
    r = ServingSystem(model, params, CollmConfig(theta=0.8)).generate(
        prompts, max_new, mode="collm", num_slots=2, channel=ch,
        tick_time_s=0.01)
    agg = r["stats"]
    assert all(len(t) == max_new for t in r["tokens"])
    served = agg.exits_l1 + agg.exits_l2 + agg.cloud_requests
    # the admission token is uncounted when it exits at the prompt's last
    # position, counted as a cloud request when the prefill served it
    assert agg.tokens - len(prompts) <= served <= agg.tokens
    # every submitted request resolved exactly once: committed reply or
    # deadline miss (cloud_requests also counts admission prefill tokens,
    # which never cross the channel — hence the n_clients slack)
    submitted = r["channel_stats"]["requests"]
    assert (agg.cloud_requests - len(prompts) + agg.deadline_misses
            <= submitted
            <= agg.cloud_requests + agg.deadline_misses)


# ---------------------------------------------------------------------------
# reply reordering across slot refill
# ---------------------------------------------------------------------------
def test_late_reply_dropped_across_refill(tiny_trained):
    """A retired slot's reply arriving during its successor's stream must
    be dropped: the successor's tokens are identical to running it
    alone under the same channel conditions."""
    model, params, data = (tiny_trained["model"], tiny_trained["params"],
                           tiny_trained["data"])
    p0, p1 = _prompts(data, [10, 9])
    # replies take 0.6 virtual seconds; a 6-token stream at 0.01s/tick
    # with a 0.01s deadline retires long before they arrive — they land
    # in the successor's lifetime and must be dropped by the seq guard
    mk = lambda: ScriptedChannel([0.6], deadline_s=0.01)
    both = ServingSystem(model, params, CollmConfig(theta=0.8)).generate(
        [p0, p1], 6, mode="collm", num_slots=1, channel=mk(),
        tick_time_s=0.01)
    alone = ServingSystem(model, params, CollmConfig(theta=0.8)).generate(
        [p1], 6, mode="collm", num_slots=1, channel=mk(), tick_time_s=0.01)
    assert both["tokens"][1] == alone["tokens"][0]
    assert both["late_drops"] >= both["stats"].deadline_misses > 0


def test_recurrent_arch_stalls_keep_state():
    """Hybrid SSM arch: stalled rows flow through the batched graph as
    placeholders, and ``edge_step_masked`` must merge their recurrent
    state out — async streams stay token-identical to sync."""
    import jax
    from repro.configs.registry import get_smoke_config
    from repro.models.registry import build_model

    cfg = get_smoke_config("zamba2-1.2b")
    model = build_model(cfg)
    assert not model.attention_only()
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (8, 9)]
    ccfg = CollmConfig(theta=0.95)
    base = ServingSystem(model, params, ccfg).generate(
        prompts, 8, mode="collm", num_slots=2)
    r = ServingSystem(model, params, ccfg).generate(
        prompts, 8, mode="collm", num_slots=2,
        channel=AsyncSimChannel(WIFI, service_s=0.004), tick_time_s=0.01)
    assert r["tokens"] == base["tokens"]
    assert r["stats"].stall_s > 0


def test_channel_protocol_base_class():
    """The engine only relies on the CloudChannel protocol surface."""
    ch = CloudChannel(deadline_s=1.0)
    h = ch.submit(reply="x", now=0.0)
    assert ch.arrival_of(h) == 0.0
    (rep,) = ch.poll(0.0)
    assert rep.deadline_t == 1.0
    assert ch.next_arrival() is None
