"""Synthetic data pipeline: deterministic corpora with learnable structure.

The container is offline, so corpora are generated:

  * ``markov`` — an order-2 Markov chain over the vocabulary with a skewed
    transition table.  Gives early exits a confidence gradient: frequent
    bigrams become predictable at shallow layers first (mirrors the paper's
    Table 1 phenomenon).
  * ``copy``   — induction-style [BOS a1..ak SEP a1..ak] sequences; the copy
    tail is predictable with near-1.0 confidence once learned.

Batches are packed to fixed seq_len with next-token labels + masks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    kind: str = "markov"       # "markov" | "copy" | "mixed"
    seed: int = 0


class SyntheticCorpus:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        r = np.random.default_rng(cfg.seed + 1)
        # skewed order-1 table with strong modes (rows sum to 1)
        logits = r.gumbel(size=(v, v)) * 2.0
        top = r.integers(0, v, size=v)
        logits[np.arange(v), top] += 6.0      # each token has a likely successor
        self.table = np.exp(logits - logits.max(1, keepdims=True))
        self.table /= self.table.sum(1, keepdims=True)

    def _markov_seq(self, n: int) -> np.ndarray:
        v = self.cfg.vocab_size
        seq = np.empty(n, np.int32)
        seq[0] = self.rng.integers(0, v)
        for i in range(1, n):
            seq[i] = self.rng.choice(v, p=self.table[seq[i - 1]])
        return seq

    def _copy_seq(self, n: int) -> np.ndarray:
        v = self.cfg.vocab_size
        k = max(2, n // 2 - 1)
        head = self.rng.integers(2, v, size=k).astype(np.int32)
        sep = np.array([1], np.int32)
        seq = np.concatenate([head, sep, head])[:n]
        if len(seq) < n:
            seq = np.pad(seq, (0, n - len(seq)), constant_values=0)
        return seq

    def sample_tokens(self, n: int, kind: Optional[str] = None) -> np.ndarray:
        kind = kind or self.cfg.kind
        if kind == "mixed":
            kind = "copy" if self.rng.random() < 0.5 else "markov"
        return self._markov_seq(n) if kind == "markov" else self._copy_seq(n)

    def batches(self, steps: int) -> Iterator[Dict[str, np.ndarray]]:
        cfg = self.cfg
        for _ in range(steps):
            toks = np.stack([self.sample_tokens(cfg.seq_len + 1)
                             for _ in range(cfg.batch_size)])
            yield {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32),
                "mask": np.ones((cfg.batch_size, cfg.seq_len), np.float32),
            }

    def prompts(self, n: int, length: int) -> np.ndarray:
        return np.stack([self.sample_tokens(length) for _ in range(n)])
