"""jit'd public wrapper for the fused exit-head confidence kernel.

On CPU (this container) the kernel runs in interpret mode; on TPU set
``interpret=False`` (default resolves from the backend)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.exit_head.kernel import exit_head_pallas
from repro.kernels.exit_head.ref import exit_head_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def exit_confidence(hidden: jax.Array, weight: jax.Array,
                    norm_scale: jax.Array, *, block_b: int = 8,
                    block_v: int = 512, interpret: bool = None,
                    use_kernel: bool = True):
    """(B,d) hidden + (V,d) unembedding -> (confidence, token, logsumexp).

    Falls back to the jnp oracle for shapes the kernel's tiling cannot
    cover evenly (the oracle IS the reference semantics)."""
    b, d = hidden.shape
    v = weight.shape[0]
    if interpret is None:
        interpret = _default_interpret()
    bb = min(block_b, b)
    bv = min(block_v, v)
    if not use_kernel or b % bb or v % bv:
        return exit_head_ref(hidden, weight, norm_scale)
    return exit_head_pallas(hidden, weight, norm_scale, block_b=bb,
                            block_v=bv, interpret=interpret)
