"""Pure-jnp oracle for the fused early-exit confidence head."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def exit_head_ref(hidden: jax.Array, weight: jax.Array, norm_scale: jax.Array,
                  eps: float = 1e-5) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """hidden: (B, d); weight: (V, d); norm_scale: (d,).

    Returns (confidence (B,), token (B,), logsumexp (B,)) of the exit head:
    rms-norm -> unembed -> max-softmax-prob + argmax."""
    h = hidden.astype(jnp.float32)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    hn = h * jax.lax.rsqrt(var + eps) * (1.0 + norm_scale.astype(jnp.float32))
    logits = hn @ weight.astype(jnp.float32).T           # (B, V)
    lse = jax.nn.logsumexp(logits, axis=-1)
    mx = jnp.max(logits, axis=-1)
    conf = jnp.exp(mx - lse)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return conf, tok, lse
