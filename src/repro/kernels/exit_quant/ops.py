"""jit'd public wrapper for the fused exit-head + quantize kernel.

On CPU (this container) the kernel runs in interpret mode; on TPU set
``interpret=False`` (default resolves from the backend)."""
from __future__ import annotations

import jax

from repro.kernels.exit_quant.kernel import exit_quant_pallas
from repro.kernels.exit_quant.ref import exit_quant_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def exit_quant(hidden: jax.Array, weight: jax.Array, norm_scale: jax.Array,
               *, block_b: int = 8, block_v: int = 512, eps: float = 1e-5,
               interpret: bool = None, use_kernel: bool = True):
    """(B,d) hidden + (V,d) unembedding ->
    (confidence, token, logsumexp, q int8 (B,d), scale fp32 (B,1)).

    One launch for the below-θ hot path: the exit decision AND the int8
    wire packet of the same hidden tile.  Falls back to the jnp oracle for
    shapes the kernel's tiling cannot cover evenly (the oracle IS the
    reference semantics)."""
    b, d = hidden.shape
    v = weight.shape[0]
    if interpret is None:
        interpret = _default_interpret()
    bb = min(block_b, b)
    bv = min(block_v, v)
    if not use_kernel or b % bb or v % bv:
        return exit_quant_ref(hidden, weight, norm_scale, eps)
    return exit_quant_pallas(hidden, weight, norm_scale, block_b=bb,
                             block_v=bv, eps=eps, interpret=interpret)
