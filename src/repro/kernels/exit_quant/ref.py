"""Pure-jnp oracle for the fused exit-head + wire-quantize kernel.

Reference semantics = the two-launch baseline the kernel fuses: the
exit-head confidence pass (``exit_head_ref``) followed by the transport
int8 quantizer (``quantize_int8_ref``) over the SAME raw hidden tile.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels.exit_head.ref import exit_head_ref
from repro.kernels.quantize.ref import quantize_int8_ref


def exit_quant_ref(hidden: jax.Array, weight: jax.Array,
                   norm_scale: jax.Array, eps: float = 1e-5
                   ) -> Tuple[jax.Array, jax.Array, jax.Array,
                              jax.Array, jax.Array]:
    """hidden: (B, d); weight: (V, d); norm_scale: (d,).

    Returns (confidence (B,), token (B,), logsumexp (B,),
    q int8 (B, d), scale fp32 (B, 1)) — the exit decision plus the int8
    wire packet of the raw (pre-norm) hidden, exactly what a below-θ row
    uploads to the cloud."""
    conf, tok, lse = exit_head_ref(hidden, weight, norm_scale, eps)
    q, scale = quantize_int8_ref(hidden)
    return conf, tok, lse, q, scale
