"""Fused exit-head confidence + int8 wire quantization — Pallas TPU kernel.

A below-θ decode row today costs TWO launches over the same (B, d) hidden
tile: the exit-head confidence pass (``kernels/exit_head``) and a separate
``kernels/quantize`` launch producing the int8 packet it uploads.  Both
read the identical hidden from HBM.  This kernel fuses them: while the
V-axis grid streams the unembedding through VMEM for the running
(max, logsumexp, argmax), the first V step quantizes the resident raw
hidden tile in place — one pass over the hidden, one launch, and the int8
wire packet (data + per-row scale) drops out alongside the exit decision.

Grid: (B/block_b, V/block_v) like ``exit_head``; the V axis is minormost
(sequential on TPU) so VMEM scratch carries the running statistics, and
the quantized outputs are written once at ``vi == 0`` (their blocks only
depend on the B index).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _exit_quant_kernel(h_ref, w_ref, ns_ref, conf_ref, tok_ref, lse_ref,
                       q_ref, s_ref, m_scr, l_scr, a_scr, *, eps: float,
                       block_v: int, n_v: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        a_scr[...] = jnp.zeros_like(a_scr)
        # quantize the resident RAW hidden (pre-norm: the wire carries the
        # activation, not the exit-head's normalized view) — same per-row
        # absmax scaling as the transport quantizer
        xf = h_ref[...].astype(jnp.float32)                # (bb, d)
        scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        s_ref[...] = scale
        q_ref[...] = jnp.clip(jnp.round(xf / scale),
                              -127, 127).astype(jnp.int8)

    # rms-norm the hidden block (full d is resident)
    h = h_ref[...].astype(jnp.float32)                     # (bb, d)
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    ns = ns_ref[...].astype(jnp.float32)
    hn = h * jax.lax.rsqrt(var + eps) * (1.0 + ns)

    w = w_ref[...].astype(jnp.float32)                     # (bv, d)
    logits = jax.lax.dot_general(hn, w, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    tile_max = jnp.max(logits, axis=-1)                    # (bb,)
    tile_arg = (jnp.argmax(logits, axis=-1).astype(jnp.int32)
                + vi * block_v)

    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, tile_max)
    corr = jnp.exp(m_old - m_new)
    l_scr[...] = (l_scr[...] * corr
                  + jnp.sum(jnp.exp(logits - m_new[:, None]), axis=-1))
    a_scr[...] = jnp.where(tile_max > m_old, tile_arg, a_scr[...])
    m_scr[...] = m_new

    @pl.when(vi == n_v - 1)
    def _finish():
        m = m_scr[...]
        l = l_scr[...]
        lse = m + jnp.log(l)
        conf_ref[...] = jnp.exp(m - lse)
        tok_ref[...] = a_scr[...]
        lse_ref[...] = lse


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_v", "eps", "interpret"))
def exit_quant_pallas(hidden: jax.Array, weight: jax.Array,
                      norm_scale: jax.Array, *, block_b: int = 8,
                      block_v: int = 512, eps: float = 1e-5,
                      interpret: bool = True):
    """hidden: (B, d); weight: (V, d) ->
    (conf (B,), tok (B,), lse (B,), q int8 (B, d), scale fp32 (B, 1))."""
    b, d = hidden.shape
    v = weight.shape[0]
    block_b = min(block_b, b)
    block_v = min(block_v, v)
    assert b % block_b == 0 and v % block_v == 0, (b, v, block_b, block_v)
    n_b, n_v = b // block_b, v // block_v

    kernel = functools.partial(_exit_quant_kernel, eps=eps, block_v=block_v,
                               n_v=n_v)
    conf, tok, lse, q, s = pl.pallas_call(
        kernel,
        grid=(n_b, n_v),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_v, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.int8),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.int32),
        ],
        interpret=interpret,
    )(hidden, weight, norm_scale)
    return conf, tok, lse, q, s
