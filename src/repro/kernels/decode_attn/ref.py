"""Pure-jnp oracle for GQA flash-decode attention over a ring KV cache."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    pos_ids: jax.Array, cur_pos: jax.Array,
                    window: int = 0) -> jax.Array:
    """q: (B,H,d); k/v: (B,S,KV,d); pos_ids: (B,S) (-1 = empty slot);
    cur_pos: scalar int.  Returns (B,H,d)."""
    b, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    valid = (pos_ids >= 0) & (pos_ids <= cur_pos)
    if window:
        valid &= (cur_pos - pos_ids) < window
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
