"""Pure-jnp oracles for GQA flash-decode attention: ring KV cache
(``decode_attn_ref``) and block-paged KV cache (``decode_attn_paged_ref``,
K/V gathered through a per-row block table)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    pos_ids: jax.Array, cur_pos: jax.Array,
                    window: int = 0) -> jax.Array:
    """q: (B,H,d); k/v: (B,S,KV,d); pos_ids: (B,S) (-1 = empty slot);
    cur_pos: scalar or per-row (B,) int.  Returns (B,H,d)."""
    b, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (b,))[:, None]
    qg = q.reshape(b, kvh, g, d)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    valid = (pos_ids >= 0) & (pos_ids <= cur)
    if window:
        valid &= (cur - pos_ids) < window
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def decode_attn_paged_ref(q: jax.Array, kp: jax.Array, vp: jax.Array,
                          pos_pages: jax.Array, block_tbl: jax.Array,
                          cur_pos: jax.Array, window: int = 0, *,
                          k_scale: jax.Array = None,
                          v_scale: jax.Array = None) -> jax.Array:
    """q: (B,H,d); kp/vp: (P,page,KV,d) physical pages; pos_pages: (P,page)
    (-1 = empty slot); block_tbl: (B,n_lp) physical page ids (-1 =
    unallocated); cur_pos: scalar or per-row (B,) int.  Returns (B,H,d).

    Gathers the logical K/V view through the block table (unmapped pages
    read page 0, masked via pos = -1), then the attention itself IS the
    ring oracle — one masked-softmax implementation for both layouts.

    For int8 pages, ``k_scale``/``v_scale`` (P,page,KV) fp32 dequantize the
    gathered view (materialized here; the Pallas kernel dequantizes
    in-VMEM instead)."""
    b = q.shape[0]
    kvh, ps = kp.shape[2], kp.shape[1]
    n_lp = block_tbl.shape[1]
    d = kp.shape[3]
    phys = jnp.where(block_tbl >= 0, block_tbl, 0)
    k = kp[phys]
    v = vp[phys]
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[phys][..., None]
        v = v.astype(jnp.float32) * v_scale[phys][..., None]
    k = k.reshape(b, n_lp * ps, kvh, d)
    v = v.reshape(b, n_lp * ps, kvh, d)
    pos = jnp.where(block_tbl[:, :, None] >= 0, pos_pages[phys],
                    -1).reshape(b, n_lp * ps)
    return decode_attn_ref(q, k, v, pos, cur_pos, window=window)
