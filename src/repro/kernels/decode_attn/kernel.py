"""GQA flash-decode attention — Pallas TPU kernels (ring + paged).

One new query token attends over a long KV cache: the cloud tier's
per-token hot loop at decode_32k/long_500k shapes.  KV is streamed
HBM->VMEM in (block_s, d) tiles; online-softmax statistics live in VMEM
scratch; the (G, d) output tile is written once at the last S tile.

Grid: (B, KV_heads, S/block_s) — S minormost (sequential), so scratch
carries (acc, m, l) across KV tiles.  The G = H/KV query heads of one KV
group ride together through the MXU: (G, d) @ (d, block_s).

Two cache layouts share that loop:

  * ``decode_attn_pallas`` — dense (possibly ring-buffered) (B, S) cache;
    the S tile index maps straight into the row's cache.
  * ``decode_attn_paged_pallas`` — block-paged cache (P, page_size): the
    per-row block table rides in as a **scalar-prefetch** operand
    (``pltpu.PrefetchScalarGridSpec``) so the k/v/pos BlockSpec index maps
    can look up, per (row, logical-page) grid point, WHICH physical page to
    DMA — the vLLM PagedAttention trick, no gather materialization.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, pos_ref, cur_ref, o_ref,
                        acc_scr, m_scr, l_scr, *, n_s: int, window: int,
                        scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bs, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (bs, d)
    pos = pos_ref[0]                               # (bs,)
    cur = cur_ref[0]

    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    valid = (pos >= 0) & (pos <= cur)
    if window:
        valid &= (cur - pos) < window
    logits = jnp.where(valid[None, :], logits, NEG_INF)

    m_old = m_scr[...]                             # (G,)
    m_new = jnp.maximum(m_old, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    corr = jnp.exp(m_old - m_new)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new

    @pl.when(si == n_s - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "window", "interpret"))
def decode_attn_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                       pos_ids: jax.Array, cur_pos: jax.Array, *,
                       block_s: int = 512, window: int = 0,
                       interpret: bool = True) -> jax.Array:
    """q: (B,H,d); k/v: (B,S,KV,d); pos_ids: (B,S); cur_pos: () int32."""
    b, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    block_s = min(block_s, s)
    assert s % block_s == 0
    n_s = s // block_s
    qg = q.reshape(b, kvh, g, d)
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32)[None], (1,))

    kernel = functools.partial(_decode_attn_kernel, n_s=n_s, window=window,
                               scale=1.0 / math.sqrt(d))
    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, ki, si: (bi, si, ki, 0)),
            pl.BlockSpec((1, block_s), lambda bi, ki, si: (bi, si)),
            pl.BlockSpec((1,), lambda bi, ki, si: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, pos_ids, cur)
    return out.reshape(b, h, d)


def _decode_attn_paged_kernel(tbl_ref, q_ref, k_ref, v_ref, *rest,
                              n_lp: int, window: int, scale: float,
                              quantized: bool = False):
    if quantized:
        (ks_ref, vs_ref, pos_ref, cur_ref, o_ref,
         acc_scr, m_scr, l_scr) = rest
    else:
        pos_ref, cur_ref, o_ref, acc_scr, m_scr, l_scr = rest
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (ps, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (ps, d)
    if quantized:
        # int8 pages: dequantize in-VMEM with the per-row absmax scales
        # that rode in next to the block-table-indexed page DMA.  HBM
        # traffic for this tile is ps*d int8 + ps fp32, not ps*d fp32.
        k = k * ks_ref[0, :, 0][:, None]           # (ps, d)
        v = v * vs_ref[0, :, 0][:, None]
    pos = pos_ref[0]                               # (ps,)
    cur = cur_ref[0]
    mapped = tbl_ref[bi, pi] >= 0                  # unallocated -> all invalid

    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    valid = (pos >= 0) & (pos <= cur) & mapped
    if window:
        valid &= (cur - pos) < window
    logits = jnp.where(valid[None, :], logits, NEG_INF)

    m_old = m_scr[...]
    m_new = jnp.maximum(m_old, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    corr = jnp.exp(m_old - m_new)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new

    @pl.when(pi == n_lp - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def decode_attn_paged_pallas(q: jax.Array, kp: jax.Array, vp: jax.Array,
                             pos_pages: jax.Array, block_tbl: jax.Array,
                             cur_pos: jax.Array, *, k_scale=None,
                             v_scale=None, window: int = 0,
                             interpret: bool = True) -> jax.Array:
    """q: (B,H,d); kp/vp: (P,page_size,KV,d); pos_pages: (P,page_size);
    block_tbl: (B,n_lp) int32 (-1 = unallocated); cur_pos: scalar or (B,).

    The KV tile of grid point (b, k, pi) is DMA'd from physical page
    ``block_tbl[b, pi]`` via scalar-prefetch index maps; unmapped pages
    read page 0 and are masked out.

    With int8 pages, pass ``k_scale``/``v_scale`` (P,page_size,KV) fp32:
    the per-row absmax scales ride through the SAME block-table index maps
    as the pages and dequantization happens in-kernel, after the DMA — the
    HBM read per token shrinks ~4x instead of being re-expanded in XLA."""
    b, h, d = q.shape
    kvh, ps = kp.shape[2], kp.shape[1]
    n_lp = block_tbl.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    cur = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (b,))
    tbl = block_tbl.astype(jnp.int32)
    quantized = k_scale is not None

    def page_map(bi, ki, pi, tbl_ref):
        return (jnp.maximum(tbl_ref[bi, pi], 0), 0, ki, 0)

    def scale_map(bi, ki, pi, tbl_ref):
        return (jnp.maximum(tbl_ref[bi, pi], 0), 0, ki)

    def pos_map(bi, ki, pi, tbl_ref):
        return (jnp.maximum(tbl_ref[bi, pi], 0), 0)

    kernel = functools.partial(_decode_attn_paged_kernel, n_lp=n_lp,
                               window=window, scale=1.0 / math.sqrt(d),
                               quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, g, d),
                     lambda bi, ki, pi, tbl_ref: (bi, ki, 0, 0)),
        pl.BlockSpec((1, ps, 1, d), page_map),
        pl.BlockSpec((1, ps, 1, d), page_map),
    ]
    operands = [qg, kp, vp]
    if quantized:
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map),
                     pl.BlockSpec((1, ps, 1), scale_map)]
        operands += [k_scale, v_scale]
    in_specs += [
        pl.BlockSpec((1, ps), pos_map),
        pl.BlockSpec((1,), lambda bi, ki, pi, tbl_ref: (bi,)),
    ]
    operands += [pos_pages, cur]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, n_lp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda bi, ki, pi, tbl_ref: (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        interpret=interpret,
    )(tbl, *operands)
    return out.reshape(b, h, d)
