"""jit'd public wrappers for GQA flash-decode attention (ring + paged)."""
from __future__ import annotations

import jax

from repro.kernels.decode_attn.kernel import (decode_attn_paged_pallas,
                                              decode_attn_pallas)
from repro.kernels.decode_attn.ref import (decode_attn_paged_ref,
                                           decode_attn_ref)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 pos_ids: jax.Array, cur_pos, *, window: int = 0,
                 block_s: int = 512, interpret: bool = None,
                 use_kernel: bool = True) -> jax.Array:
    """q: (B,H,d) one new token; k/v: (B,S,KV,d) ring cache -> (B,H,d)."""
    if interpret is None:
        interpret = _default_interpret()
    s = k.shape[1]
    bs = min(block_s, s)
    if not use_kernel or s % bs:
        return decode_attn_ref(q, k, v, pos_ids, cur_pos, window=window)
    return decode_attn_pallas(q, k, v, pos_ids, cur_pos, block_s=bs,
                              window=window, interpret=interpret)


def flash_decode_paged(q: jax.Array, kp: jax.Array, vp: jax.Array,
                       pos_pages: jax.Array, block_tbl: jax.Array, cur_pos,
                       *, k_scale=None, v_scale=None, window: int = 0,
                       interpret: bool = None,
                       use_kernel: bool = True) -> jax.Array:
    """q: (B,H,d) one new token; kp/vp: (P,page_size,KV,d) page pool;
    block_tbl: (B,n_lp) per-row physical page ids -> (B,H,d).  The Pallas
    path DMAs one physical page per grid step through a scalar-prefetched
    block table (block size = page_size).

    int8 pools pass ``k_scale``/``v_scale`` (P,page_size,KV) fp32 per-row
    scales; dequantization then happens inside the kernel, after the page
    DMA, so the HBM read stays int8-sized."""
    if interpret is None:
        interpret = _default_interpret()
    if not use_kernel:
        return decode_attn_paged_ref(q, kp, vp, pos_pages, block_tbl,
                                     cur_pos, window=window,
                                     k_scale=k_scale, v_scale=v_scale)
    return decode_attn_paged_pallas(q, kp, vp, pos_pages, block_tbl, cur_pos,
                                    k_scale=k_scale, v_scale=v_scale,
                                    window=window, interpret=interpret)
