"""jit'd public wrapper for GQA flash-decode attention."""
from __future__ import annotations

import jax

from repro.kernels.decode_attn.kernel import decode_attn_pallas
from repro.kernels.decode_attn.ref import decode_attn_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 pos_ids: jax.Array, cur_pos, *, window: int = 0,
                 block_s: int = 512, interpret: bool = None,
                 use_kernel: bool = True) -> jax.Array:
    """q: (B,H,d) one new token; k/v: (B,S,KV,d) ring cache -> (B,H,d)."""
    if interpret is None:
        interpret = _default_interpret()
    s = k.shape[1]
    bs = min(block_s, s)
    if not use_kernel or s % bs:
        return decode_attn_ref(q, k, v, pos_ids, cur_pos, window=window)
    return decode_attn_pallas(q, k, v, pos_ids, cur_pos, block_s=bs,
                              window=window, interpret=interpret)
