"""Pure-jnp oracle for the transport quantizer (per-row int8 + fp16)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8_ref(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (N, d) -> (q int8 (N,d), scale fp32 (N,1))."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
