"""Per-row int8 wire-format quantizer — Pallas TPU kernel.

Fuses absmax-reduce + scale + round + clip in one VMEM pass over (block_n, d)
tiles of the hidden-state upload buffer, producing the int8 payload and the
fp32 per-row scales that cross the pod boundary (beyond-paper transport
format; paper uses fp16)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def quantize_int8_pallas(x: jax.Array, *, block_n: int = 256,
                         interpret: bool = True):
    n, d = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_n, d), lambda i: (i, 0)),
                   pl.BlockSpec((block_n, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.int8),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return q, s
