"""jit'd public wrapper for the int8 transport quantizer."""
from __future__ import annotations

import jax

from repro.kernels.quantize.kernel import quantize_int8_pallas
from repro.kernels.quantize.ref import dequantize_int8_ref, quantize_int8_ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def quantize_int8(x: jax.Array, *, block_n: int = 256,
                  interpret: bool = None, use_kernel: bool = True):
    """(N,d) -> (int8 payload, fp32 per-row scale)."""
    if interpret is None:
        interpret = _default_interpret()
    n = x.shape[0]
    bn = min(block_n, n)
    if not use_kernel or n % bn:
        return quantize_int8_ref(x)
    return quantize_int8_pallas(x, block_n=bn, interpret=interpret)


dequantize_int8 = dequantize_int8_ref
