"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable via
the chunked GLA core) and sLSTM (scalar memory, strictly sequential scan).

Block layout follows the xLSTM language-model family: pre-norm residual
blocks; the mLSTM block is pre-up-projection (factor ``expand``) with a
causal depthwise conv feeding q/k; the sLSTM block uses block-diagonal
(per-head) recurrent mixing followed by a small gated FFN.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import gla
from repro.models.common import dense_init, rms_norm, split_rngs

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# causal depthwise conv1d helpers (shared with mamba2)
# ---------------------------------------------------------------------------
def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B,S,D); w: (W,D) depthwise causal conv."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def conv_decode_step(x1: jax.Array, conv_state: jax.Array,
                     w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x1: (B,1,D); conv_state: (B,W-1,D) past inputs.  Returns (y1, state)."""
    width = w.shape[0]
    window = jnp.concatenate([conv_state, x1], axis=1)        # (B,W,D)
    y = jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                   w.astype(jnp.float32))[:, None, :].astype(x1.dtype)
    return y, window[:, -(width - 1):, :] if width > 1 else conv_state


def _per_head_rmsnorm(y: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """y: (B,H,S,D) per-head norm with per-head scale (H,D)."""
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    out = yf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))[None, :, None, :]
    return out.astype(y.dtype)


# ===========================================================================
# mLSTM block
# ===========================================================================
def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    expand = cfg.ssm.expand if cfg.ssm else 2
    di = cfg.d_model * expand
    h = cfg.ssm.num_ssm_heads or cfg.n_heads
    return di, h, di // h


def init_mlstm_block(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    di, h, hd = _mlstm_dims(cfg)
    conv_w = cfg.ssm.conv_width if cfg.ssm else 4
    r = split_rngs(rng, 8)
    return {
        "norm": jnp.zeros((d,), dtype),
        "w_up": dense_init(r[0], d, 2 * di, dtype),
        "conv": (jax.random.normal(r[1], (conv_w, di)) * 0.1).astype(dtype),
        "wq": dense_init(r[2], di, di, dtype),
        "wk": dense_init(r[3], di, di, dtype),
        "wv": dense_init(r[4], di, di, dtype),
        "w_if": dense_init(r[5], di, 2 * h, dtype),
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.full((h,), 3.0)]).astype(dtype),
        "head_norm": jnp.zeros((h, hd), dtype),
        "w_down": dense_init(r[6], di, d, dtype),
    }


def _mlstm_qkv_gates(params: Params, cfg: ModelConfig, xi: jax.Array,
                     xc: jax.Array):
    di, h, hd = _mlstm_dims(cfg)
    b, s, _ = xi.shape
    q = jnp.einsum("bsd,de->bse", xc, params["wq"].astype(xi.dtype))
    k = jnp.einsum("bsd,de->bse", xc, params["wk"].astype(xi.dtype))
    v = jnp.einsum("bsd,de->bse", xi, params["wv"].astype(xi.dtype))
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3) / math.sqrt(hd)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    gates = (jnp.einsum("bsd,de->bse", xi, params["w_if"].astype(xi.dtype))
             + params["b_if"].astype(xi.dtype))
    i_pre, f_pre = jnp.split(gates.astype(jnp.float32), 2, axis=-1)
    lf = jax.nn.log_sigmoid(f_pre).transpose(0, 2, 1)          # (B,H,S)
    li = i_pre.transpose(0, 2, 1)
    return q, k, v, lf, li


def mlstm_forward(params: Params, cfg: ModelConfig, x: jax.Array, *,
                  state: Optional[Params] = None,
                  return_state: bool = False):
    """Full-sequence mLSTM block.  x: (B,S,d)."""
    di, h, hd = _mlstm_dims(cfg)
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", xn, params["w_up"].astype(x.dtype))
    xi, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(causal_conv1d(xi, params["conv"]))
    q, k, v, lf, li = _mlstm_qkv_gates(params, cfg, xi, xc)
    chunk = cfg.ssm.chunk_size if cfg.ssm else 256
    gstate = state["gla"] if state is not None else None
    y, gnew = gla.chunked_gla(q, k, v, lf, li, normalize=True, chunk=chunk,
                              state=gstate)
    y = _per_head_rmsnorm(y, params["head_norm"], cfg.norm_eps)
    y = y.transpose(0, 2, 1, 3).reshape(x.shape[0], x.shape[1], di)
    y = y * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", y, params["w_down"].astype(x.dtype))
    if return_state:
        conv_w = params["conv"].shape[0]
        tail = xi[:, -(conv_w - 1):, :]
        pad = conv_w - 1 - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"gla": gnew, "conv": tail}
    return out


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    di, h, hd = _mlstm_dims(cfg)
    conv_w = cfg.ssm.conv_width if cfg.ssm else 4
    return {"gla": gla.init_gla_state(batch, h, hd, hd, jnp.float32),
            "conv": jnp.zeros((batch, conv_w - 1, di), dtype)}


def mlstm_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 cache: Params) -> Tuple[jax.Array, Params]:
    """x: (B,1,d)."""
    di, h, hd = _mlstm_dims(cfg)
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", xn, params["w_up"].astype(x.dtype))
    xi, z = jnp.split(up, 2, axis=-1)
    yc, conv_state = conv_decode_step(xi, cache["conv"], params["conv"])
    xc = jax.nn.silu(yc)
    q, k, v, lf, li = _mlstm_qkv_gates(params, cfg, xi, xc)
    y1, gnew = gla.gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                   lf[:, :, 0], li[:, :, 0], cache["gla"],
                                   normalize=True)
    y = _per_head_rmsnorm(y1[:, :, None, :], params["head_norm"], cfg.norm_eps)
    y = y.transpose(0, 2, 1, 3).reshape(x.shape[0], 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = x + jnp.einsum("bse,ed->bsd", y, params["w_down"].astype(x.dtype))
    return out, {"gla": gnew, "conv": conv_state}


# ===========================================================================
# sLSTM block
# ===========================================================================
def _slstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    h = cfg.ssm.num_ssm_heads or cfg.n_heads
    return h, cfg.d_model // h


def init_slstm_block(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    h, hd = _slstm_dims(cfg)
    r = split_rngs(rng, 12)
    def rec(key):
        return (jax.random.normal(key, (h, hd, hd)) / math.sqrt(hd)).astype(dtype)
    f_ff = int(d * 4 / 3)
    return {
        "norm": jnp.zeros((d,), dtype),
        "wz": dense_init(r[0], d, d, dtype), "rz": rec(r[1]),
        "wi": dense_init(r[2], d, d, dtype), "ri": rec(r[3]),
        "wf": dense_init(r[4], d, d, dtype), "rf": rec(r[5]),
        "wo": dense_init(r[6], d, d, dtype), "ro": rec(r[7]),
        "b_z": jnp.zeros((d,), dtype), "b_i": jnp.zeros((d,), dtype),
        "b_f": jnp.full((d,), 3.0, dtype), "b_o": jnp.zeros((d,), dtype),
        "head_norm": jnp.zeros((h, hd), dtype),
        "norm2": jnp.zeros((d,), dtype),
        "ffn_up": dense_init(r[8], d, 2 * f_ff, dtype),
        "ffn_down": dense_init(r[9], f_ff, d, dtype),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    h, hd = _slstm_dims(cfg)
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.zeros((batch, h, hd), jnp.float32),
            "h": jnp.zeros((batch, h, hd), jnp.float32)}


def _slstm_cell(params: Params, cfg: ModelConfig, xt: jax.Array,
                state: Params) -> Tuple[jax.Array, Params]:
    """xt: (B,d) -> (h_out (B,d), state)."""
    h, hd = _slstm_dims(cfg)
    b = xt.shape[0]
    c, n, m, hprev = state["c"], state["n"], state["m"], state["h"]
    xf = xt.astype(jnp.float32)

    def lin(w, bias, r):
        pre = (xf @ w.astype(jnp.float32) + bias.astype(jnp.float32)).reshape(b, h, hd)
        return pre + jnp.einsum("bhd,hde->bhe", hprev, r.astype(jnp.float32))

    z = jnp.tanh(lin(params["wz"], params["b_z"], params["rz"]))
    i_pre = lin(params["wi"], params["b_i"], params["ri"])
    f_pre = lin(params["wf"], params["b_f"], params["rf"])
    o = jax.nn.sigmoid(lin(params["wo"], params["b_o"], params["ro"]))
    lf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(lf + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(lf + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_tilde = c_new / jnp.maximum(n_new, 1e-6)
    h_out = o * h_tilde
    return h_out, {"c": c_new, "n": n_new, "m": m_new, "h": h_out}


def slstm_forward(params: Params, cfg: ModelConfig, x: jax.Array, *,
                  state: Optional[Params] = None, return_state: bool = False):
    h, hd = _slstm_dims(cfg)
    b, s, d = x.shape
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    st = state or init_slstm_cache(cfg, b)

    def step(carry, xt):
        h_out, new = _slstm_cell(params, cfg, xt, carry)
        return new, h_out

    st_new, hs = jax.lax.scan(step, st, jnp.moveaxis(xn, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)                                # (B,S,H,hd)
    hs = _per_head_rmsnorm(hs.transpose(0, 2, 1, 3), params["head_norm"],
                           cfg.norm_eps).transpose(0, 2, 1, 3)
    y = hs.reshape(b, s, d).astype(x.dtype)
    x = x + y
    # gated ffn
    xn2 = rms_norm(x, params["norm2"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", xn2, params["ffn_up"].astype(x.dtype))
    u, g = jnp.split(up, 2, axis=-1)
    y2 = jax.nn.silu(g) * u
    out = x + jnp.einsum("bse,ed->bsd", y2, params["ffn_down"].astype(x.dtype))
    if return_state:
        return out, st_new
    return out


def slstm_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 cache: Params) -> Tuple[jax.Array, Params]:
    b, s, d = x.shape
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    h_out, new = _slstm_cell(params, cfg, xn[:, 0], cache)
    hs = _per_head_rmsnorm(h_out[:, :, None, :], params["head_norm"],
                           cfg.norm_eps)[:, :, 0, :]
    y = hs.reshape(b, 1, d).astype(x.dtype)
    x = x + y
    xn2 = rms_norm(x, params["norm2"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", xn2, params["ffn_up"].astype(x.dtype))
    u, g = jnp.split(up, 2, axis=-1)
    y2 = jax.nn.silu(g) * u
    out = x + jnp.einsum("bse,ed->bsd", y2, params["ffn_down"].astype(x.dtype))
    return out, new
