"""Mamba2 (SSD, arXiv:2405.21060-style) block built on the chunked GLA core.

State-space duality view: per head, the SSD recurrence

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * (B_t x_t^T)
    y_t = C_t^T h_t + D * x_t

is gated linear attention with lf = dt*a (a<0), li = log(dt), k=B, q=C, v=x.
A single group is used (B/C shared across heads), matching Zamba2-1.2B.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import gla
from repro.models.common import dense_init, rms_norm, split_rngs
from repro.models.xlstm import causal_conv1d, conv_decode_step

Params = Dict[str, Any]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    ssm = cfg.ssm
    di = cfg.d_model * ssm.expand
    n = ssm.state_size
    headdim = 64 if di % 64 == 0 else di // max(ssm.num_ssm_heads, 1)
    h = di // headdim
    return di, n, h, headdim


def init_mamba2_block(rng: jax.Array, cfg: ModelConfig,
                      dtype=jnp.float32) -> Params:
    d = cfg.d_model
    di, n, h, p = _dims(cfg)
    conv_w = cfg.ssm.conv_width
    conv_dim = di + 2 * n
    r = split_rngs(rng, 6)
    return {
        "norm": jnp.zeros((d,), dtype),
        # in-proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "w_in": dense_init(r[0], d, 2 * di + 2 * n + h, dtype),
        "conv": (jax.random.normal(r[1], (conv_w, conv_dim)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),   # softplus^-1-ish small dt
        "d_skip": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "w_out": dense_init(r[2], di, d, dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, n, h, p = _dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt_pre = proj[..., di + di + 2 * n:]
    return z, xbc, dt_pre


def _ssd_inputs(cfg: ModelConfig, params: Params, xbc: jax.Array,
                dt_pre: jax.Array):
    """xbc: (B,S,di+2n) post-conv; returns q,k,v,lf,li shaped for GLA."""
    di, n, h, p = _dims(cfg)
    bsz, s, _ = xbc.shape
    x = xbc[..., :di].reshape(bsz, s, h, p).transpose(0, 2, 1, 3)   # v
    bmat = xbc[..., di:di + n]
    cmat = xbc[..., di + n:]
    k = jnp.broadcast_to(bmat[:, None], (bsz, h, s, n))
    q = jnp.broadcast_to(cmat[:, None], (bsz, h, s, n))
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32)
                         + params["dt_bias"]).transpose(0, 2, 1)     # (B,H,S)
    a = -jnp.exp(params["a_log"])                                    # (H,)
    lf = dt * a[None, :, None]
    li = jnp.log(jnp.maximum(dt, 1e-9))
    return q, k, x, lf, li, dt


def mamba2_forward(params: Params, cfg: ModelConfig, x: jax.Array, *,
                   state: Optional[Params] = None, return_state: bool = False):
    di, n, h, p = _dims(cfg)
    bsz, s, d = x.shape
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", xn, params["w_in"].astype(x.dtype))
    z, xbc, dt_pre = _split_proj(cfg, proj)
    xbc = jax.nn.silu(causal_conv1d(xbc, params["conv"])
                      + params["conv_bias"].astype(x.dtype))
    q, k, v, lf, li, _ = _ssd_inputs(cfg, params, xbc, dt_pre)
    gstate = state["gla"] if state is not None else None
    y, gnew = gla.chunked_gla(q, k, v, lf, li, normalize=False,
                              chunk=cfg.ssm.chunk_size, state=gstate)
    y = y + params["d_skip"][None, :, None, None] * v.astype(jnp.float32)
    y = y.transpose(0, 2, 1, 3).reshape(bsz, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    if return_state:
        conv_w = params["conv"].shape[0]
        zc, xbc_raw, _ = _split_proj(cfg, proj)
        tail = xbc_raw[:, -(conv_w - 1):, :]
        pad = conv_w - 1 - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"gla": gnew, "conv": tail}
    return out


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    di, n, h, p = _dims(cfg)
    conv_w = cfg.ssm.conv_width
    return {"gla": gla.init_gla_state(batch, h, n, p, jnp.float32),
            "conv": jnp.zeros((batch, conv_w - 1, di + 2 * n), dtype)}


def mamba2_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                  cache: Params) -> Tuple[jax.Array, Params]:
    di, n, h, p = _dims(cfg)
    bsz = x.shape[0]
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", xn, params["w_in"].astype(x.dtype))
    z, xbc, dt_pre = _split_proj(cfg, proj)
    yc, conv_state = conv_decode_step(xbc, cache["conv"], params["conv"])
    xbc = jax.nn.silu(yc + params["conv_bias"].astype(x.dtype))
    q, k, v, lf, li, _ = _ssd_inputs(cfg, params, xbc, dt_pre)
    y1, gnew = gla.gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                   lf[:, :, 0], li[:, :, 0], cache["gla"],
                                   normalize=False)
    y1 = y1 + params["d_skip"][None, :, None] * v[:, :, 0].astype(jnp.float32)
    y = y1.reshape(bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = x + jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return out, {"gla": gnew, "conv": conv_state}
