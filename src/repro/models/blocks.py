"""Per-layer blocks with a uniform interface used by the stack assembler.

Interface (kind in {dense, moe, mlstm, slstm, mamba2, shared_attn}):

    init_block(rng, cfg, kind)                     -> params for ONE layer
    block_forward(params, cfg, kind, x, ctx)       -> (x, aux, new_cache)
    block_decode(params, cfg, kind, x, cache, ctx) -> (x, new_cache)
    init_block_cache(cfg, kind, batch, meta)       -> cache for ONE layer

``ctx`` carries positions / encoder output / layer meta (window, cross-attn)
so stacked-scan callers can slice per-layer values.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (DENSE, MAMBA2, MLSTM, MOE, SHARED_ATTN, SLSTM,
                                ModelConfig)
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import xlstm
from repro.models.attention import (attention_forward, build_cross_cache,
                                    chunk_attention_paged, decode_attention,
                                    decode_attention_paged, init_attn_cache,
                                    init_paged_attn_cache)
from repro.models.common import dense_init, layer_norm, rms_norm, split_rngs
from repro.launch.sharding import constrain_residual

Params = Dict[str, Any]


@dataclasses.dataclass
class BlockCtx:
    positions: Optional[jax.Array] = None   # (S,) absolute positions
    enc_out: Optional[jax.Array] = None     # encoder output (enc-dec only)
    prefix_len: int = 0                     # VLM prefix-LM boundary
    window: int = 0                         # sliding window for this layer
    causal: bool = True
    pos: Any = None                         # decode position: scalar or (B,)
    max_seq: int = 0                        # cache capacity (decode)
    cache_offset: int = 0                   # prefill write offset
    block_tbl: Optional[jax.Array] = None   # (B, max_logical) paged KV table
    write_mask: Optional[jax.Array] = None  # (B,) rows allowed to write KV
    dtype: Any = jnp.float32


def _norm(x, params, cfg, key):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, params[key + "_scale"], params[key + "_bias"],
                          cfg.norm_eps)
    return rms_norm(x, params[key + "_scale"], cfg.norm_eps)


def _init_norm(cfg, d, dtype):
    if cfg.norm_type == "layernorm":
        return {"_scale": jnp.ones((d,), dtype), "_bias": jnp.zeros((d,), dtype)}
    return {"_scale": jnp.zeros((d,), dtype)}


def _mlp_init(rng, cfg, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    r = split_rngs(rng, 3)
    if cfg.mlp_kind == "gelu":
        return {"w1": dense_init(r[0], d, f, dtype),
                "b1": jnp.zeros((f,), dtype),
                "w2": dense_init(r[1], f, d, dtype),
                "b2": jnp.zeros((d,), dtype)}
    return {"w_gate": dense_init(r[0], d, f, dtype),
            "w_up": dense_init(r[1], d, f, dtype),
            "w_down": dense_init(r[2], f, d, dtype)}


def _mlp(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["w1"].astype(x.dtype))
                        + params["b1"].astype(x.dtype))
        return jnp.einsum("bsf,fd->bsd", h, params["w2"].astype(x.dtype)) \
            + params["b2"].astype(x.dtype)
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype)))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", g * u, params["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_block(rng: jax.Array, cfg: ModelConfig, kind: str,
               dtype=jnp.float32, with_cross: Optional[bool] = None) -> Params:
    from repro.models.attention import init_attention
    if with_cross is None:
        with_cross = cfg.is_encdec
    r = split_rngs(rng, 4)
    if kind in (DENSE, SHARED_ATTN):
        p: Params = {"attn": init_attention(r[0], cfg, dtype=dtype),
                     "mlp": _mlp_init(r[1], cfg, dtype)}
        for k, v in _init_norm(cfg, cfg.d_model, dtype).items():
            p["ln1" + k] = v
            p["ln2" + k] = v
        if with_cross:
            p["cross"] = init_attention(r[2], cfg, cross=True, dtype=dtype)
            for k, v in _init_norm(cfg, cfg.d_model, dtype).items():
                p["lnx" + k] = v
        return p
    if kind == MOE:
        p = {"attn": init_attention(r[0], cfg, dtype=dtype),
             "moe": moe_mod.init_moe(r[1], cfg, dtype)}
        for k, v in _init_norm(cfg, cfg.d_model, dtype).items():
            p["ln1" + k] = v
            p["ln2" + k] = v
        return p
    if kind == MLSTM:
        return xlstm.init_mlstm_block(r[0], cfg, dtype)
    if kind == SLSTM:
        return xlstm.init_slstm_block(r[0], cfg, dtype)
    if kind == MAMBA2:
        return m2.init_mamba2_block(r[0], cfg, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     window: int, dtype=jnp.float32) -> Params:
    if kind in (DENSE, SHARED_ATTN, MOE):
        c: Params = {"self": init_attn_cache(cfg, batch, max_seq,
                                             window=window, dtype=dtype)}
        if cfg.is_encdec and kind != MOE:
            c["cross"] = init_attn_cache(cfg, batch, cfg.encoder_seq,
                                         kv_len=cfg.encoder_seq, dtype=dtype)
        return c
    return _init_recurrent_cache(cfg, kind, batch, dtype)


def init_block_cache_paged(cfg: ModelConfig, kind: str, batch: int,
                           num_pages: int, page_size: int,
                           dtype=jnp.float32,
                           kv_dtype: str = "float32") -> Params:
    """Paged variant: self-attention K/V lives in the shared page pool
    (no batch axis — rows address it through their block table); cross-attn
    and recurrent state stay dense per-row (fixed size, nothing to page).
    ``kv_dtype="int8"`` stores the pages quantized with per-row scales."""
    if kind in (DENSE, SHARED_ATTN, MOE):
        c: Params = {"self": init_paged_attn_cache(cfg, num_pages, page_size,
                                                   dtype=dtype,
                                                   kv_dtype=kv_dtype)}
        if cfg.is_encdec and kind != MOE:
            c["cross"] = init_attn_cache(cfg, batch, cfg.encoder_seq,
                                         kv_len=cfg.encoder_seq, dtype=dtype)
        return c
    return _init_recurrent_cache(cfg, kind, batch, dtype)


def _init_recurrent_cache(cfg: ModelConfig, kind: str, batch: int,
                          dtype) -> Params:
    if kind == MLSTM:
        return xlstm.init_mlstm_cache(cfg, batch, dtype)
    if kind == SLSTM:
        return xlstm.init_slstm_cache(cfg, batch, dtype)
    if kind == MAMBA2:
        return m2.init_mamba2_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def block_forward(params: Params, cfg: ModelConfig, kind: str, x: jax.Array,
                  ctx: BlockCtx, cache: Optional[Params] = None
                  ) -> Tuple[jax.Array, jax.Array, Optional[Params]]:
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (DENSE, SHARED_ATTN, MOE):
        h = _norm(x, params, cfg, "ln1")
        self_cache = cache.get("self") if cache else None
        att, new_self = attention_forward(
            params["attn"], cfg, h, positions=ctx.positions,
            causal=ctx.causal, window=ctx.window, prefix_len=ctx.prefix_len,
            use_rope=cfg.use_rope, cache=self_cache,
            cache_offset=ctx.cache_offset)
        # mid-block sequence-parallel point (active ShardingPolicy only):
        # the residual re-enters its (batch, "model", None) layout between
        # the attention and MLP sub-layers instead of drifting to whatever
        # layout the attention output propagated
        x = constrain_residual(x + att)
        new_cache: Optional[Params] = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache["self"] = new_self
        if "cross" in params and ctx.enc_out is not None:
            hx = _norm(x, params, cfg, "lnx")
            catt, _ = attention_forward(params["cross"], cfg, hx,
                                        positions=ctx.positions,
                                        enc_out=ctx.enc_out, causal=False,
                                        use_rope=False)
            x = x + catt
            if cache is not None and "cross" in cache:
                new_cache["cross"] = build_cross_cache(
                    params["cross"], cfg, ctx.enc_out,
                    dtype=cache["cross"]["k"].dtype)
        h2 = _norm(x, params, cfg, "ln2")
        if kind == MOE:
            y, aux = moe_mod.moe_forward(params["moe"], cfg, h2)
        else:
            y = _mlp(params["mlp"], cfg, h2)
        return x + y, aux, new_cache
    if kind == MLSTM:
        if cache is not None:
            out, st = xlstm.mlstm_forward(params, cfg, x, state=cache,
                                          return_state=True)
            return out, aux, st
        return xlstm.mlstm_forward(params, cfg, x), aux, None
    if kind == SLSTM:
        if cache is not None:
            out, st = xlstm.slstm_forward(params, cfg, x, state=cache,
                                          return_state=True)
            return out, aux, st
        return xlstm.slstm_forward(params, cfg, x), aux, None
    if kind == MAMBA2:
        if cache is not None:
            out, st = m2.mamba2_forward(params, cfg, x, state=cache,
                                        return_state=True)
            return out, aux, st
        return m2.mamba2_forward(params, cfg, x), aux, None
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# single-token decode
# ---------------------------------------------------------------------------
def block_decode(params: Params, cfg: ModelConfig, kind: str, x: jax.Array,
                 cache: Params, ctx: BlockCtx) -> Tuple[jax.Array, Params]:
    """Single-token decode; paged caches also accept a multi-token chunk
    (``x``: (B,C,d) with ``ctx.pos`` the chunk's first position and
    ``ctx.write_mask`` optionally (B,C)) — the chunked-prefill path."""
    if kind in (DENSE, SHARED_ATTN, MOE):
        h = _norm(x, params, cfg, "ln1")
        if "kp" in cache["self"] and x.shape[1] > 1:
            att, new_self = chunk_attention_paged(
                params["attn"], cfg, h, cache["self"], ctx.pos,
                ctx.block_tbl, window=ctx.window, use_rope=cfg.use_rope,
                write_mask=ctx.write_mask)
        elif "kp" in cache["self"]:
            att, new_self = decode_attention_paged(
                params["attn"], cfg, h, cache["self"], ctx.pos,
                ctx.block_tbl, window=ctx.window, use_rope=cfg.use_rope,
                write_mask=ctx.write_mask)
        else:
            att, new_self = decode_attention(params["attn"], cfg, h,
                                             cache["self"], ctx.pos,
                                             window=ctx.window,
                                             use_rope=cfg.use_rope)
        # same mid-block sequence-parallel point as block_forward (no-op
        # for S=1 decode; load-bearing for page-sized prefill chunks)
        x = constrain_residual(x + att)
        new_cache = dict(cache)
        new_cache["self"] = new_self
        if "cross" in params and "cross" in cache:
            hx = _norm(x, params, cfg, "lnx")
            catt, _ = decode_attention(params["cross"], cfg, hx,
                                       cache["cross"], ctx.pos, cross=True,
                                       use_rope=False)
            x = x + catt
        h2 = _norm(x, params, cfg, "ln2")
        if kind == MOE:
            y = moe_mod.moe_forward_decode(params["moe"], cfg, h2)
        else:
            y = _mlp(params["mlp"], cfg, h2)
        return x + y, new_cache
    if kind == MLSTM:
        return xlstm.mlstm_decode(params, cfg, x, cache)
    if kind == SLSTM:
        return xlstm.slstm_decode(params, cfg, x, cache)
    if kind == MAMBA2:
        return m2.mamba2_decode(params, cfg, x, cache)
    raise ValueError(kind)
