"""Gated linear attention core — the shared recurrence engine for the
xLSTM mLSTM block and the Mamba2 (SSD) block.

State recurrence (per batch, head):

    S_t = exp(lf_t) * S_{t-1} + exp(li_t) * k_t v_t^T        (Dk x Dv matrix)
    n_t = exp(lf_t) * n_{t-1} + exp(li_t) * k_t              (mLSTM normalizer)
    y_t = q_t S_t            [/ max(|q_t n_t|, exp(-m_t)) when normalize]

Three equivalent implementations:
  * ``recurrent_gla``  — step-by-step lax.scan (oracle; also the decode rule)
  * ``chunked_gla``    — chunk-parallel form: O(S/L) sequential steps with
                         dense (L x L) intra-chunk attention on the MXU.
                         This is the TPU adaptation of the paper-pool SSM
                         kernels: HBM->VMEM chunk streaming, MXU matmuls.
  * ``gla_decode_step``— single-token state update for serving.

The mLSTM exponential input gate is unbounded, so the xLSTM stabilizer
``m_t = max(lf_t + m_{t-1}, li_t)`` is threaded through all forms when
``normalize=True`` (the normalizer cancels the scale).  Mamba2 gates are
bounded (lf<=0, li=log dt), so the unstabilized path is used.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

State = Dict[str, jax.Array]


def init_gla_state(batch: int, heads: int, dk: int, dv: int,
                   dtype=jnp.float32) -> State:
    return {
        "S": jnp.zeros((batch, heads, dk, dv), dtype),
        "n": jnp.zeros((batch, heads, dk), dtype),
        "m": jnp.zeros((batch, heads), dtype),
    }


def _finalize(y_raw: jax.Array, n_dot: jax.Array, m_row: jax.Array,
              normalize: bool) -> jax.Array:
    if normalize:
        denom = jnp.maximum(jnp.abs(n_dot), jnp.exp(-m_row))
        return y_raw / denom[..., None]
    return y_raw


def recurrent_gla(q: jax.Array, k: jax.Array, v: jax.Array,
                  lf: jax.Array, li: jax.Array, *, normalize: bool,
                  state: Optional[State] = None) -> Tuple[jax.Array, State]:
    """Oracle step-scan.  q,k: (B,H,S,Dk); v: (B,H,S,Dv); lf,li: (B,H,S)."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    st = state or init_gla_state(b, h, dk, dv, jnp.float32)

    def step(carry, xs):
        S, n, m = carry
        qt, kt, vt, lft, lit = xs
        if normalize:
            m_new = jnp.maximum(lft + m, lit)
            fscale = jnp.exp(lft + m - m_new)
            iscale = jnp.exp(lit - m_new)
        else:
            m_new = m
            fscale = jnp.exp(lft)
            iscale = jnp.exp(lit)
        S = fscale[..., None, None] * S + iscale[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = fscale[..., None] * n + iscale[..., None] * kt
        y_raw = jnp.einsum("bhd,bhde->bhe", qt, S)
        n_dot = jnp.einsum("bhd,bhd->bh", qt, n)
        y = _finalize(y_raw, n_dot, m_new, normalize)
        return (S, n, m_new), y

    xs = tuple(jnp.moveaxis(a, 2, 0).astype(jnp.float32)
               for a in (q, k, v)) + tuple(
        jnp.moveaxis(a, 2, 0).astype(jnp.float32) for a in (lf, li))
    (S, n, m), ys = jax.lax.scan(step, (st["S"].astype(jnp.float32),
                                        st["n"].astype(jnp.float32),
                                        st["m"].astype(jnp.float32)), xs)
    y = jnp.moveaxis(ys, 0, 2).astype(q.dtype)     # (B,H,S,Dv)
    return y, {"S": S, "n": n, "m": m}


def chunked_gla(q: jax.Array, k: jax.Array, v: jax.Array,
                lf: jax.Array, li: jax.Array, *, normalize: bool,
                chunk: int = 256,
                state: Optional[State] = None) -> Tuple[jax.Array, State]:
    """Chunk-parallel form; exact (up to fp) match of ``recurrent_gla``."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    chunk = math.gcd(s, chunk)
    nc = s // chunk
    st = state or init_gla_state(b, h, dk, dv, jnp.float32)

    def resh(a, d_last):
        return jnp.moveaxis(
            a.astype(jnp.float32).reshape(b, h, nc, chunk, *d_last), 2, 0)

    qc, kc, vc = resh(q, (dk,)), resh(k, (dk,)), resh(v, (dv,))
    lfc, lic = resh(lf, ()), resh(li, ())

    neg_inf = jnp.float32(-1e30)

    # backward recomputes the (L x L) intra-chunk gate/score matrices
    # instead of saving them per chunk (same flash-style discipline as
    # attention; EXPERIMENTS.md §Perf iteration 5 — zamba2 train).
    @jax.checkpoint
    def chunk_step(carry, xs):
        S, n, m_prev = carry                      # (B,H,Dk,Dv),(B,H,Dk),(B,H)
        qb, kb, vb, lfb, lib = xs                 # (B,H,L,*)
        bcum = jnp.cumsum(lfb, axis=-1)           # inclusive: b_t
        b_last = bcum[..., -1]
        # --- intra log-weights D[t, s] = b_t - b_s + li_s (s <= t) ---------
        dmat = bcum[..., :, None] - bcum[..., None, :] + lib[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri, dmat, neg_inf)
        w_inter = bcum + m_prev[..., None]        # (B,H,L)
        if normalize:
            m_row = jnp.maximum(w_inter, jnp.max(dmat, axis=-1))
        else:
            m_row = jnp.zeros_like(w_inter)
        scores = jnp.einsum("bhtd,bhsd->bhts", qb, kb)
        wmat = scores * jnp.exp(dmat - m_row[..., None])
        y_intra = jnp.einsum("bhts,bhse->bhte", wmat, vb)
        y_inter = jnp.exp(w_inter - m_row)[..., None] * jnp.einsum(
            "bhtd,bhde->bhte", qb, S)
        n_dot = (jnp.sum(wmat, axis=-1)
                 + jnp.exp(w_inter - m_row) * jnp.einsum("bhtd,bhd->bht", qb, n))
        y = _finalize(y_intra + y_inter, n_dot, m_row, normalize)
        # --- end-of-chunk state update --------------------------------------
        g = b_last[..., None] - bcum + lib        # (B,H,L)
        if normalize:
            m_new = jnp.maximum(b_last + m_prev, jnp.max(g, axis=-1))
        else:
            m_new = m_prev
        carry_scale = jnp.exp(b_last + m_prev - m_new)
        gi = jnp.exp(g - m_new[..., None])
        S_new = carry_scale[..., None, None] * S + jnp.einsum(
            "bhld,bhle,bhl->bhde", kb, vb, gi)
        n_new = carry_scale[..., None] * n + jnp.einsum("bhld,bhl->bhd", kb, gi)
        return (S_new, n_new, m_new), y

    (S, n, m), ys = jax.lax.scan(
        chunk_step, (st["S"].astype(jnp.float32), st["n"].astype(jnp.float32),
                     st["m"].astype(jnp.float32)),
        (qc, kc, vc, lfc, lic))
    y = jnp.moveaxis(ys, 0, 2).reshape(b, h, s, dv).astype(q.dtype)
    return y, {"S": S, "n": n, "m": m}


def gla_decode_step(q: jax.Array, k: jax.Array, v: jax.Array,
                    lf: jax.Array, li: jax.Array, state: State, *,
                    normalize: bool) -> Tuple[jax.Array, State]:
    """One-token update.  q,k: (B,H,Dk); v: (B,H,Dv); lf,li: (B,H)."""
    S, n, m = (state["S"].astype(jnp.float32), state["n"].astype(jnp.float32),
               state["m"].astype(jnp.float32))
    q, k, v = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    lf, li = lf.astype(jnp.float32), li.astype(jnp.float32)
    if normalize:
        m_new = jnp.maximum(lf + m, li)
        fscale = jnp.exp(lf + m - m_new)
        iscale = jnp.exp(li - m_new)
    else:
        m_new = m
        fscale = jnp.exp(lf)
        iscale = jnp.exp(li)
    S = fscale[..., None, None] * S + iscale[..., None, None] * (
        k[..., :, None] * v[..., None, :])
    n = fscale[..., None] * n + iscale[..., None] * k
    y_raw = jnp.einsum("bhd,bhde->bhe", q, S)
    n_dot = jnp.einsum("bhd,bhd->bh", q, n)
    y = _finalize(y_raw, n_dot, m_new, normalize)
    return y, {"S": S, "n": n, "m": m_new}
