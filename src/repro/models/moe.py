"""Mixture-of-Experts FFN with top-k routing.

Scatter/capacity ("dropped") implementation — the standard TPU-friendly
formulation: tokens are scattered into per-expert buffers of fixed capacity
``C = ceil(T * top_k / E * capacity_factor)``, each expert runs a dense
batched FFN over its buffer (ECd,Edf einsums -> MXU-shaped), and results are
gathered back with router-probability combine weights.  This keeps compute
proportional to *routed* tokens (the roofline honesty requirement) while
avoiding the (T,E,C) one-hot dispatch einsum whose memory is intractable.

Expert weights use gated-SiLU FFNs.  Auxiliary load-balance loss follows
Switch/OLMoE.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, split_rngs

Params = Dict[str, Any]


def init_moe(rng: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    assert cfg.moe is not None
    e, d, f = cfg.moe.num_experts, cfg.d_model, cfg.moe.expert_d_ff
    rngs = split_rngs(rng, 4)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    return {
        "router": dense_init(rngs[0], d, e, jnp.float32),  # router in fp32
        "w_gate": (jax.random.normal(rngs[1], (e, d, f)) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(rngs[2], (e, d, f)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(rngs[3], (e, f, d)) * scale_out).astype(dtype),
    }


def moe_forward(params: Params, cfg: ModelConfig,
                x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).  Routing is per-token.

    When a distribution policy is active (production meshes), dispatch runs
    inside ``shard_map`` so the scatter/gather are DEVICE-LOCAL — GSPMD
    never partitions them.  Both the global flat dispatch (cumsum over
    B*S*K) and a batched-per-row scatter make the SPMD partitioner
    replicate multi-GB dispatch tensors on every device (measured 700 GB
    and 741 GB/device respectively for olmoe train_4k — EXPERIMENTS.md
    §Perf iterations 1a/1b).  The plain path below is the single-device
    reference semantics (also the oracle for the shard_map path)."""
    from repro.launch import sharding as shardlib
    policy = shardlib.current_policy()
    if policy is not None and x.shape[1] > 1:
        return _moe_forward_shardmap(params, cfg, x, policy)
    return _moe_forward_local(params, cfg, x)


def _moe_forward_local(params: Params, cfg: ModelConfig,
                       x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-sequence batched dispatch (single-device reference)."""
    moe = cfg.moe
    b, s, d = x.shape
    e, k = moe.num_experts, moe.top_k
    cap = int(math.ceil(s * k / e * moe.capacity_factor))
    cap = max(cap, k)

    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(router_logits, axis=-1)            # (B, S, E)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (B, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert's buffer, per row
    flat_e = top_e.reshape(b, s * k)                          # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (B, S*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot            # exclusive
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[..., None],
                                   axis=2)[..., 0]            # (B, S*K)
    keep = flat_pos < cap
    buf_e = jnp.where(keep, flat_e, e)                        # expert e = drop
    buf_p = jnp.where(keep, flat_pos, 0)

    tok_rep = jnp.repeat(x, k, axis=1).reshape(b, s * k, d)
    bidx = jnp.arange(b)[:, None]
    buffers = jnp.zeros((b, e + 1, cap, d), x.dtype)
    buffers = buffers.at[bidx, buf_e, buf_p].set(tok_rep, mode="drop")
    buffers = buffers[:, :e]                                  # (B, E, C, d)

    # batched expert FFN (gated SiLU)
    wg = params["w_gate"].astype(x.dtype)
    wu = params["w_up"].astype(x.dtype)
    wd = params["w_down"].astype(x.dtype)
    hidden = jax.nn.silu(jnp.einsum("becd,edf->becf", buffers, wg))
    hidden = hidden * jnp.einsum("becd,edf->becf", buffers, wu)
    expert_out = jnp.einsum("becf,efd->becd", hidden, wd)     # (B, E, C, d)

    # gather back
    gathered = expert_out[bidx, buf_e.clip(0, e - 1), buf_p]  # (B, S*K, d)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    weights = top_p.reshape(b, s * k, 1).astype(gathered.dtype)
    out = (gathered * weights).reshape(b, s, k, d).sum(axis=2)

    # Switch-style load-balance auxiliary loss (over all tokens)
    me = probs.mean(axis=(0, 1))                              # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e.reshape(-1)].add(
        jnp.full((b * s * k,), 1.0 / (b * s * k)))            # token fraction
    aux = e * jnp.sum(me * ce) * moe.router_aux_coef

    return out, aux


def _moe_forward_shardmap(params: Params, cfg: ModelConfig, x: jax.Array,
                          policy) -> Tuple[jax.Array, jax.Array]:
    """Expert FFN with device-local dispatch under shard_map.

    Tokens arrive sharded (batch over data/pod, seq over model — the
    sequence-parallel residual layout); each device dispatches ITS tokens
    into a local (E, C_loc, d) buffer, runs the expert FFN on its d_ff
    shard of every expert, and psums the down-projection over ``model``.
    The only collectives are the weight all-gathers GSPMD already inserts
    for FSDP and one psum per layer — no partitioned scatters."""
    import jax.experimental.shard_map as _shmap
    from jax.sharding import PartitionSpec as P

    mesh = policy.mesh
    moe = cfg.moe
    b, s, d = x.shape
    e = moe.num_experts
    baxes = None
    from repro.launch.sharding import batch_axes, _fits
    baxes = batch_axes(mesh, b)
    seq_ax = "model" if (policy.seq_parallel
                         and _fits(s, mesh, "model")) else None
    x_spec = P(baxes, seq_ax, None)
    model_axes = ("model",) if "model" in mesh.axis_names else ()
    all_axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)

    def local_fn(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        t = bl * sl
        k = moe.top_k
        cap = max(int(math.ceil(t * k / e * moe.capacity_factor)), k)
        xf = xl.reshape(t, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
        flat_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]
        keep = flat_pos < cap
        buf_e = jnp.where(keep, flat_e, e)
        buf_p = jnp.where(keep, flat_pos, 0)
        tok_rep = jnp.repeat(xf, k, axis=0)
        buffers = jnp.zeros((e + 1, cap, d), xl.dtype)
        buffers = buffers.at[buf_e, buf_p].set(tok_rep, mode="drop")[:e]
        hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buffers, wg))
        hidden = hidden * jnp.einsum("ecd,edf->ecf", buffers, wu)
        eout = jnp.einsum("ecf,efd->ecd", hidden, wd)
        if model_axes:
            eout = jax.lax.psum(eout, model_axes)   # partial d_ff shards
        gathered = eout[buf_e.clip(0, e - 1), buf_p]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        weights = top_p.reshape(-1)[:, None].astype(gathered.dtype)
        out = (gathered * weights).reshape(t, k, d).sum(1).reshape(bl, sl, d)
        # load-balance aux across ALL shards
        me = jax.lax.pmean(probs.mean(0), all_axes)
        ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(
            jnp.full((t * k,), 1.0 / (t * k)))
        ce = jax.lax.pmean(ce, all_axes)
        aux = e * jnp.sum(me * ce) * moe.router_aux_coef
        return out, aux

    fn = _shmap.shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(), P(None, None, "model"),
                  P(None, None, "model"), P(None, "model", None)),
        out_specs=(x_spec, P()),
        check_rep=False)
    out, aux = fn(x, params["router"],
                  params["w_gate"].astype(x.dtype),
                  params["w_up"].astype(x.dtype),
                  params["w_down"].astype(x.dtype))
    return out, aux


def moe_forward_decode(params: Params, cfg: ModelConfig,
                       x: jax.Array) -> jax.Array:
    """Decode-time MoE for (B, 1, d): dense-gather formulation.

    With one token per row, the capacity machinery is overhead; gather the
    K expert weight slices per token instead (B*K is small at decode)."""
    moe = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    router_logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                               params["router"])
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, moe.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    wg = params["w_gate"].astype(x.dtype)[top_e]    # (T, K, d, f)
    wu = params["w_up"].astype(x.dtype)[top_e]
    wd = params["w_down"].astype(x.dtype)[top_e]
    h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", xf, wg))
    h = h * jnp.einsum("td,tkdf->tkf", xf, wu)
    y = jnp.einsum("tkf,tkfd->tkd", h, wd)
    out = (y * top_p[..., None].astype(y.dtype)).sum(axis=1)
    return out.reshape(b, s, d)
