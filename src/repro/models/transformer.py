"""Stack assembler: composes per-layer blocks into full models with
early-exit heads and edge/cloud partitions (the paper's technique).

Layers are grouped into *segments* — maximal runs of identical
(kind, window) — and each segment's parameters are stacked along a leading
layer axis and driven by ``lax.scan`` (small HLO, production meshes compile
fast).  Segments are additionally cut at every early-exit layer, so the
paper's partition boundaries (``l_ee1``, ``l_ee2``) are always segment
boundaries and edge/cloud partitions are segment subsets.

Zamba2's shared attention block is represented as length-1 segments whose
parameters all alias ``params["shared"]``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DENSE, MOE, SHARED_ATTN, ModelConfig
from repro.launch import sharding as shardlib
from repro.models.blocks import (BlockCtx, block_decode, block_forward,
                                 init_block, init_block_cache,
                                 init_block_cache_paged)
from repro.models.common import (embed_init, layer_norm, rms_norm,
                                 sinusoidal_positions, split_rngs)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    kind: str
    window: int
    start: int          # 0-based first layer index
    length: int
    shared: bool = False

    @property
    def end(self) -> int:          # exclusive
        return self.start + self.length


def build_segments(cfg: ModelConfig) -> Tuple[SegmentSpec, ...]:
    kinds = cfg.block_kinds()
    windows = cfg.layer_windows()
    cuts = {l for l in cfg.exit_layers}          # cut AFTER 1-based layer l
    segs: List[SegmentSpec] = []
    start = 0
    for i in range(1, cfg.n_layers + 1):
        boundary = (
            i == cfg.n_layers
            or kinds[i] != kinds[i - 1]
            or windows[i] != windows[i - 1]
            or i in cuts
            or kinds[i - 1] == SHARED_ATTN       # shared blocks stand alone
            or kinds[i] == SHARED_ATTN
        )
        if boundary:
            segs.append(SegmentSpec(kind=kinds[start], window=windows[start],
                                    start=start, length=i - start,
                                    shared=kinds[start] == SHARED_ATTN))
            start = i
    return tuple(segs)


def _stack(trees: Sequence[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


class Model:
    """Pure-function model wrapper; all methods take explicit params."""

    def __init__(self, cfg: ModelConfig, param_dtype=jnp.float32,
                 compute_dtype=None):
        self.cfg = cfg.validate()
        self.segments = build_segments(cfg)
        self.param_dtype = param_dtype
        self.compute_dtype = compute_dtype or param_dtype

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, rng: jax.Array) -> Params:
        cfg, dt = self.cfg, self.param_dtype
        n_rngs = len(self.segments) + 8
        rngs = split_rngs(rng, n_rngs)
        params: Params = {
            "embed": embed_init(rngs[0], cfg.vocab_size, cfg.d_model, dt),
        }
        seg_params = []
        for si, seg in enumerate(self.segments):
            if seg.shared:
                seg_params.append({})           # alias of params["shared"]
                continue
            layer_rngs = split_rngs(rngs[1 + si], seg.length)
            seg_params.append(_stack([
                init_block(r, cfg, seg.kind, dt) for r in layer_rngs]))
        params["segments"] = tuple(seg_params)
        if any(s.shared for s in self.segments):
            params["shared"] = init_block(rngs[-6], cfg, SHARED_ATTN, dt,
                                          with_cross=False)
        params["final_norm"] = jnp.zeros((cfg.d_model,), dt) \
            if cfg.norm_type == "rms" else {
                "scale": jnp.ones((cfg.d_model,), dt),
                "bias": jnp.zeros((cfg.d_model,), dt)}
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(rngs[-5], cfg.vocab_size,
                                           cfg.d_model, dt)
        # per-exit read-out norms (heads share the unembedding — EE-Tuning
        # style tied heads; see DESIGN.md)
        params["exit_norms"] = {
            str(l): jnp.zeros((cfg.d_model,), dt) for l in cfg.exit_layers}
        if cfg.is_encdec:
            enc_rngs = split_rngs(rngs[-4], cfg.encoder_layers)
            params["encoder"] = {
                "layers": _stack([init_block(r, cfg, DENSE, dt,
                                             with_cross=False)
                                  for r in enc_rngs]),
                "norm": jnp.zeros((cfg.d_model,), dt)
                if cfg.norm_type == "rms" else {
                    "scale": jnp.ones((cfg.d_model,), dt),
                    "bias": jnp.zeros((cfg.d_model,), dt)},
            }
        if cfg.vision_tokens:
            params["vis_proj"] = (
                jax.random.normal(rngs[-3], (cfg.d_model, cfg.d_model))
                / jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32))).astype(dt)
        return params

    def param_specs(self) -> Params:
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        return jax.eval_shape(self.init, rng)

    # ------------------------------------------------------------------
    # norms / heads
    # ------------------------------------------------------------------
    def _final_norm(self, params: Params, x: jax.Array) -> jax.Array:
        if self.cfg.norm_type == "layernorm":
            fn = params["final_norm"]
            return layer_norm(x, fn["scale"], fn["bias"], self.cfg.norm_eps)
        return rms_norm(x, params["final_norm"], self.cfg.norm_eps)

    def unembed_weight(self, params: Params) -> jax.Array:
        """(V, d) read-out weight (tied or separate)."""
        return params.get("lm_head", params["embed"])

    def logits(self, params: Params, x: jax.Array) -> jax.Array:
        w = self.unembed_weight(params)
        out = jnp.einsum("bsd,vd->bsv", self._final_norm(params, x),
                         w.astype(x.dtype))
        return shardlib.constrain_logits(out)

    def exit_logits(self, params: Params, layer: int,
                    x: jax.Array) -> jax.Array:
        scale = params["exit_norms"][str(layer)]
        h = rms_norm(x, scale, self.cfg.norm_eps)
        w = self.unembed_weight(params)
        out = jnp.einsum("bsd,vd->bsv", h, w.astype(x.dtype))
        return shardlib.constrain_logits(out)

    # ------------------------------------------------------------------
    # embedding front-ends
    # ------------------------------------------------------------------
    def embed_tokens(self, params: Params, tokens: jax.Array,
                     pos_offset: Any = 0) -> jax.Array:
        """``pos_offset`` may be a scalar or a per-row (B,) position vector
        (continuous batching: rows decode at independent offsets)."""
        x = params["embed"][tokens].astype(self.compute_dtype)
        if not self.cfg.use_rope:
            s = tokens.shape[1]
            off = jnp.asarray(pos_offset)
            if off.ndim == 1:                      # (B,) -> (B,S) positions
                idx = off[:, None] + jnp.arange(s)
            else:
                idx = off + jnp.arange(s)
            x = x + sinusoidal_positions(idx, self.cfg.d_model).astype(x.dtype)
        return x

    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """Whisper encoder over precomputed conv-frontend frames (B,Se,d)."""
        cfg = self.cfg
        x = frames.astype(self.compute_dtype)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
        ctx = BlockCtx(positions=jnp.arange(x.shape[1]), causal=False,
                       dtype=self.compute_dtype)

        def body(h, p):
            h, _, _ = block_forward(p, cfg, DENSE, h, ctx)
            return shardlib.constrain_residual(h), None

        x, _ = jax.lax.scan(jax.checkpoint(body), x,
                            params["encoder"]["layers"])
        if cfg.norm_type == "layernorm":
            n = params["encoder"]["norm"]
            return layer_norm(x, n["scale"], n["bias"], cfg.norm_eps)
        return rms_norm(x, params["encoder"]["norm"], cfg.norm_eps)

    def embed_inputs(self, params: Params, batch: Dict[str, jax.Array]
                     ) -> Tuple[jax.Array, BlockCtx]:
        """Training/prefill front-end: returns (x, ctx)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = None
        prefix = 0
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
            x = self.embed_tokens(params, tokens)
        elif cfg.vision_tokens:
            vis = jnp.einsum("bpd,de->bpe",
                             batch["patches"].astype(self.compute_dtype),
                             params["vis_proj"].astype(self.compute_dtype))
            x = jnp.concatenate([vis, self.embed_tokens(params, tokens)],
                                axis=1)
            prefix = vis.shape[1]
        else:
            x = self.embed_tokens(params, tokens)
        ctx = BlockCtx(positions=jnp.arange(x.shape[1]), enc_out=enc_out,
                       prefix_len=prefix, dtype=self.compute_dtype)
        return x, ctx

    # ------------------------------------------------------------------
    # segment execution
    # ------------------------------------------------------------------
    def _seg_params(self, params: Params, si: int) -> Params:
        seg = self.segments[si]
        return params["shared"] if seg.shared else params["segments"][si]

    def run_segments(self, params: Params, x: jax.Array, ctx: BlockCtx,
                     seg_indices: Sequence[int],
                     caches: Optional[Dict[int, Params]] = None,
                     collect_exits: bool = True, remat: bool = False):
        """Full-seq execution of the given segments.

        Returns (x, exit_hiddens {1-based layer: hidden}, aux, new_caches)."""
        cfg = self.cfg
        exit_set = set(cfg.exit_layers) if collect_exits else set()
        exit_hiddens: Dict[int, jax.Array] = {}
        aux_total = jnp.zeros((), jnp.float32)
        new_caches: Dict[int, Params] = {}
        x = shardlib.constrain_residual(x)
        for si in seg_indices:
            seg = self.segments[si]
            sctx = dataclasses.replace(ctx, window=seg.window)
            p = self._seg_params(params, si)
            cache = caches.get(si) if caches is not None else None
            if seg.shared:
                x, aux, nc = block_forward(p, cfg, seg.kind, x, sctx,
                                           cache=cache)
                x = shardlib.constrain_residual(x)
                aux_total = aux_total + aux
            else:
                def body(h, inp):
                    lp, lc = inp
                    h, aux, nc = block_forward(lp, cfg, seg.kind, h, sctx,
                                               cache=lc)
                    return shardlib.constrain_residual(h), (aux, nc)

                if remat:
                    body = jax.checkpoint(body)
                x, (auxs, nc) = jax.lax.scan(body, x, (p, cache))
                aux_total = aux_total + jnp.sum(auxs)
            if cache is not None:
                new_caches[si] = nc
            if seg.end in exit_set:
                exit_hiddens[seg.end] = x
        return x, exit_hiddens, aux_total, new_caches

    def decode_segments(self, params: Params, x: jax.Array, ctx: BlockCtx,
                        seg_indices: Sequence[int], caches: Dict[int, Params],
                        collect_exits: bool = True):
        """Single-token execution.  Returns (x, exit_hiddens, new_caches)."""
        cfg = self.cfg
        exit_set = set(cfg.exit_layers) if collect_exits else set()
        exit_hiddens: Dict[int, jax.Array] = {}
        new_caches: Dict[int, Params] = {}
        # sequence-parallel constraint under an active ShardingPolicy:
        # a no-op for single-token decode (S=1 can't split), load-bearing
        # for the chunked-prefill path that decodes page-sized chunks
        x = shardlib.constrain_residual(x)
        for si in seg_indices:
            seg = self.segments[si]
            sctx = dataclasses.replace(ctx, window=seg.window)
            p = self._seg_params(params, si)
            cache = caches[si]
            if seg.shared:
                x, nc = block_decode(p, cfg, seg.kind, x, cache, sctx)
                x = shardlib.constrain_residual(x)
            else:
                def body(h, inp):
                    lp, lc = inp
                    h, nc = block_decode(lp, cfg, seg.kind, h, lc, sctx)
                    return shardlib.constrain_residual(h), nc

                x, nc = jax.lax.scan(body, x, (p, cache))
            new_caches[si] = nc
            if seg.end in exit_set:
                exit_hiddens[seg.end] = x
        return x, exit_hiddens, new_caches

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int,
                   seg_indices: Optional[Sequence[int]] = None,
                   dtype=None) -> Dict[int, Params]:
        cfg = self.cfg
        dt = dtype or self.compute_dtype
        seg_indices = (range(len(self.segments)) if seg_indices is None
                       else seg_indices)
        caches: Dict[int, Params] = {}
        for si in seg_indices:
            seg = self.segments[si]
            per_layer = [init_block_cache(cfg, seg.kind, batch, max_seq,
                                          seg.window, dt)
                         for _ in range(seg.length)]
            caches[si] = _stack(per_layer) if not seg.shared else per_layer[0]
        return caches

    def init_paged_cache(self, batch: int, num_pages: int, page_size: int,
                         seg_indices: Optional[Sequence[int]] = None,
                         dtype=None, kv_dtype: str = "float32"
                         ) -> Dict[int, Params]:
        """Block-paged caches: self-attention K/V is pooled across rows in
        ``num_pages`` pages of ``page_size`` tokens (plus a trash page) and
        addressed through a per-row block table passed to ``decode_step``;
        cross-attention / recurrent state stays dense per row.
        ``kv_dtype="int8"`` stores pages quantized with per-row scales."""
        cfg = self.cfg
        dt = dtype or self.compute_dtype
        seg_indices = (range(len(self.segments)) if seg_indices is None
                       else seg_indices)
        caches: Dict[int, Params] = {}
        for si in seg_indices:
            seg = self.segments[si]
            per_layer = [init_block_cache_paged(cfg, seg.kind, batch,
                                                num_pages, page_size, dt,
                                                kv_dtype=kv_dtype)
                         for _ in range(seg.length)]
            caches[si] = _stack(per_layer) if not seg.shared else per_layer[0]
        return caches

    def attention_only(self, seg_indices: Optional[Sequence[int]] = None
                       ) -> bool:
        """True when every segment is attention-style (KV-cached).  Such
        partitions tolerate right-padded prefill: pad positions are causally
        invisible to real tokens and their cache entries can be invalidated
        afterwards.  Recurrent (SSM/xLSTM) segments cannot — their state
        advances through pad tokens irreversibly."""
        seg_indices = (range(len(self.segments)) if seg_indices is None
                       else seg_indices)
        return all(self.segments[si].kind in (DENSE, SHARED_ATTN, MOE)
                   for si in seg_indices)

    def invalidate_cache_after(self, caches: Dict[int, Params],
                               true_len: Any) -> Dict[int, Params]:
        """Mark self-attention cache entries at ring slots >= true_len as
        invalid (pos = -1).  Used after a right-padded prefill so the pad
        positions never participate in decode attention; decode overwrites
        each slot before reading it, so the row stays correct as generation
        advances past ``true_len``."""
        def fix(c: Params) -> Params:
            if not isinstance(c, dict):
                return c
            if "pos" in c and "k" in c:            # self-attn ring cache
                s = c["pos"].shape[-1]
                keep = jnp.arange(s) < true_len
                return {**c, "pos": jnp.where(keep, c["pos"], -1)}
            return {k: (fix(v) if k != "cross" else v) for k, v in c.items()}
        return {si: fix(c) for si, c in caches.items()}

    def cache_specs(self, batch: int, max_seq: int,
                    seg_indices: Optional[Sequence[int]] = None,
                    dtype=None):
        return jax.eval_shape(
            functools.partial(self.init_cache, batch, max_seq, seg_indices,
                              dtype))

    # ------------------------------------------------------------------
    # public entry points
    # ------------------------------------------------------------------
    def all_segments(self) -> Tuple[int, ...]:
        return tuple(range(len(self.segments)))

    def edge_segments(self, l_ee2: Optional[int] = None) -> Tuple[int, ...]:
        l_ee2 = l_ee2 or (self.cfg.exit_layers[-1] if self.cfg.exit_layers
                          else self.cfg.n_layers)
        return tuple(i for i, s in enumerate(self.segments) if s.end <= l_ee2)

    def cloud_segments(self, l_ee1: Optional[int] = None) -> Tuple[int, ...]:
        l_ee1 = l_ee1 or (self.cfg.exit_layers[0] if self.cfg.exit_layers
                          else 0)
        return tuple(i for i, s in enumerate(self.segments)
                     if s.start >= l_ee1)

    def forward_train(self, params: Params, batch: Dict[str, jax.Array]
                      ) -> Dict[str, Any]:
        """Full forward with all exit logits (multi-exit training)."""
        x, ctx = self.embed_inputs(params, batch)
        x, exit_hiddens, aux, _ = self.run_segments(
            params, x, ctx, self.all_segments(), remat=True)
        out = {
            "logits": self.logits(params, x),
            "exit_logits": {l: self.exit_logits(params, l, h)
                            for l, h in exit_hiddens.items()},
            "aux_loss": aux,
            "prefix_len": ctx.prefix_len,
        }
        return out

    def forward_train_hiddens(self, params: Params,
                              batch: Dict[str, jax.Array]) -> Dict[str, Any]:
        """Training forward that stops at hidden states (no unembedding) —
        pairs with ``loss.multi_exit_loss_fused`` (chunked fused CE)."""
        x, ctx = self.embed_inputs(params, batch)
        x, exit_hiddens, aux, _ = self.run_segments(
            params, x, ctx, self.all_segments(), remat=True)
        return {"final": x, "exits": exit_hiddens, "aux_loss": aux,
                "prefix_len": ctx.prefix_len}

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                caches: Dict[int, Params],
                seg_indices: Optional[Sequence[int]] = None):
        """Full-sequence pass that fills caches.  Returns
        (last-position hidden, exit_hiddens, new_caches, ctx-extras)."""
        seg_indices = seg_indices or self.all_segments()
        x, ctx = self.embed_inputs(params, batch)
        x, exit_hiddens, _, new_caches = self.run_segments(
            params, x, ctx, seg_indices, caches=caches)
        return x, exit_hiddens, new_caches, ctx

    def decode_step(self, params: Params, token: jax.Array,
                    caches: Dict[int, Params], pos: jax.Array,
                    seg_indices: Optional[Sequence[int]] = None,
                    collect_exits: bool = True,
                    block_tbl: Optional[jax.Array] = None,
                    write_mask: Optional[jax.Array] = None):
        """token: (B,1) -> (final hidden (B,1,d), exit_hiddens, caches).
        ``pos`` is a scalar or a per-row (B,) position vector.  Paged caches
        additionally need ``block_tbl`` (B, max_logical); ``write_mask``
        (B,) bool redirects masked rows' KV writes to the trash page."""
        seg_indices = seg_indices or self.all_segments()
        x = self.embed_tokens(params, token, pos_offset=pos)
        ctx = BlockCtx(pos=pos, block_tbl=block_tbl, write_mask=write_mask,
                       dtype=self.compute_dtype)
        return self.decode_segments(params, x, ctx, seg_indices, caches,
                                    collect_exits=collect_exits)

    def decode_from_hidden(self, params: Params, hidden: jax.Array,
                           caches: Dict[int, Params], pos: jax.Array,
                           seg_indices: Sequence[int],
                           block_tbl: Optional[jax.Array] = None,
                           write_mask: Optional[jax.Array] = None):
        """Cloud-partition decode: continue from an uploaded hidden state."""
        ctx = BlockCtx(pos=pos, block_tbl=block_tbl, write_mask=write_mask,
                       dtype=self.compute_dtype)
        return self.decode_segments(params, hidden, ctx, seg_indices, caches,
                                    collect_exits=False)
