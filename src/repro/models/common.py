"""Shared low-level layers: norms, rotary embeddings, initializers."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                             # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings.

    ``seq`` may be an int (returns (seq, d)) or a positions array
    (returns (*seq.shape, d)) — the latter avoids materializing huge tables
    for long decode positions."""
    half = d_model // 2
    log_timescale = jnp.log(10000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    pos = (jnp.arange(seq, dtype=jnp.float32) if isinstance(seq, int)
           else jnp.asarray(seq, jnp.float32))
    scaled = pos[..., None] * inv
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


# ---------------------------------------------------------------------------
# Initializers (explicit rng threading; shapes only when used via eval_shape)
# ---------------------------------------------------------------------------
def dense_init(rng: jax.Array, d_in: int, d_out: int,
               dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(rng: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02).astype(dtype)


@dataclasses.dataclass(frozen=True)
class RunDtypes:
    param: Any = jnp.float32
    compute: Any = jnp.float32

    @staticmethod
    def bf16() -> "RunDtypes":
        return RunDtypes(param=jnp.bfloat16, compute=jnp.bfloat16)


def split_rngs(rng: jax.Array, n: int):
    return list(jax.random.split(rng, n))
