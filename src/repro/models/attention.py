"""Multi-head attention: GQA, optional bias, RoPE, sliding window,
prefix-LM masks, cross-attention, chunked (flash-style) long-sequence path,
banded path for sliding windows, and single-token decode over two KV cache
layouts:

  * **dense rings** (``init_attn_cache`` / ``decode_attention``) — one
    ``(B, S)`` ring per layer, writes at ``pos % S``; memory is
    ``B x max_seq`` whatever the streams actually use;
  * **block pages** (``init_paged_attn_cache`` / ``decode_attention_paged``)
    — K/V live in ``(num_pages, page_size)`` pages shared by all rows and
    are addressed through a per-row block table (see
    ``repro.core.paging``); logical slot ``s`` always holds position ``s``
    (no wrap), unmapped rows write to the trash page.

Pure functions over explicit parameter pytrees.  The Pallas flash-decode
kernels in ``repro.kernels.decode_attn`` mirror ``decode_attention`` (ring)
and the paged gather (block table) and are validated against them.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init, split_rngs

Params = Dict[str, Any]

_DIRECT_LIMIT = 1 << 22   # Sq*Sk above this -> chunked path
_Q_CHUNK = 512
_KV_CHUNK = 1024


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_attention(rng: jax.Array, cfg: ModelConfig, *, cross: bool = False,
                   dtype=jnp.float32) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    rngs = split_rngs(rng, 4)
    p: Params = {
        "wq": dense_init(rngs[0], d, h * hd, dtype),
        "wk": dense_init(rngs[1], d, kv * hd, dtype),
        "wv": dense_init(rngs[2], d, kv * hd, dtype),
        "wo": dense_init(rngs[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    del cross  # same parameter structure; kv source differs at call time
    return p


def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
                 kv_src: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    hd, h, kv = cfg.resolved_head_dim, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", kv_src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", kv_src, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(*q.shape[:2], h, hd)
    k = k.reshape(*k.shape[:2], kv, hd)
    v = v.reshape(*v.shape[:2], kv, hd)
    return q, k, v


# ---------------------------------------------------------------------------
# Masked softmax attention cores
# ---------------------------------------------------------------------------
def _mask_logits(logits: jax.Array, qpos: jax.Array, kpos: jax.Array,
                 causal: bool, window: int, prefix_len: int) -> jax.Array:
    """logits: (..., Sq, Sk); qpos: (Sq,), kpos: (Sk,)."""
    ok = jnp.ones(logits.shape[-2:], bool)
    if causal:
        allowed = kpos[None, :] <= qpos[:, None]
        if prefix_len:
            allowed = allowed | (kpos[None, :] < prefix_len)
        ok &= allowed
    if window:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    return jnp.where(ok, logits, -jnp.inf)


def _direct_attention(q, k, v, qpos, kpos, *, causal, window, prefix_len,
                      scale) -> jax.Array:
    """q: (B,Sq,H,D), k/v: (B,Sk,KV,D) -> (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = _mask_logits(logits, qpos, kpos, causal, window, prefix_len)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)          # fully-masked rows
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def _chunked_attention(q, k, v, qpos, kpos, *, causal, window, prefix_len,
                       scale, q_chunk=_Q_CHUNK, kv_chunk=_KV_CHUNK) -> jax.Array:
    """Two-level online-softmax scan; memory O(q_chunk * kv_chunk)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    q_chunk = math.gcd(sq, q_chunk)
    kv_chunk = math.gcd(sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk

    qg = q.reshape(b, nq, q_chunk, kvh, g, d)
    qpos_c = qpos.reshape(nq, q_chunk)
    kc = k.reshape(b, nk, kv_chunk, kvh, d)
    vc = v.reshape(b, nk, kv_chunk, kvh, d)
    kpos_c = kpos.reshape(nk, kv_chunk)

    # flash-style memory discipline: checkpoint both scan bodies so the
    # backward pass RECOMPUTES the per-chunk probability tiles instead of
    # saving the full O(S^2) f32 attention matrix (measured 16 GB/device
    # per layer for command-r train_4k — EXPERIMENTS.md §Perf iteration 2).
    @jax.checkpoint
    def q_body(_, qi):
        qblk, qp = qi                             # (b,qc,kvh,g,d), (qc,)
        acc0 = jnp.zeros((b, kvh, g, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, kvh, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)

        @jax.checkpoint
        def kv_body(carry, ki):
            acc, m, l = carry
            kblk, vblk, kp = ki
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            logits = _mask_logits(logits, qp, kp, causal, window, prefix_len)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(logits - m_safe[..., None])
            p = jnp.where(jnp.isfinite(logits), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            l = l * corr + jnp.sum(p, axis=-1)
            return (acc, m_new, l), None

        (acc, _, l), _ = jax.lax.scan(
            kv_body, (acc0, m0, l0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kpos_c))
        out = acc / jnp.maximum(l[..., None], 1e-30)   # (b,kvh,g,qc,d)
        return None, out

    _, outs = jax.lax.scan(q_body, None,
                           (jnp.moveaxis(qg, 1, 0), qpos_c))
    # outs: (nq, b, kvh, g, qc, d)
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5)).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def _banded_attention(q, k, v, qpos, kpos, *, window, scale,
                      q_chunk=_Q_CHUNK) -> jax.Array:
    """Sliding-window causal attention with exact O(S*window) cost: each query
    chunk attends only to the kv band [chunk_start - window, chunk_end)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q_chunk = math.gcd(sq, q_chunk)
    nq = sq // q_chunk
    band = window + q_chunk
    # pad kv on the left so every band slice is in range
    pad = band
    kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    kpos_p = jnp.pad(kpos, (pad, 0), constant_values=-10 ** 9)

    qg = q.reshape(b, nq, q_chunk, kvh, g, d)
    qpos_c = qpos.reshape(nq, q_chunk)
    starts = jnp.arange(nq) * q_chunk          # band end = start + q_chunk

    @jax.checkpoint
    def body(_, xs):
        qblk, qp, start = xs
        kb = jax.lax.dynamic_slice_in_dim(kp, start + pad + q_chunk - band, band, 1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start + pad + q_chunk - band, band, 1)
        kpb = jax.lax.dynamic_slice_in_dim(kpos_p, start + pad + q_chunk - band,
                                           band, 0)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kb,
                            preferred_element_type=jnp.float32) * scale
        logits = _mask_logits(logits, qp, kpb, True, window, 0)
        w = jax.nn.softmax(logits, axis=-1)
        w = jnp.where(jnp.isnan(w), 0.0, w)
        out = jnp.einsum("bkgqs,bskd->bkgqd", w.astype(vb.dtype), vb,
                         preferred_element_type=jnp.float32)
        return None, out

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qg, 1, 0), qpos_c, starts))
    out = jnp.transpose(outs, (1, 0, 4, 2, 3, 5)).reshape(b, sq, h, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def init_attn_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
                    window: int = 0, kv_len: Optional[int] = None,
                    dtype=jnp.float32) -> Params:
    s = kv_len if kv_len is not None else (min(max_seq, window) if window
                                           else max_seq)
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s, kvh, hd), dtype),
        "v": jnp.zeros((batch, s, kvh, hd), dtype),
        "pos": jnp.full((batch, s), -1, jnp.int32),
    }


def _cache_write(cache: Params, k: jax.Array, v: jax.Array,
                 positions: jax.Array, offset) -> Params:
    """Write S new kv entries at ring positions (offset..offset+S-1) % size."""
    size = cache["k"].shape[1]
    s = k.shape[1]
    if s == size and isinstance(offset, int) and offset == 0:
        pos = jnp.broadcast_to(positions[None, :], cache["pos"].shape)
        return {"k": k.astype(cache["k"].dtype),
                "v": v.astype(cache["v"].dtype), "pos": pos.astype(jnp.int32)}
    if s > size:
        # only the last `size` entries survive in the ring
        k, v, positions = k[:, s - size:], v[:, s - size:], positions[s - size:]
        s = size
    idx = (positions % size).astype(jnp.int32)
    ck = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
    cp = cache["pos"].at[:, idx].set(
        jnp.broadcast_to(positions[None, :], (k.shape[0], s)).astype(jnp.int32))
    return {"k": ck, "v": cv, "pos": cp}


# ---------------------------------------------------------------------------
# Paged caches (block tables; see repro.core.paging)
# ---------------------------------------------------------------------------
def quantize_kv_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row int8 quantization of K/V entries: one absmax scale per
    ``(..., kv_head)`` row over ``head_dim`` — the same scaling as the
    transport quantizer (``repro.kernels.quantize``).

    x: (..., KV, d) -> (q int8 (..., KV, d), scale fp32 (..., KV))."""
    from repro.kernels.quantize.ref import quantize_int8_ref
    q, s = quantize_int8_ref(x)
    return q, s[..., 0]


def init_paged_attn_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                          *, dtype=jnp.float32,
                          kv_dtype: str = "float32") -> Params:
    """Page-pool KV storage for ONE layer.  Physical page 0 is the trash
    page (writes of unmapped rows land there); ``pos = -1`` marks an empty
    page slot, so a freshly (re)allocated page is invisible to attention
    until it is written.

    ``kv_dtype="int8"`` stores pages quantized per page-row: ``kp``/``vp``
    become int8 and per-row absmax scales ride alongside as ``ks``/``vs``
    ``(P+1, page_size, KV)`` float32 — page axis 0 like ``kp``, so every
    page-axis consumer (gather/scatter/swap) handles them generically."""
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    p = num_pages + 1                              # + trash page
    if kv_dtype == "int8":
        return {
            "kp": jnp.zeros((p, page_size, kvh, hd), jnp.int8),
            "vp": jnp.zeros((p, page_size, kvh, hd), jnp.int8),
            "ks": jnp.zeros((p, page_size, kvh), jnp.float32),
            "vs": jnp.zeros((p, page_size, kvh), jnp.float32),
            "pos": jnp.full((p, page_size), -1, jnp.int32),
        }
    if kv_dtype != "float32":
        raise ValueError(f"kv_dtype must be 'float32' or 'int8', "
                         f"got {kv_dtype!r}")
    return {
        "kp": jnp.zeros((p, page_size, kvh, hd), dtype),
        "vp": jnp.zeros((p, page_size, kvh, hd), dtype),
        "pos": jnp.full((p, page_size), -1, jnp.int32),
    }


def paged_scatter_prefill(cache: Params, row: Params,
                          pages: jax.Array) -> Params:
    """Scatter a single-row dense prefill cache into physical pages.

    ``row``: dense cache {"k": (1, L, KV, d), ...} as produced by prefill
    on one stream (ring wide enough that slot ``s`` holds position ``s``).
    ``pages``: (ceil(L / page_size),) physical page ids; entries ``< 0``
    redirect to the trash page (right-pad positions beyond the pages the
    allocator actually granted — their ``pos`` is already -1)."""
    ps = cache["kp"].shape[1]
    n_lp = pages.shape[0]
    dest = jnp.where(pages >= 0, pages, 0).astype(jnp.int32)

    def tiles(x, fill):
        x = x[0][:n_lp * ps]                       # drop batch axis, trim ring
        pad = n_lp * ps - x.shape[0]
        if pad:
            cfgpad = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
            x = jnp.pad(x, cfgpad, constant_values=fill)
        return x.reshape((n_lp, ps) + x.shape[1:])

    out = {"pos": cache["pos"].at[dest].set(tiles(row["pos"], -1).astype(
        jnp.int32))}
    if "ks" in cache:                              # int8 pages + scales
        qk, sk = quantize_kv_rows(row["k"])
        qv, sv = quantize_kv_rows(row["v"])
        out["kp"] = cache["kp"].at[dest].set(tiles(qk, 0))
        out["vp"] = cache["vp"].at[dest].set(tiles(qv, 0))
        out["ks"] = cache["ks"].at[dest].set(tiles(sk, 0.0))
        out["vs"] = cache["vs"].at[dest].set(tiles(sv, 0.0))
    else:
        out["kp"] = cache["kp"].at[dest].set(tiles(row["k"], 0).astype(
            cache["kp"].dtype))
        out["vp"] = cache["vp"].at[dest].set(tiles(row["v"], 0).astype(
            cache["vp"].dtype))
    return out


def paged_reset_pages(cache: Params, pages: jax.Array) -> Params:
    """Invalidate the given physical pages (``pos = -1``) so a page freed
    from a retired stream never leaks stale K/V once reallocated.  Entries
    ``< 0`` redirect to the trash page (already invalid)."""
    dest = jnp.where(pages >= 0, pages, 0).astype(jnp.int32)
    return {**cache, "pos": cache["pos"].at[dest].set(-1)}


def paged_gather(cache: Params, block_tbl: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Materialize the logical (B, max_logical*page_size) K/V view of a
    paged cache through the block table (unmapped pages read the trash page
    and are masked via ``pos = -1``)."""
    b, n_lp = block_tbl.shape
    ps = cache["kp"].shape[1]
    phys = jnp.where(block_tbl >= 0, block_tbl, 0)
    k, v = cache["kp"][phys], cache["vp"][phys]
    if "ks" in cache:                              # dequantize int8 pages
        k = k.astype(jnp.float32) * cache["ks"][phys][..., None]
        v = v.astype(jnp.float32) * cache["vs"][phys][..., None]
    k = k.reshape(b, n_lp * ps, *k.shape[3:])
    v = v.reshape(b, n_lp * ps, *v.shape[3:])
    kpos = jnp.where(block_tbl[:, :, None] >= 0, cache["pos"][phys],
                     -1).reshape(b, n_lp * ps)
    return k, v, kpos


# ---------------------------------------------------------------------------
# Public forwards
# ---------------------------------------------------------------------------
def attention_forward(params: Params, cfg: ModelConfig, x: jax.Array, *,
                      positions: Optional[jax.Array] = None,
                      enc_out: Optional[jax.Array] = None,
                      causal: bool = True,
                      window: int = 0,
                      prefix_len: int = 0,
                      use_rope: bool = True,
                      cache: Optional[Params] = None,
                      cache_offset: int = 0) -> Tuple[jax.Array, Optional[Params]]:
    """Full-sequence attention (training / prefill).

    ``enc_out`` switches to cross-attention (no mask, no rope on kv).
    Returns (output, updated_cache_or_None)."""
    b, s, _ = x.shape
    kv_src = enc_out if enc_out is not None else x
    sk = kv_src.shape[1]
    q, k, v = _project_qkv(params, cfg, x, kv_src)
    if positions is None:
        positions = jnp.arange(s)
    kpos = jnp.arange(sk) if enc_out is not None else positions
    if use_rope and enc_out is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    scale = 1.0 / math.sqrt(cfg.resolved_head_dim)
    cross = enc_out is not None

    if cross:
        out = (_direct_attention if s * sk <= _DIRECT_LIMIT else
               _chunked_attention)(q, k, v, positions, kpos, causal=False,
                                   window=0, prefix_len=0, scale=scale)
    elif window and s > window:
        out = _banded_attention(q, k, v, positions, kpos, window=window,
                                scale=scale)
    elif s * sk <= _DIRECT_LIMIT:
        out = _direct_attention(q, k, v, positions, kpos, causal=causal,
                                window=window, prefix_len=prefix_len,
                                scale=scale)
    else:
        out = _chunked_attention(q, k, v, positions, kpos, causal=causal,
                                 window=window, prefix_len=prefix_len,
                                 scale=scale)

    new_cache = None
    if cache is not None:
        new_cache = _cache_write(cache, k, v, kpos, cache_offset)
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1),
                   params["wo"].astype(x.dtype))
    return y, new_cache


def decode_attention(params: Params, cfg: ModelConfig, x: jax.Array,
                     cache: Params, pos: jax.Array, *,
                     window: int = 0, use_rope: bool = True,
                     cross: bool = False,
                     update_cache: bool = True) -> Tuple[jax.Array, Params]:
    """Single-token decode.  x: (B,1,d); pos: scalar int32 position or a
    per-row (B,) position vector (continuous batching: every row decodes at
    its own sequence offset).  For ``cross=True`` the cache holds precomputed
    encoder kv (no update)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    q = q.reshape(b, 1, h, hd)
    if use_rope and not cross:
        q = apply_rope(q, pos_b[:, None], cfg.rope_theta)

    if cross:
        k, v, kpos = cache["k"], cache["v"], cache["pos"]
        new_cache = cache
    else:
        knew = jnp.einsum("bsd,de->bse", x, params["wk"].astype(x.dtype))
        vnew = jnp.einsum("bsd,de->bse", x, params["wv"].astype(x.dtype))
        if "bk" in params:
            knew = knew + params["bk"].astype(x.dtype)
            vnew = vnew + params["bv"].astype(x.dtype)
        knew = knew.reshape(b, 1, kvh, hd)
        vnew = vnew.reshape(b, 1, kvh, hd)
        if use_rope:
            knew = apply_rope(knew, pos_b[:, None], cfg.rope_theta)
        if update_cache:
            size = cache["k"].shape[1]
            slot = (pos_b % size).astype(jnp.int32)
            bidx = jnp.arange(b)
            ck = cache["k"].at[bidx, slot].set(
                knew[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, slot].set(
                vnew[:, 0].astype(cache["v"].dtype))
            cp = cache["pos"].at[bidx, slot].set(pos_b)
            cache = {"k": ck, "v": cv, "pos": cp}
        k, v, kpos = cache["k"], cache["v"], cache["pos"]
        new_cache = cache

    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    valid = kpos >= 0
    if not cross:
        valid &= kpos <= pos_b[:, None]
        if window:
            valid &= (pos_b[:, None] - kpos) < window
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache


def decode_attention_paged(params: Params, cfg: ModelConfig, x: jax.Array,
                           cache: Params, pos: jax.Array,
                           block_tbl: jax.Array, *,
                           window: int = 0, use_rope: bool = True,
                           write_mask: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, Params]:
    """Single-token decode over a block-paged KV cache.

    x: (B,1,d); pos: scalar or per-row (B,) positions; block_tbl:
    (B, max_logical) physical page ids (-1 = unallocated).  Each row writes
    its new K/V at page ``block_tbl[b, pos // page_size]``, slot
    ``pos % page_size``; rows without a mapping there — inactive slots, or
    rows excluded by ``write_mask`` (masked cloud step) — are redirected to
    the trash page with ``pos = -1``, so no cache merge is needed
    afterwards.  Attention then gathers the logical K/V view through the
    table and masks exactly like the dense ring path."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    ps = cache["kp"].shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    knew = jnp.einsum("bsd,de->bse", x, params["wk"].astype(x.dtype))
    vnew = jnp.einsum("bsd,de->bse", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        knew = knew + params["bk"].astype(x.dtype)
        vnew = vnew + params["bv"].astype(x.dtype)
    q = q.reshape(b, 1, h, hd)
    knew = knew.reshape(b, 1, kvh, hd)
    vnew = vnew.reshape(b, 1, kvh, hd)
    if use_rope:
        q = apply_rope(q, pos_b[:, None], cfg.rope_theta)
        knew = apply_rope(knew, pos_b[:, None], cfg.rope_theta)

    page = block_tbl[jnp.arange(b), pos_b // ps]        # (B,)
    ok = page >= 0
    if write_mask is not None:
        ok &= write_mask
    dest = jnp.where(ok, page, 0)
    slot = (pos_b % ps).astype(jnp.int32)
    new_cache = {"pos": cache["pos"].at[dest, slot].set(
        jnp.where(ok, pos_b, -1))}
    if "ks" in cache:                              # quantize on write
        qk, sk = quantize_kv_rows(knew[:, 0])      # (B,KV,d) int8, (B,KV)
        qv, sv = quantize_kv_rows(vnew[:, 0])
        new_cache["kp"] = cache["kp"].at[dest, slot].set(qk)
        new_cache["vp"] = cache["vp"].at[dest, slot].set(qv)
        new_cache["ks"] = cache["ks"].at[dest, slot].set(sk)
        new_cache["vs"] = cache["vs"].at[dest, slot].set(sv)
    else:
        new_cache["kp"] = cache["kp"].at[dest, slot].set(
            knew[:, 0].astype(cache["kp"].dtype))
        new_cache["vp"] = cache["vp"].at[dest, slot].set(
            vnew[:, 0].astype(cache["vp"].dtype))
    cache = new_cache

    k, v, kpos = paged_gather(cache, block_tbl)
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    valid = (kpos >= 0) & (kpos <= pos_b[:, None])
    if window:
        valid &= (pos_b[:, None] - kpos) < window
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    out = out.reshape(b, 1, h * hd).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return y, cache


def chunk_attention_paged(params: Params, cfg: ModelConfig, x: jax.Array,
                          cache: Params, pos: jax.Array,
                          block_tbl: jax.Array, *,
                          window: int = 0, use_rope: bool = True,
                          write_mask: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, Params]:
    """Multi-token chunk decode over a block-paged KV cache — the compute
    path of chunked prefill (``decode_attention_paged`` generalized from
    one token to a page-sized chunk).

    x: (B,C,d); pos: scalar or per-row (B,) FIRST position of each row's
    chunk (token ``i`` sits at ``pos + i``); block_tbl: (B, max_logical).
    Each token writes its K/V at page ``block_tbl[b, (pos+i) // ps]``, slot
    ``(pos+i) % ps``; tokens without a mapping or excluded by
    ``write_mask`` ((B,C) per-token, or (B,) per-row) go to the trash page
    with ``pos = -1`` — this is how a right-padded final chunk keeps its
    pad positions invisible.  Writes land before the gather, so tokens of
    the same chunk attend to each other through the pages, and the causal
    ``kpos <= qpos`` mask plays the same role as in the dense prefill."""
    b, c, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.n_heads, cfg.n_kv_heads
    ps = cache["kp"].shape[1]
    pos0 = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    pos_bc = pos0[:, None] + jnp.arange(c, dtype=jnp.int32)     # (B,C)
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    knew = jnp.einsum("bsd,de->bse", x, params["wk"].astype(x.dtype))
    vnew = jnp.einsum("bsd,de->bse", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        knew = knew + params["bk"].astype(x.dtype)
        vnew = vnew + params["bv"].astype(x.dtype)
    q = q.reshape(b, c, h, hd)
    knew = knew.reshape(b, c, kvh, hd)
    vnew = vnew.reshape(b, c, kvh, hd)
    if use_rope:
        q = apply_rope(q, pos_bc, cfg.rope_theta)
        knew = apply_rope(knew, pos_bc, cfg.rope_theta)

    bidx = jnp.arange(b)[:, None]
    page = block_tbl[bidx, pos_bc // ps]                        # (B,C)
    ok = page >= 0
    if write_mask is not None:
        wm = write_mask if write_mask.ndim == 2 else write_mask[:, None]
        ok &= wm
    dest = jnp.where(ok, page, 0)
    slot = (pos_bc % ps).astype(jnp.int32)
    new_cache = {"pos": cache["pos"].at[dest, slot].set(
        jnp.where(ok, pos_bc, -1))}
    if "ks" in cache:                              # quantize on write
        qk, sk = quantize_kv_rows(knew)            # (B,C,KV,d), (B,C,KV)
        qv, sv = quantize_kv_rows(vnew)
        new_cache["kp"] = cache["kp"].at[dest, slot].set(qk)
        new_cache["vp"] = cache["vp"].at[dest, slot].set(qv)
        new_cache["ks"] = cache["ks"].at[dest, slot].set(sk)
        new_cache["vs"] = cache["vs"].at[dest, slot].set(sv)
    else:
        new_cache["kp"] = cache["kp"].at[dest, slot].set(
            knew.astype(cache["kp"].dtype))
        new_cache["vp"] = cache["vp"].at[dest, slot].set(
            vnew.astype(cache["vp"].dtype))
    cache = new_cache

    k, v, kpos = paged_gather(cache, block_tbl)
    g = h // kvh
    qg = q.reshape(b, c, kvh, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    valid = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= pos_bc[..., None])
    if window:
        valid &= (pos_bc[..., None] - kpos[:, None, :]) < window
    logits = jnp.where(valid[:, None, None, :, :], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    out = out.reshape(b, c, h * hd).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return y, cache


def build_cross_cache(params: Params, cfg: ModelConfig,
                      enc_out: jax.Array, dtype=None) -> Params:
    """Precompute encoder kv for cross-attention decode."""
    b, sk, _ = enc_out.shape
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,de->bse", enc_out, params["wv"].astype(enc_out.dtype))
    if "bk" in params:
        k = k + params["bk"].astype(enc_out.dtype)
        v = v + params["bv"].astype(enc_out.dtype)
    dt = dtype or enc_out.dtype
    return {"k": k.reshape(b, sk, kvh, hd).astype(dt),
            "v": v.reshape(b, sk, kvh, hd).astype(dt),
            "pos": jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))}
