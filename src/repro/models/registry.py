"""Model construction entry point."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config, get_smoke_config
from repro.models.transformer import Model


def build_model(cfg: ModelConfig, *, param_dtype=jnp.float32,
                compute_dtype=None) -> Model:
    return Model(cfg, param_dtype=param_dtype, compute_dtype=compute_dtype)


def build_by_name(arch: str, *, smoke: bool = False,
                  param_dtype=jnp.float32, compute_dtype=None) -> Model:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    return build_model(cfg, param_dtype=param_dtype,
                       compute_dtype=compute_dtype)
