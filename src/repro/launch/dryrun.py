import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x input shape) on the
production meshes; record memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init) — this is the only entry point that fakes 512
host devices; tests and benches see the real single device.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import ASSIGNED, get_config          # noqa: E402
from repro.configs.shapes import SHAPES_BY_NAME, shape_applicable  # noqa: E402
from repro.launch import sharding as shardlib                    # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch.specs import (arg_shardings, choose_fsdp,      # noqa: E402
                                input_specs, make_step_fn)
from repro.models.registry import build_model                    # noqa: E402
from repro.roofline.collectives import parse_collectives         # noqa: E402


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            *, fsdp=None, seq_parallel=True, vocab_shard=True,
            save_hlo: bool = False, tag: str = "",
            microbatches: int = 1) -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "skipped", "tag": tag}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec["reason"] = why
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["n_devices"] = mesh.devices.size
    model = build_model(cfg, param_dtype=jnp.bfloat16)
    rec["microbatches"] = microbatches
    step = make_step_fn(model, shape, microbatches=microbatches)
    args = input_specs(model, shape)
    if fsdp is None:
        fsdp = choose_fsdp(args[0], mesh)
    rec["fsdp"] = bool(fsdp)
    in_sh = arg_shardings(model, shape, mesh, args, fsdp=fsdp)
    # exact per-device resident argument bytes (params + opt + caches) from
    # the actual shardings — memory_analysis double-checks this
    import math as _math
    def _leaf_bytes(l, s):
        shard = s.shard_shape(l.shape)
        return _math.prod(shard) * l.dtype.itemsize
    rec["arg_bytes_per_device"] = int(sum(
        jax.tree.leaves(jax.tree.map(_leaf_bytes, args, in_sh))))
    policy = shardlib.ShardingPolicy(mesh, batch=shape.global_batch,
                                     seq_parallel=seq_parallel,
                                     vocab_shard=vocab_shard)
    # donation: train updates (params, opt) in place; prefill/decode update
    # caches in place — halves resident state exactly like production.
    donate = {"train": (0, 1), "prefill": (2,), "decode": (2,)}[shape.kind]
    # pin output shardings to the input shardings of donated state so the
    # donation actually aliases (mismatched shardings silently drop it)
    if shape.kind == "train":
        out_sh = (in_sh[0], in_sh[1], None)
    elif shape.kind == "prefill":
        out_sh = (None, in_sh[2])
    else:
        out_sh = (None, None, in_sh[2])
    try:
        t0 = time.time()
        with shardlib.use_policy(policy):
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=donate).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        try:
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(mem, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:  # CPU backend may not implement it
            rec["memory_analysis"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            # jax <= 0.4.x returns a one-element list of dicts; newer
            # versions return the dict directly
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                    if isinstance(v, (int, float))}
        except Exception as e:
            rec["cost_analysis"] = {"error": str(e)}
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo, mesh.devices.size)
        rec["hlo_bytes"] = len(hlo)
        if save_hlo:
            with open(os.path.join(out_dir, f"{arch}_{shape_name}_"
                                   f"{mesh_name}{tag}.hlo"), "w") as f:
                f.write(hlo)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--fsdp", default=None, choices=[None, "on", "off"])
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--no-vocab-shard", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    combos = []
    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = (list(SHAPES_BY_NAME) if (args.all or not args.shape)
              else [args.shape])
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    fsdp = None if args.fsdp is None else (args.fsdp == "on")
    results = []
    for a, s, mp in combos:
        rec = run_one(a, s, mp, args.out, fsdp=fsdp,
                      seq_parallel=not args.no_seq_parallel,
                      vocab_shard=not args.no_vocab_shard,
                      save_hlo=args.save_hlo, tag=args.tag,
                      microbatches=args.microbatches)
        results.append(rec)
        fname = os.path.join(
            args.out, f"{a}_{s}_{'2x16x16' if mp else '16x16'}"
            f"{args.tag}.json")
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        brief = {k: rec.get(k) for k in
                 ("arch", "shape", "mesh", "status", "lower_s", "compile_s",
                  "reason", "error")}
        print(json.dumps(brief), flush=True)
        if rec["status"] == "ok":
            ma = rec.get("memory_analysis", {})
            ca = rec.get("cost_analysis", {})
            print(f"  mem={ma}  flops={ca.get('flops')} "
                  f"bytes={ca.get('bytes accessed')} "
                  f"coll={ {k: round(v['wire_bytes']/1e6,1) for k,v in rec['collectives'].items()} }MB",
                  flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"DONE ok={n_ok} skipped={n_skip} "
          f"error={len(results) - n_ok - n_skip}")
    return 0 if all(r["status"] in ("ok", "skipped") for r in results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
