"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --smoke \
        --steps 50 --batch 4 --seq 64 --ckpt artifacts/ckpt/xlstm

``--smoke`` trains the reduced config on the local device; without it the
full config is used (requires a real TPU mesh — on CPU use --smoke)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.registry import build_model
from repro.training.checkpoint import save_checkpoint
from repro.training.optim import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step


def add_modality(batch, cfg, rng):
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            rng, (batch["tokens"].shape[0], cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.vision_tokens:
        batch["patches"] = jax.random.normal(
            rng, (batch["tokens"].shape[0], cfg.vision_tokens,
                  cfg.d_model)) * 0.1
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ee-llm-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"exits={cfg.exit_layers}")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    n_params = sum(l.size for l in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    opt = init_adamw(params)
    step = jax.jit(make_train_step(model, opt_cfg))
    data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      batch_size=args.batch, kind="mixed"))
    t0 = time.time()
    for i, b in enumerate(data.batches(args.steps)):
        batch = add_modality({k: jnp.asarray(v) for k, v in b.items()},
                             cfg, rng)
        params, opt, mets = step(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            exits = {k: round(float(v), 3) for k, v in mets.items()
                     if k.startswith("exit")}
            print(f"step {i:4d} loss={float(mets['loss']):.4f} "
                  f"main={float(mets['main_loss']):.4f} {exits} "
                  f"lr={float(mets['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params, extra={"arch": cfg.name,
                                                  "steps": args.steps})
        print(f"saved checkpoint to {args.ckpt}.npz")


if __name__ == "__main__":
    main()
