"""ShapeDtypeStruct input specs + lowerable step functions for every
(architecture x input-shape) combination — no device allocation anywhere.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.launch import sharding as shardlib
from repro.models.transformer import Model
from repro.training.optim import AdamWConfig, AdamWState, init_adamw
from repro.training.train_step import make_train_step

Pytree = Any
SDS = jax.ShapeDtypeStruct


# --------------------------------------------------------------------------
# input specs
# --------------------------------------------------------------------------
def batch_input_specs(cfg: ModelConfig, shape: InputShape, *,
                      with_labels: bool, dtype=jnp.bfloat16) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, SDS] = {"tokens": SDS((b, s), jnp.int32)}
    if with_labels:
        specs["labels"] = SDS((b, s), jnp.int32)
        specs["mask"] = SDS((b, s), dtype)
    if cfg.is_encdec:
        specs["frames"] = SDS((b, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.vision_tokens:
        specs["patches"] = SDS((b, cfg.vision_tokens, cfg.d_model), dtype)
    return specs


def input_specs(model: Model, shape: InputShape) -> Tuple[Pytree, ...]:
    """All example arguments (as ShapeDtypeStructs) for the shape's step."""
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = batch_input_specs(cfg, shape, with_labels=True)
        params = model.param_specs()
        opt = jax.eval_shape(init_adamw, params)
        return (params, opt, batch)
    if shape.kind == "prefill":
        batch = batch_input_specs(cfg, shape, with_labels=False)
        params = model.param_specs()
        caches = model.cache_specs(b, s)
        return (params, batch, caches)
    # decode: one token against a seq_len KV cache
    params = model.param_specs()
    caches = model.cache_specs(b, s)
    token = SDS((b, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return (params, token, caches, pos)


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------
def make_step_fn(model: Model, shape: InputShape,
                 opt_cfg: AdamWConfig = AdamWConfig(),
                 microbatches: int = 1) -> Callable:
    if shape.kind == "train":
        return make_train_step(model, opt_cfg, microbatches=microbatches)
    if shape.kind == "prefill":
        def prefill_step(params, batch, caches):
            x, exit_h, new_caches, _ = model.prefill(params, batch, caches)
            logits = model.logits(params, x[:, -1:])[:, 0]
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            return tok, new_caches
        return prefill_step

    def serve_step(params, token, caches, pos):
        x, exit_h, new_caches = model.decode_step(params, token, caches, pos)
        logits = model.logits(params, x)[:, 0]
        # exit heads are first-class: confidence computed every step
        confs = {}
        for l, h in exit_h.items():
            xl = model.exit_logits(params, l, h)[:, 0].astype(jnp.float32)
            confs[l] = jnp.exp(jnp.max(xl, -1) - jax.nn.logsumexp(xl, -1))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return tok, confs, new_caches
    return serve_step


# --------------------------------------------------------------------------
# sharding trees for the step arguments
# --------------------------------------------------------------------------
def choose_fsdp(param_specs: Pytree, mesh, threshold_bytes=2 << 30) -> bool:
    per_dev = shardlib.estimate_param_bytes_per_device(param_specs, mesh,
                                                       fsdp=False)
    return per_dev > threshold_bytes


def arg_shardings(model: Model, shape: InputShape, mesh, args: Tuple,
                  fsdp: bool = None) -> Tuple:
    b = shape.global_batch
    params = args[0]
    if fsdp is None:
        fsdp = choose_fsdp(params, mesh)
    psh = shardlib.params_shardings(params, mesh, fsdp=fsdp)
    if shape.kind == "train":
        _, opt, batch = args
        opt_sh = AdamWState(
            step=shardlib.replicated(opt.step, mesh),
            mu=shardlib.params_shardings(opt.mu, mesh, fsdp=fsdp),
            nu=shardlib.params_shardings(opt.nu, mesh, fsdp=fsdp))
        bsh = shardlib.batch_shardings(batch, mesh, batch=b)
        return (psh, opt_sh, bsh)
    if shape.kind == "prefill":
        _, batch, caches = args
        bsh = shardlib.batch_shardings(batch, mesh, batch=b)
        csh = shardlib.cache_shardings(caches, mesh, batch=b)
        return (psh, bsh, csh)
    _, token, caches, pos = args
    tsh = shardlib.batch_shardings(token, mesh, batch=b)
    csh = shardlib.cache_shardings(caches, mesh, batch=b)
    possh = shardlib.replicated(pos, mesh)
    return (psh, tsh, csh, possh)
