import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""CE-CoLLM technique dry-run: the disaggregated two-tier deployment.

Pod 0 (edge tier) compiles the edge partition step (layers 1..l_ee2 + exit
heads); pod 1 (cloud tier) compiles the cloud partition step (l_ee1+1..L).
The artifact records each tier's cost/memory analyses plus the cross-tier
wire bytes per token for every transport format — the quantity the paper's
technique (early exits + fp16 + async upload) minimizes.

    PYTHONPATH=src python -m repro.launch.dryrun_collm \
        --arch ee-llm-7b --batch 128 --seq 32768
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import get_config                    # noqa: E402
from repro.core.collm import CollmConfig                         # noqa: E402
from repro.core.disagg import TwoTierRuntime                     # noqa: E402
from repro.launch.mesh import make_production_mesh, pod_submeshes  # noqa: E402
from repro.models.registry import build_model                    # noqa: E402
from repro.roofline.collectives import parse_collectives         # noqa: E402


def run(arch: str, batch: int, seq: int, wire: str, out_dir: str) -> dict:
    mesh = make_production_mesh(multi_pod=True)
    edge_mesh, cloud_mesh = pod_submeshes(mesh)
    cfg = get_config(arch)
    model = build_model(cfg, param_dtype=jnp.bfloat16)
    rt = TwoTierRuntime(model, CollmConfig(wire_format=wire), edge_mesh,
                        cloud_mesh)
    rec = {"arch": arch, "batch": batch, "seq": seq, "wire": wire,
           "l_ee1": rt.collm.l_ee1, "l_ee2": rt.collm.l_ee2,
           "edge_chips": int(edge_mesh.devices.size),
           "cloud_chips": int(cloud_mesh.devices.size)}
    t0 = time.time()
    edge_l, cloud_l, info = rt.lower_tiers(batch, seq)
    rec["lower_s"] = round(time.time() - t0, 1)
    rec["wire_bytes_per_token"] = info["wire_bytes_per_token"]
    for name, lowered, n in (("edge", edge_l, edge_mesh.devices.size),
                             ("cloud", cloud_l, cloud_mesh.devices.size)):
        t0 = time.time()
        compiled = lowered.compile()
        tier = {"compile_s": round(time.time() - t0, 1)}
        try:
            ma = compiled.memory_analysis()
            tier["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes") if hasattr(ma, k)}
        except Exception as e:
            tier["memory_analysis"] = {"error": str(e)}
        try:
            tier["cost_analysis"] = {
                k: float(v) for k, v in compiled.cost_analysis().items()
                if isinstance(v, (int, float))}
        except Exception as e:
            tier["cost_analysis"] = {"error": str(e)}
        tier["collectives"] = parse_collectives(compiled.as_text(), int(n))
        rec[name] = tier
    rec["status"] = "ok"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"collm_{arch}_{batch}x{seq}_{wire}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ee-llm-7b")
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--seq", type=int, default=32768)
    ap.add_argument("--wire", default="float16",
                    choices=["float32", "float16", "int8"])
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    rec = run(args.arch, args.batch, args.seq, args.wire, args.out)
    brief = {k: rec[k] for k in ("arch", "status", "lower_s",
                                 "wire_bytes_per_token")}
    for tier in ("edge", "cloud"):
        brief[tier] = {"compile_s": rec[tier]["compile_s"],
                       "flops": rec[tier]["cost_analysis"].get("flops"),
                       "mem": rec[tier]["memory_analysis"]}
    print(json.dumps(brief, indent=1))


if __name__ == "__main__":
    main()
