"""Production mesh construction (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state — ``jax.make_mesh`` is only called by launchers (dryrun.py sets
XLA_FLAGS for 512 host devices *before* any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_cloud_mesh(shape):
    """Cloud-service mesh from a ``CollmConfig.cloud_mesh`` pair.

    ``shape`` is a ``(data, model)`` device grid, e.g. ``(2, 4)``.  Fails
    loudly when the host exposes fewer devices than the grid needs — on a
    CPU dev box run with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    exported *before* python starts (jax reads it at import)."""
    dims = tuple(int(s) for s in shape)
    if len(dims) != 2 or any(d < 1 for d in dims):
        raise ValueError(f"cloud_mesh must be a (data, model) pair of "
                         f"positive ints, got {shape!r}")
    need, have = dims[0] * dims[1], len(jax.devices())
    if need > have:
        raise ValueError(
            f"cloud_mesh {dims} needs {need} devices but only {have} "
            f"visible; set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={need} before importing jax to emulate them")
    return jax.make_mesh(dims, ("data", "model"))


def make_debug_mesh(n_devices: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    n = min(n_devices, len(jax.devices()))
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def pod_submeshes(mesh):
    """Split a multi-pod mesh into per-pod ("data","model") meshes — the
    two-tier (edge pod / cloud pod) CE-CoLLM deployment."""
    import numpy as np
    from jax.sharding import Mesh
    devs = np.asarray(mesh.devices)
    assert "pod" in mesh.axis_names and devs.shape[0] >= 2
    edge = Mesh(devs[0], ("data", "model"))
    cloud = Mesh(devs[1], ("data", "model"))
    return edge, cloud
