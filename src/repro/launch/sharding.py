"""Role-based sharding policy for the production meshes.

Rules (docs/sharding.md):
  * params — tensor-parallel on heads/d_ff/experts/vocab over ``model``;
    optional FSDP over ``data`` (and ``pod``) for storage of large models.
    Stacked segment params never shard the leading layer axis.
  * batch tensors — leading batch dim over ``("pod","data")``.
  * decode caches — batch over ``("pod","data")``; KV sequence over
    ``model``; when batch is unshardable (long_500k B=1) the sequence dim
    takes ``("data","model")`` (sequence-parallel decode attention).
  * paged pools (``kp``/``vp``/``ks``/``vs`` + page-major ``pos``) have no
    batch axis — the page axis shards over ``("pod","data")``, KV heads
    over ``model`` when they divide (never the in-page token axis: the
    paged gather's flatten would cross shard boundaries).
  * activations — residual stream constrained to sequence-parallel
    ``(batch, "model", None)`` between blocks; logits vocab-sharded over
    ``model`` (keeps (B,S,V) exit/main logits on-chip).

Every assignment is divisibility-checked; anything that does not divide
evenly is replicated on that axis.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return n


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return axes is not None and dim % _axis_size(mesh, axes) == 0 \
        and _axis_size(mesh, axes) > 1


def batch_axes(mesh: Mesh, b: int):
    """Largest prefix of ("pod","data") that divides the batch."""
    cands = [ax for ax in ("pod", "data") if ax in mesh.axis_names]
    for trial in (tuple(cands), ("data",), None):
        if trial is None:
            return None
        if b % _axis_size(mesh, trial) == 0:
            return trial
    return None


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
        elif hasattr(p, "name"):
            names.append(str(p.name))
    return tuple(names)


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "w1", "ffn_up", "w_in",
                 "vis_proj"}          # shard OUTPUT dim over model
_ROW_PARALLEL = {"wo", "w_down", "w2", "ffn_down", "w_out"}  # shard INPUT dim
_EMBED = {"embed", "lm_head"}


_ATTN_PROJ = {"wq", "wk", "wv", "wo"}   # reshaped to (.., heads, head_dim)


def param_pspec(path, leaf, mesh: Mesh, *, fsdp: bool,
                head_dim: int = 0) -> P:
    """``head_dim`` > 0 restricts attention projections (wq/wk/wv/wo) to
    head-aligned model sharding: the flattened heads*head_dim dim is only
    sharded when the HEAD COUNT divides the model axis, so the downstream
    (B,S,heads,head_dim) reshape never splits inside a head.  A mid-head
    shard is both the wrong parallelism (rope/attention mix within a
    head) and a known XLA resharding hazard on the reshape."""
    names = _path_names(path)
    name = names[-1] if names else ""
    in_segment = "segments" in names or "layers" in names
    stack = 1 if in_segment and leaf.ndim >= 1 else 0   # leading layer axis
    nd = leaf.ndim
    spec = [None] * nd
    fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names) \
        if fsdp else None

    def put(dim, axes):
        if 0 <= dim < nd and spec[dim] is None and _fits(leaf.shape[dim],
                                                         mesh, axes):
            spec[dim] = axes if isinstance(axes, str) else tuple(axes)
            return True
        return False

    def heads_align(dim) -> bool:
        if head_dim <= 0 or name not in _ATTN_PROJ:
            return True
        heads, rem = divmod(leaf.shape[dim], head_dim)
        return rem == 0 and heads % _axis_size(mesh, "model") == 0

    if nd - stack < 2:
        return P()                      # norms / biases replicated
    if name in _EMBED:
        put(0, "model")
        if fsdp_axes:
            put(1, fsdp_axes)
        return P(*spec)
    if name in _COL_PARALLEL:
        if heads_align(nd - 1):
            put(nd - 1, "model")
        if fsdp_axes:
            put(nd - 2, fsdp_axes)
        return P(*spec)
    if name in _ROW_PARALLEL:
        if heads_align(nd - 2):
            put(nd - 2, "model")
        if fsdp_axes:
            put(nd - 1, fsdp_axes)
        return P(*spec)
    if name == "router":
        return P()
    # fallback: greedy — model on largest shardable dim, fsdp on next
    order = sorted(range(stack, nd), key=lambda i: -leaf.shape[i])
    for i in order:
        if put(i, "model"):
            break
    if fsdp_axes:
        for i in order:
            if put(i, fsdp_axes):
                break
    return P(*spec)


# --------------------------------------------------------------------------
# cache specs
# --------------------------------------------------------------------------
def cache_pspec(path, leaf, mesh: Mesh, *, batch: int) -> P:
    """Cache layouts (leading stacked-layer axis L for scanned segments):
       k/v:  (L?, B, S, KV, hd)   pos: (L?, B, S)
       gla S:(L?, B, H, dk, dv)   n: (L?, B, H, dk)   m: (L?, B, H)
       conv: (L?, B, W, di)       slstm c/n/m/h: (L?, B, H, hd)
    Paged pools are page-major with no batch axis (rows reach pages
    through their block tables; physical page 0 is the trash page):
       kp/vp: (L?, P, ps, KV, hd)   ks/vs: (L?, P, ps, KV)
       pos:   (L?, P, ps) — told apart from dense pos by the batch dim.
    Page axis shards over ("pod","data"); KV heads over "model" when they
    divide, else replicated (kp and ks share the same KV count, so pages
    and their int8 scale rows always shard consistently; the in-page
    token axis is never sharded — the paged gather's flatten would cross
    shard boundaries).
    """
    names = _path_names(path)
    name = names[-1] if names else ""
    nd = leaf.ndim
    spec = [None] * nd
    baxes = batch_axes(mesh, batch)

    def put(dim, axes):
        if axes is None or not (0 <= dim < nd) or spec[dim] is not None:
            return False
        if _fits(leaf.shape[dim], mesh, axes):
            spec[dim] = axes if isinstance(axes, str) else tuple(axes)
            return True
        return False

    # page-major pool leaves (paged/int8 layouts, PRs 2/6)
    if name in ("kp", "vp"):
        pdim, kvdim = nd - 4, nd - 2
    elif name in ("ks", "vs"):
        pdim, kvdim = nd - 3, nd - 1
    elif name == "pos" and nd >= 2 and leaf.shape[nd - 2] != batch:
        pdim, kvdim = nd - 2, None      # paged pos: (L?, P, ps)
    else:
        pdim = None
    if pdim is not None:
        for trial in (("pod", "data"), ("data",)):
            axes = tuple(a for a in trial if a in mesh.axis_names)
            if axes and put(pdim, axes):
                break
        if kvdim is not None:
            # KV heads over model when they divide; otherwise replicate.
            # Never shard the in-page token axis: the paged gather
            # flattens (logical_pages, ps) and a sharded ps would put
            # shard boundaries mid-flatten (an XLA resharding hazard).
            put(kvdim, "model")
        return P(*spec)

    # locate dims from the right (robust to the optional stack axis)
    if name in ("k", "v"):
        bdim, sdim = nd - 4, nd - 3
    elif name == "pos":
        bdim, sdim = nd - 2, nd - 1
    elif name == "S":
        bdim, sdim = nd - 4, None
    elif name in ("n", "conv", "c", "h"):
        bdim, sdim = nd - 3, None
    elif name == "m":
        bdim, sdim = nd - 2 if nd >= 2 else 0, None
    else:
        bdim, sdim = None, None

    if bdim is not None and leaf.shape[bdim] == batch and baxes is not None:
        put(bdim, baxes)
    if sdim is not None:
        # KV sequence dim: model axis, plus data/pod when batch unsharded
        if baxes is None:
            for trial in (("pod", "data", "model"), ("data", "model"),
                          ("model",)):
                axes = tuple(a for a in trial if a in mesh.axis_names)
                if put(sdim, axes):
                    break
        else:
            put(sdim, "model")
    elif name in ("S", "n", "conv", "c", "h", "m"):
        # recurrent states: shard the largest non-batch dim over model
        order = sorted(range(nd), key=lambda i: -leaf.shape[i])
        for i in order:
            if i == bdim:
                continue
            if put(i, "model"):
                break
    return P(*spec)


# --------------------------------------------------------------------------
# batch (input) specs
# --------------------------------------------------------------------------
def input_pspec(leaf, mesh: Mesh, batch: int) -> P:
    baxes = batch_axes(mesh, batch)
    if leaf.ndim == 0 or baxes is None or leaf.shape[0] != batch:
        return P()
    return P(baxes, *([None] * (leaf.ndim - 1)))


# --------------------------------------------------------------------------
# activation-constraint policy (sequence parallelism + vocab sharding)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    batch: int
    seq_parallel: bool = True
    vocab_shard: bool = True

    def _ns(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def residual(self, x: jax.Array) -> jax.Array:
        """(B,S,d) residual between blocks -> sequence-parallel."""
        if not self.seq_parallel or x.ndim != 3 or x.shape[1] < 2:
            return x
        baxes = batch_axes(self.mesh, x.shape[0])
        if not _fits(x.shape[1], self.mesh, "model"):
            return x
        return jax.lax.with_sharding_constraint(
            x, self._ns(P(baxes, "model", None)))

    def logits(self, x: jax.Array) -> jax.Array:
        """(B,S,V) logits -> vocab-sharded over model."""
        if not self.vocab_shard or x.ndim != 3:
            return x
        if not _fits(x.shape[-1], self.mesh, "model"):
            return x
        baxes = batch_axes(self.mesh, x.shape[0])
        return jax.lax.with_sharding_constraint(
            x, self._ns(P(baxes, None, "model")))


_ACTIVE: Optional[ShardingPolicy] = None


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, policy
    try:
        yield
    finally:
        _ACTIVE = prev


def current_policy() -> Optional[ShardingPolicy]:
    return _ACTIVE


def constrain_residual(x: jax.Array) -> jax.Array:
    return _ACTIVE.residual(x) if _ACTIVE is not None else x


def constrain_logits(x: jax.Array) -> jax.Array:
    return _ACTIVE.logits(x) if _ACTIVE is not None else x


# --------------------------------------------------------------------------
# pytree -> NamedSharding trees
# --------------------------------------------------------------------------
def params_shardings(specs: Pytree, mesh: Mesh, *, fsdp: bool,
                     head_dim: int = 0) -> Pytree:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_pspec(p, l, mesh, fsdp=fsdp,
                                                     head_dim=head_dim)),
        specs)


def cache_shardings(specs: Pytree, mesh: Mesh, *, batch: int) -> Pytree:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_pspec(p, l, mesh, batch=batch)),
        specs)


def batch_shardings(specs: Pytree, mesh: Mesh, *, batch: int) -> Pytree:
    return jax.tree.map(
        lambda l: NamedSharding(mesh, input_pspec(l, mesh, batch)), specs)


def replicated(specs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(lambda l: NamedSharding(mesh, P()), specs)


def estimate_param_bytes_per_device(specs: Pytree, mesh: Mesh,
                                    fsdp: bool, head_dim: int = 0) -> float:
    total = 0.0
    def visit(path, leaf):
        nonlocal total
        spec = param_pspec(path, leaf, mesh, fsdp=fsdp, head_dim=head_dim)
        shards = 1
        for s in spec:
            if s:
                shards *= _axis_size(mesh, s)
        total += leaf.size * leaf.dtype.itemsize / shards
        return leaf
    jax.tree_util.tree_map_with_path(visit, specs)
    return total
