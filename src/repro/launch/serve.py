"""Serving launcher: CE-CoLLM co-inference over synthetic prompts.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-3b-a800m \
        --smoke --mode collm --theta 0.8 --clients 2 --max-new 16

``--channel sim`` prices every cloud request with WiFi-class network
parameters in virtual time (the engine overlaps edge decode with in-flight
replies); ``--deadline`` arms the latency-aware early exit.

``--cloud-batch`` switches to the multi-client topology (paper §5): each
client is its own single-slot engine and the shared ``CloudBatcher``
coalesces their concurrent cloud requests into one masked cloud step over
a pooled cloud cache; with ``--channel sim`` the engines' channels share
one batching ``CloudServicePoint`` (``--batch-window``).
"""
from __future__ import annotations

import argparse
import math

import jax

from repro.configs.registry import get_config, get_smoke_config
from repro.core.collm import CollmConfig
from repro.core.netsim import NetworkParams
from repro.core.transport import AsyncSimChannel, CloudServicePoint
from repro.core.workload import ArrivalProcess, arrival_times
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.registry import build_model
from repro.serving.adaptive import AdaptiveConfig, ResumeCostModel
from repro.serving.engine import ServingSystem, token_agreement
from repro.training.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ee-llm-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="collm",
                    choices=["collm", "standalone", "cloud"])
    ap.add_argument("--theta", type=float, default=0.8)
    ap.add_argument("--wire", default="float16",
                    choices=["float32", "float16", "int8"])
    ap.add_argument("--backfill", action="store_true")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--kv-layout", default="dense",
                    choices=["dense", "paged"],
                    help="paged: block-paged KV pool shared across slots")
    ap.add_argument("--kv-dtype", default="float32",
                    choices=["float32", "int8"],
                    help="int8: quantized KV pages with per-row absmax "
                         "scales (needs --kv-layout paged)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged pool size; below the worst-case demand it "
                         "oversubscribes (pair with --preemption)")
    ap.add_argument("--preemption", default="off",
                    choices=["off", "recompute", "swap"],
                    help="optimistic paged admission: preempt victim "
                         "streams on OutOfPages and resume by re-prefill "
                         "(recompute) or host page swap (swap)")
    ap.add_argument("--preempt-policy", default="youngest",
                    choices=["youngest", "fewest-pages", "lru"],
                    help="victim selection under --preemption")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="prefill prompts one page-sized chunk per tick "
                         "interleaved with decode (needs --kv-layout "
                         "paged)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="radix prefix cache: streams sharing a prompt "
                         "prefix map the same refcounted KV pages, "
                         "copy-on-write on divergence (needs "
                         "--chunked-prefill)")
    ap.add_argument("--channel", default="sync", choices=["sync", "sim"],
                    help="sim: WiFi-class async channel in virtual time")
    ap.add_argument("--deadline", type=float, default=math.inf,
                    help="per-request reply budget (virtual s); a miss "
                         "commits the edge token")
    ap.add_argument("--tick-time", type=float, default=0.01,
                    help="virtual edge compute per decode tick (sim)")
    ap.add_argument("--speculative", action="store_true",
                    help="commit provisional edge tokens while cloud "
                         "replies are in flight")
    ap.add_argument("--spec-k", type=int, default=1,
                    help="edge draft length: ship up to k provisional "
                         "tokens per verification request (needs "
                         "--speculative; 1 = classic speculative path)")
    ap.add_argument("--cloud-tp", type=int, default=0,
                    help="model-axis size of the cloud tensor-parallel "
                         "mesh; the cloud partition's steps compile "
                         "against a (--cloud-dp x N) device grid "
                         "(docs/sharding.md; 0 = single device)")
    ap.add_argument("--cloud-dp", type=int, default=1,
                    help="data-axis (batch) size of the cloud mesh "
                         "(needs --cloud-tp)")
    ap.add_argument("--cloud-batch", action="store_true",
                    help="multi-client mode: one engine per client, cloud "
                         "requests coalesced by the shared CloudBatcher")
    ap.add_argument("--batch-window", type=float, default=0.004,
                    help="cloud service accumulation window (virtual s, "
                         "--cloud-batch with --channel sim)")
    ap.add_argument("--service-s", type=float, default=0.008,
                    help="virtual cost of one cloud service step "
                         "(--channel sim)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop fleet replay (docs/fleet_sim.md): mean "
                         "request arrivals per virtual second (0 = closed "
                         "loop, the whole backlog at t=0)")
    ap.add_argument("--arrival-cv2", type=float, default=1.0,
                    help="interarrival squared coefficient of variation "
                         "(1 = Poisson, >1 = bursty gamma renewals)")
    ap.add_argument("--diurnal-amp", type=float, default=0.0,
                    help="sinusoidal arrival-rate modulation depth in "
                         "[0, 1)")
    ap.add_argument("--diurnal-period", type=float, default=10.0,
                    help="diurnal modulation period (virtual s)")
    ap.add_argument("--arrival-seed", type=int, default=0)
    ap.add_argument("--slo-ttft", type=float, default=None,
                    help="per-request time-to-first-token target "
                         "(virtual s) folded into SLO attainment")
    ap.add_argument("--slo-tpot", type=float, default=None,
                    help="per-request mean inter-token latency target "
                         "(virtual s)")
    ap.add_argument("--adaptive", action="store_true",
                    help="engine-side adaptive control: watermark AIMD on "
                         "the page pool, fluid-ODE admission gate, "
                         "per-victim swap-vs-recompute (needs --kv-layout "
                         "paged)")
    args = ap.parse_args()
    if args.cloud_batch and (args.preemption != "off"
                             or args.num_pages is not None):
        # multi-client mode runs one single-slot engine per client: a lone
        # slot has no victim to preempt, and generate_multi sizes its own
        # pools — fail loudly instead of silently ignoring the flags
        ap.error("--preemption/--num-pages apply to the single-engine "
                 "scheduler; drop --cloud-batch to use them")
    if args.kv_layout != "paged" and (args.preemption != "off"
                                      or args.num_pages is not None):
        # dense slots own fixed rings: there is no page pool to
        # oversubscribe, so these flags could never take effect
        ap.error("--preemption/--num-pages need --kv-layout paged")
    if args.kv_layout != "paged" and args.kv_dtype != "float32":
        ap.error("--kv-dtype int8 needs --kv-layout paged")
    if args.spec_k != 1 and not args.speculative:
        ap.error("--spec-k needs --speculative (drafting generalizes the "
                 "speculative path)")
    if args.chunked_prefill and args.kv_layout != "paged":
        ap.error("--chunked-prefill writes chunks through the paged "
                 "decode path; needs --kv-layout paged")
    if args.prefix_share and not args.chunked_prefill:
        ap.error("--prefix-share admits the unshared suffix through "
                 "chunked prefill; needs --chunked-prefill")
    if args.cloud_dp != 1 and not args.cloud_tp:
        ap.error("--cloud-dp sizes the data axis of the cloud mesh; "
                 "needs --cloud-tp")
    if args.arrival_rate < 0:
        ap.error("--arrival-rate must be >= 0")
    if args.arrival_rate == 0 and (args.arrival_cv2 != 1.0
                                   or args.diurnal_amp != 0.0):
        ap.error("--arrival-cv2/--diurnal-amp shape the arrival process; "
                 "need --arrival-rate > 0")
    if ((args.arrival_rate > 0 or args.slo_ttft is not None
         or args.slo_tpot is not None) and args.channel != "sim"):
        ap.error("--arrival-rate/--slo-* replay in virtual time; need "
                 "--channel sim")
    if args.adaptive and args.kv_layout != "paged":
        ap.error("--adaptive tunes the paged pool's watermark and "
                 "admission; needs --kv-layout paged")
    if args.adaptive and args.cloud_batch:
        ap.error("--adaptive drives the single-engine scheduler; drop "
                 "--cloud-batch to use it")
    cloud_mesh = (args.cloud_dp, args.cloud_tp) if args.cloud_tp else None
    if cloud_mesh is not None:
        need = cloud_mesh[0] * cloud_mesh[1]
        if need > len(jax.devices()):
            ap.error(f"--cloud-dp x --cloud-tp = {need} devices but only "
                     f"{len(jax.devices())} visible (locally: export "
                     f"XLA_FLAGS=--xla_force_host_platform_device_count="
                     f"{need} before launching)")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        params, _ = load_checkpoint(args.ckpt, params)
    data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                      batch_size=1))
    ccfg = CollmConfig(
        theta=args.theta, wire_format=args.wire, backfill=args.backfill,
        speculative=args.speculative, spec_k=args.spec_k,
        kv_layout=args.kv_layout, kv_dtype=args.kv_dtype,
        preemption=args.preemption, preempt_policy=args.preempt_policy,
        chunked_prefill=args.chunked_prefill,
        prefix_share=args.prefix_share,
        cloud_mesh=cloud_mesh)
    prompts = [data.sample_tokens(args.prompt_len)
               for _ in range(args.clients)]
    if args.prefix_share:
        # the workload the flag exists for: every client opens with the
        # same system prompt (2.5 KV pages of it, so full pages can be
        # shared and the partial tail exercises copy-on-write), then its
        # own request
        import numpy as np
        system_prefix = data.sample_tokens(2 * ccfg.page_size
                                           + ccfg.page_size // 2)
        prompts = [np.concatenate([system_prefix, p]).astype(p.dtype)
                   for p in prompts]
    arrivals = None
    if args.arrival_rate > 0:
        proc = ArrivalProcess(
            rate=args.arrival_rate,
            kind="poisson" if args.arrival_cv2 == 1.0 else "gamma",
            cv2=args.arrival_cv2, diurnal_amp=args.diurnal_amp,
            diurnal_period_s=args.diurnal_period)
        arrivals = arrival_times(proc, len(prompts),
                                 seed=args.arrival_seed)
    fleet_kw = {}
    if arrivals is not None:
        fleet_kw["arrivals"] = arrivals
    if args.slo_ttft is not None:
        fleet_kw["slo_ttft_s"] = args.slo_ttft
    if args.slo_tpot is not None:
        fleet_kw["slo_tpot_s"] = args.slo_tpot
    system = ServingSystem(model, params, ccfg)
    if args.cloud_batch:
        gen_kw = dict(fleet_kw)
        if args.channel == "sim":
            # a single client has nobody to coalesce with: plain FIFO
            svc = CloudServicePoint(
                args.service_s,
                batch_window_s=args.batch_window if args.clients > 1 else 0.0,
                max_batch=args.clients)
            gen_kw.update(
                channels=[AsyncSimChannel(NetworkParams(),
                                          deadline_s=args.deadline,
                                          service=svc)
                          for _ in range(args.clients)],
                tick_time_s=args.tick_time)
        r = system.generate_multi(prompts, args.max_new, mode=args.mode,
                                  cloud_batch=True, **gen_kw)
        if "batcher" in r:
            print(f"cloud batcher: {r['batcher']}")
    else:
        gen_kw = dict(fleet_kw)
        if args.channel == "sim":
            gen_kw.update(channel=AsyncSimChannel(NetworkParams(),
                                                  deadline_s=args.deadline),
                          tick_time_s=args.tick_time)
        if args.num_pages is not None:
            gen_kw["num_pages"] = args.num_pages
        if args.adaptive:
            # per-victim swap-vs-recompute needs the cost model; both the
            # watermark AIMD and the admission gate hang off the config
            gen_kw.update(adaptive=AdaptiveConfig(),
                          resume_cost=ResumeCostModel())
        r = system.generate(prompts, args.max_new, mode=args.mode, **gen_kw)
    st = r["stats"]
    print(f"mode={args.mode} theta={args.theta} wire={args.wire} "
          f"channel={args.channel} cloud_batch={args.cloud_batch}")
    if cloud_mesh is not None:
        print(f"cloud mesh: data={cloud_mesh[0]} model={cloud_mesh[1]} "
              f"({cloud_mesh[0] * cloud_mesh[1]} devices)")
    print(f"tokens={st.tokens} exits@l1={st.exits_l1} exits@l2={st.exits_l2} "
          f"cloud_requests={st.cloud_requests} "
          f"request_rate={st.request_rate:.2%}")
    print(f"upload={st.upload_bytes/1e3:.1f}KB edge_t={st.edge_time:.2f}s "
          f"cloud_t={st.cloud_time:.2f}s")
    if args.preemption != "off":
        print(f"preemptions={st.preemptions} policy={args.preempt_policy} "
              f"mode={args.preemption}")
    if args.chunked_prefill:
        print(f"prefill_chunks={st.prefill_chunks} "
              f"prefix_hit_tokens={st.prefix_hit_tokens} "
              f"cow_copies={st.cow_copies}")
    if args.speculative and st.draft_tokens:
        print(f"draft: k={args.spec_k} draft_tokens={st.draft_tokens} "
              f"accepted={st.accepted_tokens} "
              f"accept_rate={st.accepted_tokens / st.draft_tokens:.2%} "
              f"rewinds={st.spec_rewinds}")
    if args.channel == "sim":
        print(f"virtual_t={r['virtual_time']:.3f}s "
              f"deadline_misses={st.deadline_misses} "
              f"fallbacks={st.fallbacks} stall={st.stall_s:.3f}s "
              f"overlap={st.overlap_s:.3f}s late_drops={r['late_drops']}")
    if fleet_kw:
        print(f"fleet: ttft_p50={st.ttft_p(50) * 1e3:.1f}ms "
              f"ttft_p99={st.ttft_p(99) * 1e3:.1f}ms "
              f"tpot_p50={st.token_lat_p(50) * 1e3:.1f}ms "
              f"tpot_p99={st.token_lat_p(99) * 1e3:.1f}ms "
              f"slo={st.slo_attainment:.2%} ({st.slo_met}/{st.slo_total}) "
              f"preempt_rate={st.preemption_rate:.3f} "
              f"miss_rate={st.deadline_miss_rate:.3f}")
    if args.adaptive and r.get("adaptive") is not None:
        print(f"adaptive: {r['adaptive']}")
    if args.mode != "cloud":
        base_sys = system
        if args.chunked_prefill:
            # chunked prefill is edge-resident; the cloud baseline runs on
            # a plain config (same params, same greedy streams)
            base_sys = ServingSystem(model, params, CollmConfig(
                theta=args.theta, wire_format=args.wire,
                kv_layout=args.kv_layout, kv_dtype=args.kv_dtype))
        base = base_sys.generate(prompts, args.max_new, mode="cloud")
        ags = [token_agreement(a, b)
               for a, b in zip(r["tokens"], base["tokens"])]
        print(f"agreement vs cloud (LCS-F1): "
              f"{[round(a, 3) for a in ags]}")
    print("content manager:", r["cm_stats"])


if __name__ == "__main__":
    main()
