"""TPU v5e hardware constants (per chip) for the roofline model."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_LINK_BW = 50e9            # bytes/s per link
CHIP_HBM_BYTES = 16 << 30     # 16 GiB

MESH_CHIPS_SINGLE = 256
MESH_CHIPS_MULTI = 512
