"""Parse collective ops (and their wire bytes) out of compiled/lowered HLO.

``cost_analysis`` does not expose collective traffic, so we scan the HLO
text for all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, read each op's result shape, and convert to
estimated per-device wire bytes with the standard ring factors:

    all-gather          (N-1)/N * result_bytes
    reduce-scatter      (N-1)/N * operand_bytes (~ result * N -> (N-1)*res)
    all-reduce          2 (N-1)/N * operand_bytes
    all-to-all          (N-1)/N * operand_bytes
    collective-permute  operand_bytes

N is taken from the op's replica_groups when present (group size), else
the mesh size.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|((?:pred|[suf]\d+|bf16)\[[\d,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Dict]:
    """Returns {op_kind: {count, result_bytes, wire_bytes_per_device}}."""
    out: Dict[str, Dict] = defaultdict(lambda: {"count": 0, "result_bytes": 0,
                                                "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        if "-done(" in line:
            continue    # count each async collective once (at -start)
        shape_text = m.group(1) or m.group(2) or ""
        rb = _shape_bytes(shape_text)
        # group size
        n = n_devices
        g = _GROUPS_RE.search(line)
        if g:
            n = max(2, g.group(1).count(",") + 1)
        else:
            g2 = _GROUPS2_RE.search(line)
            if g2:
                n = max(2, int(g2.group(2)))
        frac = (n - 1) / n
        if kind == "all-gather":
            wire = frac * rb
        elif kind == "reduce-scatter":
            wire = frac * rb * n  # operand = result * n
        elif kind == "all-reduce":
            wire = 2 * frac * rb
        elif kind == "all-to-all":
            wire = frac * rb
        else:  # collective-permute
            wire = rb
        rec = out[kind]
        rec["count"] += 1
        rec["result_bytes"] += rb
        rec["wire_bytes"] += wire
    return dict(out)


def total_wire_bytes(coll: Dict[str, Dict]) -> float:
    return sum(v["wire_bytes"] for v in coll.values())
