"""Three-term roofline model from dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute    = HLO_FLOPs   / (chips * peak_FLOP/s)
    memory     = HLO_bytes   / (chips * HBM_bw)
    collective = wire_bytes  / (chips * link_bw)

cost_analysis FLOPs/bytes from XLA are *global* when SPMD-partitioned HLO is
analyzed per-module (XLA reports the per-device module): we treat them as
per-device and divide only the collective term (already per-device) by the
link bandwidth.  MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) gives the
useful-compute ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.roofline import hw


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float            # 6*N_active*D global
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0

    model_compute_s: float = 0.0   # analytic 6ND-based lower bound

    def finish(self) -> "RooflineTerms":
        self.compute_s = self.flops_per_device / hw.PEAK_FLOPS_BF16
        self.memory_s = self.bytes_per_device / hw.HBM_BW
        self.collective_s = self.wire_bytes_per_device / hw.ICI_LINK_BW
        # XLA's CPU cost analysis counts while-loop (scan) bodies ONCE, not
        # x trip-count, so HLO flops/bytes UNDER-count deep scanned stacks.
        # The analytic term (MODEL_FLOPS per chip / peak) is the reliable
        # lower bound for compute; useful_ratio >> 1 flags the artifact.
        self.model_compute_s = (self.model_flops / self.chips
                                / hw.PEAK_FLOPS_BF16)
        terms = {"compute": max(self.compute_s, self.model_compute_s),
                 "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo_flops = self.flops_per_device * self.chips
        self.useful_ratio = (self.model_flops / total_hlo_flops
                             if total_hlo_flops else 0.0)
        return self

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": f"{self.compute_s:.3e}",
            "model_compute_s": f"{self.model_compute_s:.3e}",
            "memory_s": f"{self.memory_s:.3e}",
            "collective_s": f"{self.collective_s:.3e}",
            "bottleneck": self.bottleneck,
            "useful_ratio": f"{self.useful_ratio:.3f}",
        }


# ---------------------------------------------------------------------------
# Decode KV traffic (docs/kv_paging.md §Quantized pages)
#
# The decode step's HBM floor is the KV cache sweep: every new token reads
# all mapped pages of its slot across every layer, and writes one K/V row
# per layer.  These helpers derive that floor from a LIVE cache pytree, so
# int8 pools (int8 kp/vp + fp32 per-row scales) are billed at their actual
# leaf dtypes — the number ``throughput_bench --kv-dtype`` gates on.
# ---------------------------------------------------------------------------
def _paged_nodes(tree):
    """Yield every paged attention-cache node (dict with "kp") of a pytree."""
    if isinstance(tree, dict):
        if "kp" in tree:
            yield tree
            return
        for v in tree.values():
            yield from _paged_nodes(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _paged_nodes(v)


def kv_page_bytes(tree) -> int:
    """HBM bytes one decode token reads per mapped logical page: the page's
    slice of EVERY paged leaf (kp/vp, int8 scales, pos), summed across
    layers (stacked (L,P,...) leaves count all L)."""
    total = 0
    for node in _paged_nodes(tree):
        ax = 1 if node["kp"].ndim == 5 else 0
        for leaf in node.values():
            total += leaf.size // leaf.shape[ax] * leaf.dtype.itemsize
    return total


def kv_token_write_bytes(tree) -> int:
    """HBM bytes one decode token writes: one K/V row (plus scales + pos
    entry) per layer."""
    total = 0
    for node in _paged_nodes(tree):
        ax = 1 if node["kp"].ndim == 5 else 0
        for leaf in node.values():
            rows = leaf.shape[ax] * leaf.shape[ax + 1]   # pages x page_size
            total += leaf.size // rows * leaf.dtype.itemsize
    return total


def decode_kv_bytes_per_token(tree, ctx: int, page_size: int) -> int:
    """Achieved KV HBM bytes per decoded token at context length ``ctx``:
    read all mapped pages + write one row, per layer."""
    pages = -(-int(ctx) // int(page_size))               # pages_needed
    return pages * kv_page_bytes(tree) + kv_token_write_bytes(tree)


def hbm_roofline_fraction(bytes_per_token: float, tokens_per_s: float
                          ) -> float:
    """Achieved KV-sweep HBM bandwidth as a fraction of the chip roofline
    (``hw.HBM_BW``).  On the CPU CI runner this is a tiny number — the
    point is the RATIO between layouts/dtypes, and that the achieved
    bytes/token column itself is what the ``--check`` gate compares."""
    return bytes_per_token * tokens_per_s / hw.HBM_BW


def count_params(cfg) -> float:
    """Total (rough) and active parameter counts for MODEL_FLOPS."""
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    total = active = v * d  # embedding
    kinds = cfg.block_kinds()
    for k in kinds:
        if k in ("dense", "shared_attn"):
            mlp = d * cfg.d_ff * (2 if cfg.mlp_kind == "gelu" else 3)
            total += attn + mlp
            active += attn + mlp
        elif k == "moe":
            e = cfg.moe.num_experts
            per = d * cfg.moe.expert_d_ff * 3
            total += attn + e * per
            active += attn + cfg.moe.top_k * per
        elif k in ("mlstm",):
            di = d * (cfg.ssm.expand if cfg.ssm else 2)
            blk = d * 2 * di + 3 * di * di + di * d
            total += blk
            active += blk
        elif k == "slstm":
            blk = 8 * d * d + d * int(d * 4 / 3) * 3
            total += blk
            active += blk
        elif k == "mamba2":
            di = d * cfg.ssm.expand
            n = cfg.ssm.state_size
            blk = d * (2 * di + 2 * n + di // 64) + di * d
            total += blk
            active += blk
    if cfg.is_encdec:
        mlp = d * cfg.d_ff * 2
        total += cfg.encoder_layers * (attn + mlp) + L * attn  # cross attn
        active += cfg.encoder_layers * (attn + mlp) + L * attn
    return total, active


def model_flops(cfg, shape) -> float:
    """6*N_active*D for training; 2*N_active*D per generated/processed
    token for inference."""
    total, active = count_params(cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return mult * active * tokens


def analyze(record: Dict, cfg, shape) -> Optional[RooflineTerms]:
    """record: one dryrun JSON entry."""
    cost = record.get("cost_analysis") or {}
    coll = record.get("collectives") or {}
    wire = sum(v.get("wire_bytes", 0.0) for v in coll.values())
    chips = record["n_devices"]
    return RooflineTerms(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        chips=chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        wire_bytes_per_device=wire,
        model_flops=model_flops(cfg, shape),
    ).finish()
