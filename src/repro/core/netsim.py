"""Virtual-time discrete-event simulator for cloud-edge LLM serving.

Reproduces the paper's experimental setting (§5): N edge clients, one
shared cloud server, a WiFi-class link per client.  Strategies:

  * ``cloud_llm``   — Cloud-based LLM Deployment (fig 1a): all layers in the
                      cloud; only tokens cross the network.
  * ``naive``       — Naive Cloud-Edge Deployment (fig 1b): model split at
                      l_ee2; per-token synchronous hidden-state transfer of
                      the FULL context (no content manager -> no cloud KV).
  * ``ce_collm``    — the paper's system: early exits at l_ee1/l_ee2,
                      parallel (async) upload at l_ee1, content-manager KV
                      caching, per-token cloud requests only below theta.
  * ``standalone``  — edge standalone mode (last exit is the output).

Ablation switches mirror Table 4: ``half_precision`` (fp16 wire),
``early_exit`` (θ effectively 1.0 when off), ``content_manager`` (off ->
synchronous full-context uploads per request).

Time accounting matches the paper's metrics: total / edge / cloud / comm
time costs, request-cloud rate, transmitted MB.  The cloud is a FIFO
resource shared by all clients (this produces Fig 4's saturation).

This simulator runs in *virtual time*: compute costs are supplied per
partition (measured on-CPU for the tiny end-to-end example, or set to
A100-class constants to replay the paper's tables).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

# Single source of truth for wire accounting AND cloud-queue accounting:
# the simulator prices packets with the same helpers the serving engine
# uses, and books cloud service through the same CloudServicePoint the
# AsyncSimChannel uses (repro.core.transport), so the two can never
# disagree on transmitted MB or on the batched-cloud saturation knee.
from repro.core.transport import (TOKEN_BYTES, CloudServicePoint,
                                  hidden_wire_bytes)


@dataclasses.dataclass
class NetworkParams:
    up_bw: float = 4.0e6          # bytes/s (~32 Mbit/s WiFi uplink)
    down_bw: float = 8.0e6
    # per-REQUEST round trip (naive / ce_collm requests).  The cloud-based
    # API strategy streams over an open connection: bytes only, no per-token
    # RTT (this matches the paper's ~0.4 s comm for cloud deployment).
    rtt: float = 0.003


@dataclasses.dataclass
class ComputeParams:
    """Per-token per-layer compute costs (seconds)."""
    edge_layer_time: float
    cloud_layer_time: float
    exit_head_time: float = 0.0
    # edge-side wire serialization throughput (bytes/s); fp16 halves bytes
    serialize_bw: float = 2.0e9
    # prompt prefill processes the whole prompt in parallel: per-token cost
    # is a small fraction of decode cost (batched matmuls)
    prefill_discount: float = 0.05


@dataclasses.dataclass
class ModelSplit:
    n_layers: int
    l_ee1: int
    l_ee2: int
    d_model: int
    backfill: bool = False        # beyond-paper exact-KV mode


@dataclasses.dataclass
class TokenTrace:
    conf1: float
    conf2: float


@dataclasses.dataclass
class CaseTrace:
    prompt_len: int
    tokens: List[TokenTrace]      # generated tokens
    arrival_t: float = 0.0        # open-loop virtual arrival time; a case
                                  # never starts before it (workload.
                                  # stamp_arrivals attaches these)


@dataclasses.dataclass
class SimResult:
    total_time: float = 0.0       # makespan over all clients
    edge_time: float = 0.0        # summed edge busy time
    cloud_time: float = 0.0       # summed cloud busy time
    comm_time: float = 0.0        # summed time tokens were blocked on the wire
    request_cloud_rate: float = 0.0
    transmitted_mb: float = 0.0
    tokens: int = 0
    cloud_requests: int = 0
    per_client_finish: List[float] = dataclasses.field(default_factory=list)

    def as_row(self) -> Dict[str, float]:
        return {
            "total_s": round(self.total_time, 3),
            "edge_s": round(self.edge_time, 3),
            "cloud_s": round(self.cloud_time, 3),
            "comm_s": round(self.comm_time, 3),
            "request_rate_pct": round(self.request_cloud_rate * 100, 2),
            "transmitted_mb": round(self.transmitted_mb, 2),
        }


@dataclasses.dataclass
class _Client:
    cid: int
    cases: List[CaseTrace]
    now: float = 0.0
    case_idx: int = 0
    tok_idx: int = 0
    upload_link_free: float = 0.0
    upload_arrival: float = 0.0   # arrival time of the latest l_ee1 upload
    done: bool = False


def _hidden_bytes(d_model: int, half_precision: bool) -> int:
    return hidden_wire_bytes(d_model,
                             "float16" if half_precision else "float32")


def simulate(strategy: str, clients_cases: Sequence[List[CaseTrace]],
             net: NetworkParams, comp: ComputeParams, split: ModelSplit, *,
             theta: float = 0.8,
             half_precision: bool = True,
             early_exit: bool = True,
             content_manager: bool = True,
             cloud_batch_window: float = 0.0,
             cloud_max_batch: int = 1) -> SimResult:
    """Run one deployment strategy over per-client case lists.

    ``cloud_batch_window`` / ``cloud_max_batch`` configure the shared
    cloud service point: with the defaults every request occupies the
    server back-to-back (per-request FIFO — Fig 4's saturation knee);
    with batching on, requests arriving within the window share one
    batched service step, the accounting the live ``CloudBatcher``
    realizes (docs/async_transport.md)."""
    res = SimResult()
    clients = [_Client(cid=i, cases=list(cs))
               for i, cs in enumerate(clients_cases)]
    cloud = CloudServicePoint(0.0, batch_window_s=cloud_batch_window,
                              max_batch=cloud_max_batch)
    hb = _hidden_bytes(split.d_model, half_precision)
    theta_eff = theta if early_exit else 2.0   # never exit early

    heap = [(c.now, c.cid) for c in clients]
    heapq.heapify(heap)
    edge_layers_e1 = split.l_ee1
    edge_layers_e2 = split.l_ee2
    cloud_layers = split.n_layers - split.l_ee1
    pending_backfill: Dict[int, int] = {c.cid: 0 for c in clients}

    def upload_cost(nbytes: float) -> float:
        return nbytes / net.up_bw

    def serialize_cost(nbytes: float) -> float:
        return nbytes / comp.serialize_bw

    while heap:
        _, cid = heapq.heappop(heap)
        c = clients[cid]
        if c.case_idx >= len(c.cases):
            continue
        case = c.cases[c.case_idx]

        if c.tok_idx == 0:
            # open-loop replay: a case stamped with an arrival time in the
            # client's future starts then — the gap is idle, not busy
            if case.arrival_t > c.now:
                c.now = case.arrival_t
            # ---------------- prompt processing (batched prefill) ----------
            p = case.prompt_len
            pf = comp.prefill_discount
            if strategy == "cloud_llm":
                # prompt tokens to cloud, full prefill there
                wire = p * TOKEN_BYTES
                comm = wire / net.up_bw
                res.comm_time += comm
                res.transmitted_mb += wire / 1e6
                svc = p * split.n_layers * comp.cloud_layer_time * pf
                c.now = cloud.service(c.now + comm, svc)
            elif strategy == "naive":
                # edge prefills its partition, ships ALL prompt hiddens sync
                svc_e = p * edge_layers_e2 * comp.edge_layer_time * pf
                res.edge_time += svc_e
                wire = p * hb
                comm = net.rtt / 2 + upload_cost(wire)
                res.comm_time += comm
                res.transmitted_mb += wire / 1e6
                svc_c = (p * (split.n_layers - split.l_ee2)
                         * comp.cloud_layer_time * pf)
                c.now = cloud.service(c.now + svc_e + comm, svc_c) \
                    + net.rtt / 2
            elif strategy in ("ce_collm",):
                svc_e = (p * edge_layers_e2 * comp.edge_layer_time * pf
                         + serialize_cost(p * hb))
                res.edge_time += svc_e
                # prompt hiddens uploaded in parallel with edge prefill:
                # link time overlaps edge compute (content manager batches)
                wire = p * hb if content_manager else 0
                link = upload_cost(wire)
                c.upload_arrival = c.now + max(svc_e, link) + net.rtt / 2
                res.transmitted_mb += wire / 1e6
                # blocked-on-wire time is only the non-overlapped part
                res.comm_time += max(0.0, link - svc_e)
                c.now = c.now + max(svc_e, link if not content_manager else svc_e)
                # cloud prefills its partition from uploaded hiddens (async,
                # needed before the first cloud request)
                svc_c = p * cloud_layers * comp.cloud_layer_time * pf
                c.upload_arrival = cloud.service(c.upload_arrival, svc_c)
            elif strategy == "standalone":
                svc_e = p * edge_layers_e2 * comp.edge_layer_time * pf
                res.edge_time += svc_e
                c.now += svc_e

        if c.tok_idx < len(case.tokens):
            tok = case.tokens[c.tok_idx]
            res.tokens += 1
            if strategy == "cloud_llm":
                # streaming API connection: bytes only, no per-token RTT
                wire = 2 * TOKEN_BYTES
                comm = wire / net.up_bw
                res.comm_time += comm
                res.transmitted_mb += wire / 1e6
                svc = split.n_layers * comp.cloud_layer_time
                c.now = cloud.service(c.now + comm, svc)

            elif strategy == "naive":
                svc_e = edge_layers_e2 * comp.edge_layer_time
                res.edge_time += svc_e
                # the edge re-ships the FULL context's hidden states every
                # token (it does not track cloud state); the cloud keeps a
                # KV cache and only computes the new token.
                ctx = case.prompt_len + c.tok_idx + 1
                wire = ctx * hb
                comm = net.rtt + upload_cost(wire)
                res.comm_time += comm
                res.transmitted_mb += wire / 1e6
                svc_c = (split.n_layers - split.l_ee2) * comp.cloud_layer_time
                ready = c.now + svc_e + net.rtt / 2 + upload_cost(wire)
                c.now = cloud.service(ready, svc_c) + net.rtt / 2

            elif strategy == "standalone":
                svc_e = (edge_layers_e2 * comp.edge_layer_time
                         + 2 * comp.exit_head_time)
                res.edge_time += svc_e
                c.now += svc_e

            elif strategy == "ce_collm":
                # edge: layers 1..l_ee1 + exit head
                t_e1 = edge_layers_e1 * comp.edge_layer_time + comp.exit_head_time
                res.edge_time += t_e1
                now1 = c.now + t_e1
                # parallel upload dispatched at l_ee1 (content manager on)
                if content_manager:
                    wire = hb
                    link_start = max(now1, c.upload_link_free)
                    c.upload_link_free = link_start + upload_cost(wire)
                    upload_arr = c.upload_link_free + net.rtt / 2
                    res.transmitted_mb += wire / 1e6
                    res.edge_time += serialize_cost(wire)
                    now1 += serialize_cost(wire)
                else:
                    upload_arr = None
                if early_exit and tok.conf1 >= theta_eff:
                    c.now = now1
                    if not split.backfill:
                        pending_backfill[cid] = 0  # released by the manager
                    else:
                        pending_backfill[cid] += 1
                else:
                    t_e2 = ((edge_layers_e2 - edge_layers_e1)
                            * comp.edge_layer_time + comp.exit_head_time)
                    res.edge_time += t_e2
                    now2 = now1 + t_e2
                    if early_exit and tok.conf2 >= theta_eff:
                        c.now = now2
                        if not split.backfill:
                            pending_backfill[cid] = 0
                        else:
                            pending_backfill[cid] += 1
                    else:
                        # cloud request
                        res.cloud_requests += 1
                        if content_manager:
                            req_arr = now2 + net.rtt / 2
                            data_ready = max(req_arr, upload_arr)
                            res.comm_time += (data_ready - now2) + net.rtt / 2
                            res.transmitted_mb += TOKEN_BYTES / 1e6
                        else:
                            # sync full-context upload on request (Table 4
                            # "without content manager & parallel upload")
                            ctx = case.prompt_len + c.tok_idx + 1
                            wire = ctx * hb
                            comm = net.rtt + upload_cost(wire)
                            res.comm_time += comm
                            res.transmitted_mb += wire / 1e6
                            data_ready = now2 + net.rtt / 2 + upload_cost(wire)
                        nbf = pending_backfill[cid] if split.backfill else 0
                        svc_c = (1 + nbf) * cloud_layers * comp.cloud_layer_time
                        pending_backfill[cid] = 0
                        c.now = cloud.service(data_ready, svc_c) + net.rtt / 2

            c.tok_idx += 1
            if c.tok_idx >= len(case.tokens):
                c.case_idx += 1
                c.tok_idx = 0
            heapq.heappush(heap, (c.now, cid))
        else:
            c.case_idx += 1
            c.tok_idx = 0
            heapq.heappush(heap, (c.now, cid))

    res.per_client_finish = [c.now for c in clients]
    res.total_time = max(res.per_client_finish) if clients else 0.0
    # server busy time comes from the service point: a batched step serves
    # several requests with ONE service, so summing per request would lie
    res.cloud_time = cloud.busy_s
    if res.tokens:
        res.request_cloud_rate = (res.cloud_requests / res.tokens
                                  if strategy == "ce_collm" else
                                  (1.0 if strategy in ("cloud_llm", "naive")
                                   else 0.0))
    return res
