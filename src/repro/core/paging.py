"""Block-paged KV allocation (vLLM-style) for the batched serving engine.

The dense layout pins every scheduler slot to a ``max_seq`` ring, so pool
memory is ``B x max_seq`` regardless of how long each stream actually is.
The paged layout instead carves KV storage into fixed-size *pages* of
``page_size`` tokens shared by all slots:

  * each slot owns a **block table** row mapping logical page index
    (``position // page_size``) to a physical page id, ``-1`` = unallocated;
  * a host-side **free list** hands out physical pages on demand
    (alloc-on-write: prefill scatter takes the prompt's pages, each decode
    tick takes a page only when a row crosses a page boundary);
  * retiring a slot returns all its pages in bulk and the engine
    invalidates their ``pos`` markers on device, so a reallocated page can
    never leak stale K/V into another stream's attention.

Physical page 0 is reserved as the **trash page**: rows without a mapping
(inactive slots, masked cloud rows) have their writes redirected there with
``pos = -1``, which keeps the jitted step shape-stable without a cache
merge.  Admission *reserves* the worst-case page count for a request
(``ceil((prompt + max_new) / page_size)``) so a stream admitted under
backpressure can always finish; the lazy physical allocation still means
short streams touch few pages.

This module is pure host-side bookkeeping (numpy block table + Python free
list); the device-side paged cache layout lives in
``repro.models.attention`` and the jitted gather/scatter in the decode
steps.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

TRASH_PAGE = 0


def pages_needed(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


@dataclasses.dataclass
class PagePoolStats:
    allocs: int = 0
    frees: int = 0
    high_water: int = 0          # max pages simultaneously in use


class PagePool:
    """Free-list page allocator + per-slot block tables.

    ``num_pages`` counts usable pages (the trash page is extra and never
    allocated).  ``max_logical`` bounds the logical context of one slot:
    ``block_table`` is ``(num_slots, max_logical)`` int32.
    """

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_logical: int):
        if num_pages < 1:
            raise ValueError("PagePool needs at least one usable page")
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_slots = num_slots
        self.max_logical = max_logical
        # physical ids 1..num_pages; 0 is the trash page
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(num_slots)]
        self._reserved = np.zeros((num_slots,), np.int64)
        self.block_table = np.full((num_slots, max_logical), -1, np.int32)
        self.stats = PagePoolStats()

    # -- capacity ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def reserved_pages(self) -> int:
        return int(self._reserved.sum())

    @property
    def available_pages(self) -> int:
        """Pages not yet allocated and not promised to an admitted slot."""
        return self.free_pages - self.reserved_pages

    def pages_in_use(self) -> int:
        return self.num_pages - self.free_pages

    def can_admit(self, tokens: int) -> bool:
        return pages_needed(tokens, self.page_size) <= self.available_pages

    # -- slot lifecycle ----------------------------------------------------
    def reserve(self, slot: int, tokens: int) -> int:
        """Promise the worst-case page count for a request; returns it."""
        need = pages_needed(tokens, self.page_size)
        if need > self.max_logical:
            raise ValueError(
                f"request needs {need} pages but a slot maps at most "
                f"{self.max_logical} (page_size={self.page_size})")
        if need > self.available_pages:
            raise RuntimeError(
                f"out of pages: need {need}, available {self.available_pages}")
        self._reserved[slot] += need
        return need

    def alloc(self, slot: int, logical: int) -> int:
        """Map ``block_table[slot, logical]`` to a fresh physical page."""
        if self.block_table[slot, logical] != -1:
            return int(self.block_table[slot, logical])
        if self._reserved[slot] <= 0:
            raise RuntimeError(f"slot {slot}: allocation beyond reservation")
        page = self._free.pop()
        self._reserved[slot] -= 1
        self._owned[slot].append(page)
        self.block_table[slot, logical] = page
        self.stats.allocs += 1
        self.stats.high_water = max(self.stats.high_water,
                                    self.pages_in_use())
        return page

    def free_slot(self, slot: int) -> List[int]:
        """Bulk-free a retired slot's pages; returns the freed ids (the
        engine must invalidate their ``pos`` markers on device)."""
        freed = self._owned[slot]
        self._free.extend(freed)
        self.stats.frees += len(freed)
        self._owned[slot] = []
        self._reserved[slot] = 0
        self.block_table[slot, :] = -1
        return freed
