"""Block-paged KV allocation (vLLM-style) for the batched serving engine.

The dense layout pins every scheduler slot to a ``max_seq`` ring, so pool
memory is ``B x max_seq`` regardless of how long each stream actually is.
The paged layout instead carves KV storage into fixed-size *pages* of
``page_size`` tokens shared by all slots:

  * each slot owns a **block table** row mapping logical page index
    (``position // page_size``) to a physical page id, ``-1`` = unallocated;
  * a host-side **free list** hands out physical pages on demand
    (alloc-on-write: prefill scatter takes the prompt's pages, each decode
    tick takes a page only when a row crosses a page boundary);
  * retiring a slot returns all its pages in bulk and the engine
    invalidates their ``pos`` markers on device, so a reallocated page can
    never leak stale K/V into another stream's attention.

Physical page 0 is reserved as the **trash page**: rows without a mapping
(inactive slots, masked cloud rows) have their writes redirected there with
``pos = -1``, which keeps the jitted step shape-stable without a cache
merge.

Admission is **optimistic**: the pool no longer keeps a worst-case
reservation ledger — a stream is admitted when its *prompt* pages (plus a
configurable ``watermark`` of held-back headroom pages) fit the free list,
and a decode-time ``alloc`` may therefore fail with ``OutOfPages``.  The
scheduler resolves that by **preempting** a victim stream chosen by
``select_victim`` (youngest-first / fewest-pages / LRU-arrival), freeing
its pages, and resuming it later by re-prefill or swap-in (see
``SwapPool`` and docs/kv_paging.md §Preemption).  Schedulers that want
the old never-preempt guarantee (``CollmConfig.preemption = "off"``)
re-derive the conservative worst-case admission check from
``owned_pages`` — the ledger just no longer lives in the allocator.

This module is pure host-side bookkeeping (numpy block table + Python free
list); the device-side paged cache layout lives in
``repro.models.attention`` and the jitted gather/scatter in the decode
steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

TRASH_PAGE = 0

PREEMPT_POLICIES = ("youngest", "fewest-pages", "lru")


def pages_needed(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


class OutOfPages(RuntimeError):
    """``alloc`` found an empty free list — the caller must preempt a
    victim (or fail) before retrying."""


@dataclasses.dataclass
class PagePoolStats:
    allocs: int = 0
    frees: int = 0
    high_water: int = 0          # max pages simultaneously in use


class PagePool:
    """Free-list page allocator + per-slot block tables.

    ``num_pages`` counts usable pages (the trash page is extra and never
    allocated).  ``max_logical`` bounds the logical context of one slot:
    ``block_table`` is ``(num_slots, max_logical)`` int32.  ``watermark``
    pages are held back from admission (``can_admit``) so in-flight
    streams keep some alloc-on-write headroom before the scheduler has to
    preempt; it never blocks ``alloc`` itself.
    """

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_logical: int, watermark: int = 0):
        if num_pages < 1:
            raise ValueError("PagePool needs at least one usable page")
        if not 0 <= watermark < num_pages:
            raise ValueError(
                f"watermark must be in [0, num_pages): {watermark}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_slots = num_slots
        self.max_logical = max_logical
        self.watermark = watermark
        # physical ids 1..num_pages; 0 is the trash page
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(num_slots)]
        self.block_table = np.full((num_slots, max_logical), -1, np.int32)
        self.stats = PagePoolStats()

    # -- capacity ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def available_pages(self) -> int:
        """Pages admission may take right now (free minus the watermark
        held back as decode headroom)."""
        return self.free_pages - self.watermark

    def pages_in_use(self) -> int:
        return self.num_pages - self.free_pages

    def owned_pages(self, slot: int) -> int:
        """Physical pages currently allocated to one slot."""
        return len(self._owned[slot])

    def can_admit(self, tokens: int) -> bool:
        """Optimistic admission: do ``tokens`` worth of pages fit the free
        list right now (watermark respected)?  Callers decide what
        ``tokens`` means — the prompt for optimistic admission, the full
        ``prompt + max_new`` worst case for conservative admission."""
        return pages_needed(tokens, self.page_size) <= self.available_pages

    # -- slot lifecycle ----------------------------------------------------
    def alloc(self, slot: int, logical: int) -> int:
        """Map ``block_table[slot, logical]`` to a fresh physical page.

        Raises ``OutOfPages`` when the free list is empty — under
        optimistic admission this is an expected event the scheduler
        answers with preemption, not a bookkeeping bug."""
        if self.block_table[slot, logical] != -1:
            return int(self.block_table[slot, logical])
        if logical >= self.max_logical:
            raise ValueError(
                f"slot {slot}: logical page {logical} beyond max_logical "
                f"{self.max_logical}")
        if not self._free:
            raise OutOfPages(
                f"slot {slot}: no free pages for logical page {logical} "
                f"({self.pages_in_use()}/{self.num_pages} in use)")
        page = self._free.pop()
        self._owned[slot].append(page)
        self.block_table[slot, logical] = page
        self.stats.allocs += 1
        self.stats.high_water = max(self.stats.high_water,
                                    self.pages_in_use())
        return page

    def free_slot(self, slot: int) -> List[int]:
        """Bulk-free a retired (or preempted) slot's pages; returns the
        freed ids (the engine must invalidate their ``pos`` markers on
        device)."""
        freed = self._owned[slot]
        self._free.extend(freed)
        self.stats.frees += len(freed)
        self._owned[slot] = []
        self.block_table[slot, :] = -1
        return freed


# ---------------------------------------------------------------------------
# victim selection (preemption policy)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class VictimCandidate:
    """One preemptible stream as the policy sees it."""
    slot: int
    admit_seq: int               # monotonically increasing admission order
    owned_pages: int


def select_victim(cands: Sequence[VictimCandidate], policy: str) -> int:
    """Pick the slot to preempt.  Candidates must own at least one page
    (preempting a page-less slot frees nothing).

      * ``youngest``      — most recently admitted first (vLLM default:
                            the oldest streams are closest to finishing);
      * ``fewest-pages``  — smallest checkpoint/restore cost first;
      * ``lru``           — least-recently-*arrived* (oldest admission)
                            first: long-running hogs yield to fresh work.

    Ties break on admission order (youngest), then slot index, so victim
    choice is deterministic."""
    if policy not in PREEMPT_POLICIES:
        raise ValueError(f"unknown preemption policy {policy!r} "
                         f"(choose from {PREEMPT_POLICIES})")
    cands = [c for c in cands if c.owned_pages > 0]
    if not cands:
        raise OutOfPages("no preemptible stream owns any pages")
    if policy == "youngest":
        key = lambda c: (-c.admit_seq, c.slot)
    elif policy == "fewest-pages":
        key = lambda c: (c.owned_pages, -c.admit_seq, c.slot)
    else:  # lru
        key = lambda c: (c.admit_seq, c.slot)
    return min(cands, key=key).slot


# ---------------------------------------------------------------------------
# host-side swap store
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SwapPoolStats:
    swapped_out: int = 0
    swapped_in: int = 0
    bytes_out: int = 0
    bytes_in: int = 0

    @property
    def held(self) -> int:
        return self.swapped_out - self.swapped_in


class SwapPool:
    """Host-side page store for ``CollmConfig.preemption = "swap"``.

    A preempted stream's device pages are copied here (numpy, host RAM)
    and restored bit-identically into freshly allocated physical pages at
    resume — no recompute, at the cost of PCIe/host traffic.  Snapshots
    are opaque pytrees of numpy arrays keyed by a caller-chosen id."""

    def __init__(self):
        self._store: Dict[Any, Any] = {}
        self.stats = SwapPoolStats()

    @staticmethod
    def _nbytes(snapshot: Any) -> int:
        total = 0
        stack = [snapshot]
        while stack:
            node = stack.pop()
            if isinstance(node, dict):
                stack.extend(node.values())
            elif isinstance(node, (list, tuple)):
                stack.extend(node)
            elif isinstance(node, np.ndarray):
                total += node.nbytes
        return total

    def put(self, key: Any, snapshot: Any) -> None:
        if key in self._store:
            raise KeyError(f"swap key {key!r} already held")
        self._store[key] = snapshot
        self.stats.swapped_out += 1
        self.stats.bytes_out += self._nbytes(snapshot)

    def take(self, key: Any) -> Any:
        snapshot = self._store.pop(key)
        self.stats.swapped_in += 1
        self.stats.bytes_in += self._nbytes(snapshot)
        return snapshot

    def __contains__(self, key: Any) -> bool:
        return key in self._store

    def __len__(self) -> int:
        return len(self._store)
