"""Block-paged KV allocation (vLLM-style) for the batched serving engine.

The dense layout pins every scheduler slot to a ``max_seq`` ring, so pool
memory is ``B x max_seq`` regardless of how long each stream actually is.
The paged layout instead carves KV storage into fixed-size *pages* of
``page_size`` tokens shared by all slots:

  * each slot owns a **block table** row mapping logical page index
    (``position // page_size``) to a physical page id, ``-1`` = unallocated;
  * a host-side **free list** hands out physical pages on demand
    (alloc-on-write: admission takes the prompt's pages — written either by
    a monolithic prefill scatter or chunk-by-chunk under chunked prefill —
    and each decode tick takes a page only when a row crosses a page
    boundary);
  * pages are **refcounted**: a radix-style :class:`PrefixIndex` keyed by
    page-aligned token chunks lets streams whose prompts share a token
    prefix map the *same* physical pages (``share_page``), and the first
    divergent write to a page with refcount > 1 is answered with a
    **copy-on-write** (``cow_page``: allocate a private page, device-copy
    the contents, repoint the slot's block-table entry);
  * retiring a slot decrements refcounts and returns only the pages that
    actually dropped to zero; the engine invalidates their ``pos`` markers
    on device, so a reallocated page can never leak stale K/V into another
    stream's attention.  Pages still held by the prefix cache keep their
    contents and serve future prefix hits until ``evict_prefix`` reclaims
    them under pressure.

Physical page 0 is reserved as the **trash page**: rows without a mapping
(inactive slots, masked cloud rows) have their writes redirected there with
``pos = -1``, which keeps the jitted step shape-stable without a cache
merge.

Admission is **optimistic**: the pool no longer keeps a worst-case
reservation ledger — a stream is admitted when its *prompt* pages (plus a
configurable ``watermark`` of held-back headroom pages) fit the free list,
and a decode-time ``alloc`` may therefore fail with ``OutOfPages``.  The
scheduler resolves that by **preempting** a victim stream chosen by
``select_victim`` (youngest-first / fewest-pages / LRU-arrival), freeing
its pages, and resuming it later by re-prefill or swap-in (see
``SwapPool`` and docs/kv_paging.md §Preemption).  Schedulers that want
the old never-preempt guarantee (``CollmConfig.preemption = "off"``)
re-derive the conservative worst-case admission check from
``owned_pages`` — the ledger just no longer lives in the allocator.

This module is pure host-side bookkeeping (numpy block table + Python free
list); the device-side paged cache layout lives in
``repro.models.attention`` and the jitted gather/scatter in the decode
steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

TRASH_PAGE = 0

PREEMPT_POLICIES = ("youngest", "fewest-pages", "lru")


def pages_needed(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


class OutOfPages(RuntimeError):
    """``alloc`` found an empty free list — the caller must preempt a
    victim (or fail) before retrying."""


@dataclasses.dataclass
class PagePoolStats:
    allocs: int = 0
    frees: int = 0
    high_water: int = 0          # max pages simultaneously in use
    cow_copies: int = 0          # copy-on-write page splits
    prefix_hit_tokens: int = 0   # prompt tokens served from shared pages
    prefix_evictions: int = 0    # prefix-cache entries reclaimed


@dataclasses.dataclass(frozen=True)
class PrefixHit:
    """Result of ``PagePool.match_prefix``.

    ``pages`` are the physical pages backing the matched *full* page-aligned
    chunks (``hit_tokens == len(pages) * page_size`` unless a terminal also
    matched); ``terminal`` is ``(tail_page_or_None, first_token)`` when the
    ENTIRE prompt — including a partial tail — is cached, in which case
    ``hit_tokens`` covers the whole prompt and the cached greedy first token
    can be emitted without any prefill compute."""
    pages: Tuple[int, ...]
    hit_tokens: int
    terminal: Any = None         # Optional[(Optional[int], int)]


class _PrefixNode:
    """One page-aligned token chunk in the radix prefix trie."""
    __slots__ = ("children", "page", "last_used", "terminals")

    def __init__(self, page: int = -1):
        self.children: Dict[Tuple[int, ...], "_PrefixNode"] = {}
        self.page = page
        self.last_used = 0
        self.terminals: Dict[Tuple[int, ...], "_Terminal"] = {}


class _Terminal:
    """Cached completion of a whole prompt: the (possibly partial) tail
    page plus the greedy first token the prefill produced."""
    __slots__ = ("page", "token", "last_used")

    def __init__(self, page, token: int, clock: int):
        self.page = page             # Optional[int]: None for aligned tails
        self.token = token
        self.last_used = clock


class PagePool:
    """Free-list page allocator + per-slot block tables.

    ``num_pages`` counts usable pages (the trash page is extra and never
    allocated).  ``max_logical`` bounds the logical context of one slot:
    ``block_table`` is ``(num_slots, max_logical)`` int32.  ``watermark``
    pages are held back from admission (``can_admit``) so in-flight
    streams keep some alloc-on-write headroom before the scheduler has to
    preempt; it never blocks ``alloc`` itself.  The attribute is a live
    control knob: only ``__init__`` validates it, and the engine's
    adaptive loop (``serving/adaptive.py``, docs/fleet_sim.md) raises and
    decays it between scheduler ticks in response to observed
    ``OutOfPages``/preemption pressure — mutate it freely between
    ``can_admit`` calls, never mid-allocation.

    With ``prefix_cache=True`` the pool additionally keeps a radix trie of
    page-aligned prompt token chunks (``match_prefix`` / ``insert_prefix``)
    so several slots can map the same physical page (``share_page``); every
    mapping holds a reference, the trie itself holds one more, and pages
    are only returned to the free list when the last reference drops.
    """

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_logical: int, watermark: int = 0,
                 prefix_cache: bool = False):
        if num_pages < 1:
            raise ValueError("PagePool needs at least one usable page")
        if not 0 <= watermark < num_pages:
            raise ValueError(
                f"watermark must be in [0, num_pages): {watermark}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_slots = num_slots
        self.max_logical = max_logical
        self.watermark = watermark
        # physical ids 1..num_pages; 0 is the trash page
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(num_slots)]
        self.block_table = np.full((num_slots, max_logical), -1, np.int32)
        self.stats = PagePoolStats()
        self._ref: Dict[int, int] = {}         # page -> reference count
        self.prefix_cache = prefix_cache
        self._root = _PrefixNode()             # radix trie over token chunks
        self._cached: set = set()              # pages held by the trie
        self._unfilled: set = set()            # trie pages awaiting compute
        self._clock = 0                        # LRU clock for trie entries

    # -- capacity ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def reclaimable_pages(self) -> int:
        """Pages held only by the prefix cache — ``evict_prefix`` can
        return them to the free list without touching any live stream."""
        return sum(1 for p in self._cached if self._ref.get(p, 0) == 1)

    @property
    def available_pages(self) -> int:
        """Pages admission may take right now: the free list plus what the
        prefix cache could give back on demand, minus the watermark held
        back as decode headroom."""
        return self.free_pages + self.reclaimable_pages - self.watermark

    def pages_in_use(self) -> int:
        return self.num_pages - self.free_pages

    def owned_pages(self, slot: int) -> int:
        """Physical pages currently mapped by one slot (shared included)."""
        return len(self._owned[slot])

    def shared_pages(self, slot: int) -> int:
        """How many of the slot's pages other holders also reference —
        preempting the slot frees ``owned - shared`` pages, which is what
        victim selection should weigh."""
        return sum(1 for p in self._owned[slot] if self._ref.get(p, 0) > 1)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def is_shared(self, page: int) -> bool:
        """True when a write to ``page`` would be visible to another holder
        (another slot or the prefix cache) — the copy-on-write trigger."""
        return self._ref.get(page, 0) > 1

    def can_admit(self, tokens: int, hit_pages: int = 0) -> bool:
        """Optimistic admission: do ``tokens`` worth of pages fit the free
        list right now (watermark respected)?  Callers decide what
        ``tokens`` means — the prompt for optimistic admission, the full
        ``prompt + max_new`` worst case for conservative admission.
        ``hit_pages`` discounts pages a prospective prompt would map from
        the prefix cache instead of allocating (``match_prefix``), so a
        prompt that mostly hits the cache is not over-reserved against."""
        need = pages_needed(tokens, self.page_size) - hit_pages
        return max(0, need) <= self.available_pages

    # -- slot lifecycle ----------------------------------------------------
    def alloc(self, slot: int, logical: int) -> int:
        """Map ``block_table[slot, logical]`` to a fresh physical page.

        Raises ``OutOfPages`` when the free list is empty — under
        optimistic admission this is an expected event the scheduler
        answers with preemption, not a bookkeeping bug."""
        if self.block_table[slot, logical] != -1:
            return int(self.block_table[slot, logical])
        if logical >= self.max_logical:
            raise ValueError(
                f"slot {slot}: logical page {logical} beyond max_logical "
                f"{self.max_logical}")
        if not self._free:
            raise OutOfPages(
                f"slot {slot}: no free pages for logical page {logical} "
                f"({self.pages_in_use()}/{self.num_pages} in use)")
        page = self._free.pop()
        self._owned[slot].append(page)
        self.block_table[slot, logical] = page
        self._ref[page] = 1
        self.stats.allocs += 1
        self.stats.high_water = max(self.stats.high_water,
                                    self.pages_in_use())
        return page

    def share_page(self, slot: int, logical: int, page: int) -> None:
        """Map an already-populated physical page (a prefix-cache hit) into
        ``block_table[slot, logical]``, taking one more reference instead
        of allocating."""
        if self.block_table[slot, logical] != -1:
            raise ValueError(
                f"slot {slot}: logical page {logical} already mapped")
        if self._ref.get(page, 0) < 1:
            raise ValueError(f"page {page} is not live, cannot share")
        self._owned[slot].append(page)
        self.block_table[slot, logical] = page
        self._ref[page] += 1

    def cow_page(self, slot: int, logical: int) -> Tuple[int, int]:
        """Copy-on-write split: the slot is about to write into a shared
        page.  Allocates a private page, repoints the slot's block-table
        entry, and returns ``(src, dst)`` — the caller must device-copy the
        page contents (int8 pages copy their scale rows alongside) before
        the write lands.  Raises ``OutOfPages`` like ``alloc``."""
        src = int(self.block_table[slot, logical])
        if src < 0:
            raise ValueError(f"slot {slot}: logical page {logical} unmapped")
        if not self.is_shared(src):
            raise ValueError(f"page {src} is private, no copy needed")
        if not self._free:
            raise OutOfPages(
                f"slot {slot}: no free pages for CoW of logical page "
                f"{logical} ({self.pages_in_use()}/{self.num_pages} in use)")
        dst = self._free.pop()
        self._ref[src] -= 1
        self._ref[dst] = 1
        owned = self._owned[slot]
        owned[owned.index(src)] = dst
        self.block_table[slot, logical] = dst
        self.stats.allocs += 1
        self.stats.cow_copies += 1
        self.stats.high_water = max(self.stats.high_water,
                                    self.pages_in_use())
        return src, dst

    def free_slot(self, slot: int) -> List[int]:
        """Release a retired (or preempted) slot's pages: every mapping
        drops one reference, and only pages whose count hit zero go back to
        the free list.  Returns exactly those ids — the engine must
        invalidate their ``pos`` markers on device, and must NOT touch
        pages still referenced by other slots or the prefix cache (their
        contents are live)."""
        freed: List[int] = []
        for page in self._owned[slot]:
            self._ref[page] -= 1
            if self._ref[page] == 0:
                del self._ref[page]
                freed.append(page)
        self._free.extend(freed)
        self.stats.frees += len(freed)
        self._owned[slot] = []
        self.block_table[slot, :] = -1
        return freed

    # -- prefix cache (radix trie over page-aligned token chunks) ----------
    def _chunks(self, tokens: Sequence[int]):
        ps = self.page_size
        n_full = len(tokens) // ps
        return [tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
                for i in range(n_full)]

    def match_prefix(self, tokens: Sequence[int]) -> PrefixHit:
        """Walk the trie along the prompt's page-aligned chunks.  Returns
        the shared pages covering the longest cached prefix; when the whole
        prompt (full chunks + exact tail) is cached, ``terminal`` carries
        the tail page and the memoized greedy first token."""
        self._clock += 1
        node, pages = self._root, []
        for key in self._chunks(tokens):
            child = node.children.get(key)
            if child is None:
                return PrefixHit(tuple(pages), len(pages) * self.page_size)
            child.last_used = self._clock
            pages.append(child.page)
            node = child
        tail = tuple(int(t) for t in tokens[len(pages) * self.page_size:])
        term = node.terminals.get(tail)
        if term is None:
            return PrefixHit(tuple(pages), len(pages) * self.page_size)
        term.last_used = self._clock
        return PrefixHit(tuple(pages), len(tokens),
                         (term.page, term.token))

    def insert_prefix(self, slot: int, tokens: Sequence[int]) -> List[int]:
        """Register the slot's full-chunk prompt pages in the trie.  New
        chunks take the slot's own pages with one cache reference and are
        *unfilled* until the owning stream's prefill writes them
        (``mark_filled``) — a concurrent sharer must stall its suffix
        compute until then.  Returns the newly registered pages."""
        self._clock += 1
        node, added = self._root, []
        for i, key in enumerate(self._chunks(tokens)):
            child = node.children.get(key)
            if child is None:
                page = int(self.block_table[slot, i])
                if page < 0:
                    break                      # beyond the slot's mapping
                child = _PrefixNode(page)
                node.children[key] = child
                self._ref[page] += 1
                self._cached.add(page)
                self._unfilled.add(page)
                added.append(page)
            child.last_used = self._clock
            node = child
        return added

    def insert_terminal(self, slot: int, tokens: Sequence[int],
                        first_token: int) -> None:
        """Cache a completed prompt end-to-end: the partial tail page (if
        any) plus the greedy first token, so an identical future prompt
        skips prefill entirely."""
        self._clock += 1
        node = self._root
        chunks = self._chunks(tokens)
        for key in chunks:
            node = node.children.get(key)
            if node is None:
                return                         # prefix chunks were evicted
        tail = tuple(int(t) for t in tokens[len(chunks) * self.page_size:])
        if tail in node.terminals:
            return
        page = None
        if tail:
            page = int(self.block_table[slot, len(chunks)])
            if page < 0:
                return
            self._ref[page] += 1
            self._cached.add(page)
        node.terminals[tail] = _Terminal(page, int(first_token), self._clock)

    def mark_filled(self, page: int) -> None:
        """The owning stream's prefill chunk for this trie page landed on
        device — sharers may now compute past it."""
        self._unfilled.discard(page)

    def pages_filled(self, pages: Sequence[int]) -> bool:
        return not any(p in self._unfilled for p in pages)

    def _evictable(self):
        """Yield ``(last_used, kind_order, remover, page)`` for every trie
        leaf whose page no live stream maps (terminal entries, then chunk
        nodes with no children or terminals)."""
        out = []

        def walk(node: _PrefixNode):
            for tail, term in node.terminals.items():
                if term.page is None or self._ref.get(term.page, 0) == 1:
                    out.append((term.last_used, 0,
                                (node.terminals, tail), term.page))
            for key, child in node.children.items():
                walk(child)
                if not child.children and not child.terminals \
                        and self._ref.get(child.page, 0) == 1:
                    out.append((child.last_used, 1,
                                (node.children, key), child.page))

        walk(self._root)
        return out

    def evict_prefix(self, need: int) -> List[int]:
        """Reclaim least-recently-used prefix-cache entries until ``need``
        pages came back to the free list (or nothing evictable remains).
        Returns the freed ids — the engine must invalidate their ``pos``
        markers on device before they are reallocated."""
        freed: List[int] = []
        while len(freed) < need:
            cands = self._evictable()
            if not cands:
                break
            progress = False
            for _, _, (container, key), page in sorted(
                    cands, key=lambda e: (e[0], e[1])):
                del container[key]
                self.stats.prefix_evictions += 1
                progress = True
                if page is not None:
                    self._cached.discard(page)
                    self._unfilled.discard(page)
                    self._ref[page] -= 1
                    if self._ref[page] == 0:
                        del self._ref[page]
                        self._free.append(page)
                        self.stats.frees += 1
                        freed.append(page)
                        if len(freed) >= need:
                            break
            if not progress:
                break
        return freed


# ---------------------------------------------------------------------------
# victim selection (preemption policy)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class VictimCandidate:
    """One preemptible stream as the policy sees it."""
    slot: int
    admit_seq: int               # monotonically increasing admission order
    owned_pages: int
    shared_pages: int = 0        # of those, pages with refcount > 1

    @property
    def reclaimable(self) -> int:
        """Pages preempting this stream would actually free — shared pages
        stay live in their other holders, so they don't count."""
        return self.owned_pages - self.shared_pages


def select_victim(cands: Sequence[VictimCandidate], policy: str) -> int:
    """Pick the slot to preempt.  Candidates must have at least one
    *reclaimable* page: a slot whose pages are all shared (refcount > 1)
    is skipped outright — preempting it frees nothing, the pages stay
    live in the prefix cache or in their co-holders.

      * ``youngest``      — most recently admitted first (vLLM default:
                            the oldest streams are closest to finishing);
      * ``fewest-pages``  — smallest reclaim benefit first (cheapest
                            checkpoint/restore; shared pages down-rank a
                            candidate because they don't come back);
      * ``lru``           — least-recently-*arrived* (oldest admission)
                            first: long-running hogs yield to fresh work.

    Ties break on admission order (youngest), then slot index, so victim
    choice is deterministic."""
    if policy not in PREEMPT_POLICIES:
        raise ValueError(f"unknown preemption policy {policy!r} "
                         f"(choose from {PREEMPT_POLICIES})")
    cands = [c for c in cands if c.reclaimable > 0]
    if not cands:
        raise OutOfPages("no preemptible stream owns any reclaimable pages")
    if policy == "youngest":
        key = lambda c: (-c.admit_seq, c.slot)
    elif policy == "fewest-pages":
        key = lambda c: (c.reclaimable, -c.admit_seq, c.slot)
    else:  # lru
        key = lambda c: (c.admit_seq, c.slot)
    return min(cands, key=key).slot


# ---------------------------------------------------------------------------
# host-side swap store
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SwapPoolStats:
    swapped_out: int = 0
    swapped_in: int = 0
    bytes_out: int = 0
    bytes_in: int = 0

    @property
    def held(self) -> int:
        return self.swapped_out - self.swapped_in


class SwapPool:
    """Host-side page store for ``CollmConfig.preemption = "swap"``.

    A preempted stream's device pages are copied here (numpy, host RAM)
    and restored bit-identically into freshly allocated physical pages at
    resume — no recompute, at the cost of PCIe/host traffic.  Snapshots
    are opaque pytrees of numpy arrays keyed by a caller-chosen id."""

    def __init__(self):
        self._store: Dict[Any, Any] = {}
        self.stats = SwapPoolStats()

    @staticmethod
    def _nbytes(snapshot: Any) -> int:
        total = 0
        stack = [snapshot]
        while stack:
            node = stack.pop()
            if isinstance(node, dict):
                stack.extend(node.values())
            elif isinstance(node, (list, tuple)):
                stack.extend(node)
            elif isinstance(node, np.ndarray):
                total += node.nbytes
        return total

    def put(self, key: Any, snapshot: Any) -> None:
        if key in self._store:
            raise KeyError(f"swap key {key!r} already held")
        self._store[key] = snapshot
        self.stats.swapped_out += 1
        self.stats.bytes_out += self._nbytes(snapshot)

    def take(self, key: Any) -> Any:
        snapshot = self._store.pop(key)
        self.stats.swapped_in += 1
        self.stats.bytes_in += self._nbytes(snapshot)
        return snapshot

    def __contains__(self, key: Any) -> bool:
        return key in self._store

    def __len__(self) -> int:
        return len(self._store)
