"""CE-CoLLM co-inference steps (paper §4.4, Algorithm 1).

Building blocks:

  * ``edge_step``        — edge partition (layers 1..l_ee2) with exits at
                           l_ee1/l_ee2; emits the quantized l_ee1 upload.
  * ``cloud_step``       — cloud partition (layers l_ee1+1..L) continuing
                           from an uploaded hidden state; supports lazy KV
                           *backfill* of early-exited tokens (see DESIGN.md).
  * ``standalone_step``  — paper's low-latency edge standalone mode (last
                           exit is the output head; no threshold).
  * ``full_step``        — undivided model (cloud-deployment baseline).
  * ``fused_step``       — single-graph adaptive step with a bounded upload
                           ring and ``lax.cond``-gated cloud compute: the
                           TPU-native expression of "request cloud only on
                           low confidence".  θ=1.0 reproduces the full model
                           exactly (unit-tested invariant).

Host-level multi-client serving (with the ContentManager and the network
simulator) lives in ``repro.serving.engine``; this module is pure JAX.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.exits import ExitDecision, evaluate_exit, first_confident_exit
from repro.core.transport import dequantize, quantize
from repro.models.transformer import Model

Params = Dict[str, Any]
Pytree = Any


@dataclasses.dataclass(frozen=True)
class CollmConfig:
    theta: float = 0.8
    wire_format: str = "float16"      # paper: float16; beyond-paper: int8
    max_pending: int = 4              # upload ring size (fused mode)
    speculative: bool = False         # cloud always computes (latency-hiding)
    # Paper-faithful: the content manager RELEASES hidden states of tokens
    # that exited early, so the cloud KV cache has gaps at those positions
    # (this is why Table 2 ROUGE-L < 1 for theta < 1).  backfill=True is the
    # beyond-paper fix: ringed uploads are run through the cloud partition on
    # the next request, keeping cloud KV exact at modest extra cloud compute.
    backfill: bool = False


class EdgeStepOut(NamedTuple):
    decisions: Dict[int, ExitDecision]
    token: jax.Array            # (B,) first-confident-exit token
    exited: jax.Array           # (B,) bool
    upload: Dict[str, jax.Array]   # quantized l_ee1 hidden (wire packet)
    caches: Dict[int, Pytree]


def _tree_where(pred: jax.Array, a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


class CoLLM:
    """Binds a Model to the paper's partition + gating machinery."""

    def __init__(self, model: Model, ccfg: CollmConfig = CollmConfig()):
        cfg = model.cfg
        if len(cfg.exit_layers) < 1:
            raise ValueError("CE-CoLLM requires at least one exit layer")
        self.model = model
        self.ccfg = ccfg
        self.l_ee1 = cfg.exit_layers[0]
        self.l_ee2 = cfg.exit_layers[-1]
        self.edge_segs = model.edge_segments(self.l_ee2)
        self.cloud_segs = model.cloud_segments(self.l_ee1)
        # segments strictly before l_ee1 (their output is the upload point)
        self.pre_segs = tuple(i for i, s in enumerate(model.segments)
                              if s.end <= self.l_ee1)

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def init_edge_cache(self, batch: int, max_seq: int, dtype=None):
        return self.model.init_cache(batch, max_seq, self.edge_segs,
                                     dtype=dtype)

    def init_cloud_cache(self, batch: int, max_seq: int, dtype=None):
        return self.model.init_cache(batch, max_seq, self.cloud_segs,
                                     dtype=dtype)

    # ------------------------------------------------------------------
    # prefill (prompt processing)
    # ------------------------------------------------------------------
    def edge_prefill(self, params: Params, batch: Dict[str, jax.Array],
                     caches: Dict[int, Pytree]):
        """Edge processes the prompt; returns (exit decisions at last pos,
        l_ee1 hidden sequence for upload, caches)."""
        x, exit_h, new_caches, ctx = self.model.prefill(
            params, batch, caches, self.edge_segs)
        h1_seq = exit_h[self.l_ee1]
        decisions = {l: evaluate_exit(
            self.model.exit_logits(params, l, h[:, -1:]))
            for l, h in exit_h.items()}
        return decisions, h1_seq, new_caches

    def cloud_prefill(self, params: Params, h1_seq: jax.Array,
                      caches: Dict[int, Pytree],
                      enc_out: Optional[jax.Array] = None):
        """Cloud builds its KV over the uploaded prompt hidden states."""
        from repro.models.blocks import BlockCtx
        ctx = BlockCtx(positions=jnp.arange(h1_seq.shape[1]), enc_out=enc_out,
                       dtype=self.model.compute_dtype)
        x, _, _, new_caches = self.model.run_segments(
            params, h1_seq, ctx, self.cloud_segs, caches=caches,
            collect_exits=False)
        logits = self.model.logits(params, x[:, -1:])
        return logits, new_caches

    # ------------------------------------------------------------------
    # decode steps
    # ------------------------------------------------------------------
    def edge_step(self, params: Params, token: jax.Array,
                  caches: Dict[int, Pytree], pos: jax.Array) -> EdgeStepOut:
        x, exit_h, new_caches = self.model.decode_step(
            params, token, caches, pos, self.edge_segs)
        decisions = {l: evaluate_exit(self.model.exit_logits(params, l, h))
                     for l, h in exit_h.items()}
        tok, exited, _ = first_confident_exit(decisions, self.ccfg.theta)
        upload = quantize(exit_h[self.l_ee1], self.ccfg.wire_format)
        return EdgeStepOut(decisions, tok, exited, upload, new_caches)

    def cloud_step(self, params: Params, upload: Dict[str, jax.Array],
                   caches: Dict[int, Pytree], pos: jax.Array
                   ) -> Tuple[jax.Array, Dict[int, Pytree]]:
        """One uploaded hidden -> final logits (paper Algorithm 1 lines 29-37).
        Also used for KV backfill of early-exited positions."""
        hidden = dequantize(upload, self.model.compute_dtype)
        x, _, new_caches = self.model.decode_from_hidden(
            params, hidden, caches, pos, self.cloud_segs)
        return self.model.logits(params, x)[:, 0], new_caches

    def standalone_step(self, params: Params, token: jax.Array,
                        caches: Dict[int, Pytree], pos: jax.Array):
        """Edge standalone (low-latency) mode: last exit is the output."""
        x, exit_h, new_caches = self.model.decode_step(
            params, token, caches, pos, self.edge_segs)
        d = evaluate_exit(self.model.exit_logits(params, self.l_ee2,
                                                 exit_h[self.l_ee2]))
        return d.token, d, new_caches

    def full_step(self, params: Params, token: jax.Array,
                  caches: Dict[int, Pytree], pos: jax.Array):
        """Undivided model — the cloud-deployment baseline."""
        x, _, new_caches = self.model.decode_step(
            params, token, caches, pos, collect_exits=False)
        logits = self.model.logits(params, x)[:, 0]
        return jnp.argmax(logits, -1).astype(jnp.int32), logits, new_caches

    # ------------------------------------------------------------------
    # fused adaptive step (single-graph; TPU-native cond-gated cloud)
    # ------------------------------------------------------------------
    def init_fused_state(self, batch: int, max_seq: int, dtype=None):
        d = self.model.cfg.d_model
        k = self.ccfg.max_pending
        dt = dtype or self.model.compute_dtype
        return {
            "edge": self.init_edge_cache(batch, max_seq, dtype),
            "cloud": self.init_cloud_cache(batch, max_seq, dtype),
            "ring_h": jnp.zeros((k, batch, 1, d), dt),
            "ring_pos": jnp.zeros((k,), jnp.int32),
            "count": jnp.zeros((), jnp.int32),
        }

    def fused_step(self, params: Params, token: jax.Array, state: Pytree,
                   pos: jax.Array):
        """token: (B,1).  Returns (next_token (B,), info, new_state).

        Semantics: every step the l_ee1 hidden is pushed into the upload
        ring (paper's parallel upload).  Cloud compute fires only when some
        row is below θ or the ring is full; it then *backfills* the KV of
        all ringed positions in order — so the cloud cache is always exact.
        """
        model, ccfg = self.model, self.ccfg
        k = ccfg.max_pending if ccfg.backfill else 1
        out = self.edge_step(params, token, state["edge"], pos)

        # simulate the wire: quantize -> dequantize
        h1 = dequantize(out.upload, model.compute_dtype)
        # paper-faithful (no backfill): only the newest upload is retained —
        # the content manager releases the rest (gapped cloud KV).
        idx = state["count"] if ccfg.backfill else jnp.zeros((), jnp.int32)
        ring_h = jax.lax.dynamic_update_index_in_dim(
            state["ring_h"], h1.astype(state["ring_h"].dtype), idx, 0)
        ring_pos = jax.lax.dynamic_update_index_in_dim(
            state["ring_pos"], jnp.asarray(pos, jnp.int32), idx, 0)
        count = idx + 1

        need_cloud = ~jnp.all(out.exited)
        if ccfg.backfill:
            need_cloud = need_cloud | (count >= k)   # ring full -> flush
        if ccfg.speculative:
            need_cloud = jnp.ones((), bool)

        b = token.shape[0]
        vocab = model.cfg.vocab_size

        def run_cloud(operand):
            caches, rh, rp, cnt = operand

            def body(carry, i):
                c = carry
                logits_i, c_new = self.cloud_step(
                    params, {"data": rh[i]}, c, rp[i])
                valid = i < cnt
                c = _tree_where(valid, c_new, c)
                return c, jnp.where(valid, logits_i,
                                    jnp.zeros((b, vocab), logits_i.dtype))

            caches, all_logits = jax.lax.scan(body, caches, jnp.arange(k))
            final_logits = all_logits[jnp.maximum(cnt - 1, 0)]
            return caches, final_logits, jnp.zeros((), jnp.int32)

        def skip_cloud(operand):
            caches, rh, rp, cnt = operand
            return caches, jnp.zeros((b, vocab), jnp.float32), cnt

        cloud_caches, cloud_logits, new_count = jax.lax.cond(
            need_cloud, run_cloud, skip_cloud,
            (state["cloud"], ring_h, ring_pos, count))

        cloud_tok = jnp.argmax(cloud_logits, -1).astype(jnp.int32)
        next_token = jnp.where(out.exited, out.token, cloud_tok)

        new_state = {"edge": out.caches, "cloud": cloud_caches,
                     "ring_h": ring_h, "ring_pos": ring_pos,
                     "count": new_count}
        info = {"exited": out.exited, "need_cloud": need_cloud,
                "confidences": {l: d.confidence
                                for l, d in out.decisions.items()}}
        return next_token, info, new_state
