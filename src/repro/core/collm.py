"""CE-CoLLM co-inference steps (paper §4.4, Algorithm 1).

Building blocks:

  * ``edge_step``        — edge partition (layers 1..l_ee2) with exits at
                           l_ee1/l_ee2; emits the quantized l_ee1 upload.
  * ``cloud_step``       — cloud partition (layers l_ee1+1..L) continuing
                           from an uploaded hidden state; supports lazy KV
                           *backfill* of early-exited tokens (see DESIGN.md).
  * ``standalone_step``  — paper's low-latency edge standalone mode (last
                           exit is the output head; no threshold).
  * ``full_step``        — undivided model (cloud-deployment baseline).
  * ``fused_step``       — single-graph adaptive step with per-row upload
                           rings and ``lax.cond``-gated cloud compute: the
                           TPU-native expression of "request cloud only on
                           low confidence".  θ=1.0 reproduces the full model
                           exactly (unit-tested invariant).

All decode steps accept ``pos`` as a scalar or a per-row (B,) vector, and
cloud compute is gated per row (``cloud_step_masked`` merges cache updates
only for below-θ rows) — the primitives behind the continuous-batching
scheduler in ``repro.serving.engine``.  Every step also takes an optional
``block_tbl`` for the block-paged KV layout
(``CollmConfig.kv_layout="paged"``): K/V then lives in a page pool shared
across rows and masked rows write to the trash page instead of being
merged (see docs/kv_paging.md).

Host-level multi-client serving (with the ContentManager and the network
simulator) lives in ``repro.serving.engine``; this module is pure JAX.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.exits import ExitDecision, evaluate_exit, first_confident_exit
from repro.core.transport import dequantize, quantize
from repro.models.transformer import Model

Params = Dict[str, Any]
Pytree = Any


@dataclasses.dataclass(frozen=True)
class CollmConfig:
    theta: float = 0.8
    wire_format: str = "float16"      # paper: float16; beyond-paper: int8
    max_pending: int = 4              # upload ring size (fused mode)
    # Latency hiding (paper §4.4): the cloud computes for EVERY row and the
    # edge commits a *provisional* exit-head token without waiting — the
    # fused step gates cloud compute on all rows, and the batched engine
    # reconciles the provisional token against the cloud reply when it
    # arrives (keep on match, rewind-and-replace on mismatch, keep on
    # deadline miss).  Requires greedy decoding + attention-only models in
    # the batched path (rewind re-decodes positions).
    speculative: bool = False
    # Draft length of the speculative path: a below-θ row keeps committing
    # up to ``spec_k`` provisional exit tokens into one *draft*, then ships
    # the whole draft as a single verification request; the cloud scores
    # all k positions in ONE masked ring pass and the engine accepts the
    # longest agreeing prefix (rewinding only the rejected suffix).
    # spec_k=1 is exactly the classic per-token speculative path.
    spec_k: int = 1
    # Paper-faithful: the content manager RELEASES hidden states of tokens
    # that exited early, so the cloud KV cache has gaps at those positions
    # (this is why Table 2 ROUGE-L < 1 for theta < 1).  backfill=True is the
    # beyond-paper fix: ringed uploads are run through the cloud partition on
    # the next request, keeping cloud KV exact at modest extra cloud compute.
    backfill: bool = False
    # KV layout of the batched serving engine: "dense" pins each slot to a
    # max_seq ring (memory B x max_seq); "paged" shares a block-paged pool
    # across slots (memory num_pages x page_size; see docs/kv_paging.md).
    # Release-mode gaps survive either way: a gapped position is simply a
    # page slot whose pos marker was never written.
    kv_layout: str = "dense"
    page_size: int = 16               # tokens per KV page (paged layout)
    # Storage dtype of the paged KV pool.  "int8" quantizes K/V per
    # page-row on write (one absmax scale per (token, kv_head) row, the
    # transport quantizer's scaling) and dequantizes at gather — in-kernel
    # for the Pallas paged flash-decode, so int8 pages cut decode HBM
    # traffic instead of being expanded in XLA first.  Swap snapshots and
    # admission scatters carry the quantized pages + scales verbatim, so
    # preemption swap bytes shrink by the same factor.  float32 stays
    # bit-identical to the dense layout; int8 trades bounded quantization
    # error (see docs/kv_paging.md §Quantized pages) for ~3.4x less KV
    # traffic.  Only meaningful with kv_layout="paged".
    kv_dtype: str = "float32"         # "float32" | "int8"
    # Paged-KV preemption (docs/kv_paging.md §Preemption).  "off" keeps the
    # conservative worst-case admission check (a stream admitted under
    # backpressure can always finish, but the pool is sized for worst
    # cases that rarely materialize).  Otherwise admission is optimistic —
    # only the prompt's pages need to fit — and a decode-time OutOfPages
    # preempts a victim stream: its stream state is checkpointed, its
    # pages freed, and it resumes later by "recompute" (re-prefill the KV
    # from its token prefix) or "swap" (pages round-trip through a
    # host-side SwapPool).  Preemption is invisible in output space:
    # greedy token streams are identical to an un-preempted run.
    preemption: str = "off"           # "off" | "recompute" | "swap"
    preempt_policy: str = "youngest"  # "youngest" | "fewest-pages" | "lru"
    # Chunked prefill admission (docs/serving.md): instead of one
    # monolithic padded prefill at admission, the prompt is prefilled in
    # page-sized chunks interleaved with decode ticks (a per-slot
    # ``prefill_remaining`` state machine), so a long prompt stops
    # monopolizing an engine tick.  Requires kv_layout="paged" and an
    # attention-only decoder-only model (the chunk step rides the paged
    # decode write path).  Chunked runs are token-identical to each other
    # but may differ from the monolithic path in float ulps (different
    # reduction order) — comparisons should hold the admission mode fixed.
    chunked_prefill: bool = False
    # Radix prefix sharing + copy-on-write (docs/kv_paging.md §Prefix
    # sharing): the PagePool keeps a trie of page-aligned prompt token
    # chunks so streams whose prompts share a prefix map the SAME physical
    # pages (refcounted); the first divergent write to a shared page
    # triggers a copy-on-write split.  Identical whole prompts additionally
    # cache their greedy first token, skipping prefill entirely.  Requires
    # chunked_prefill=True (suffix-only compute) and greedy sampling.
    prefix_share: bool = False
    # Cloud execution mesh (docs/sharding.md): a (data, model) device grid,
    # e.g. (2, 4), the cloud partition's jitted steps compile against —
    # params placed via role-based NamedShardings, the pooled batch-major
    # cloud KV via cache_shardings, residual/logits constraints baked into
    # the cloud traces.  None (the default) keeps the single-device path:
    # no mesh, no policy, plain jax.jit.  Needs prod(cloud_mesh) visible
    # devices (locally: XLA_FLAGS=--xla_force_host_platform_device_count=N).
    cloud_mesh: Optional[Tuple[int, int]] = None


class EdgeStepOut(NamedTuple):
    decisions: Dict[int, ExitDecision]
    token: jax.Array            # (B,) first-confident-exit token
    exited: jax.Array           # (B,) bool
    upload: Dict[str, jax.Array]   # quantized l_ee1 hidden (wire packet)
    caches: Dict[int, Pytree]


def _where_rows(pred: jax.Array, a: jax.Array, b: jax.Array,
                axis: int) -> jax.Array:
    """Row-wise select: pred is (B,) and ``axis`` is the batch axis of a/b."""
    shape = [1] * a.ndim
    shape[axis] = pred.shape[0]
    return jnp.where(pred.reshape(shape), a, b)


class CoLLM:
    """Binds a Model to the paper's partition + gating machinery."""

    def __init__(self, model: Model, ccfg: CollmConfig = CollmConfig()):
        cfg = model.cfg
        if len(cfg.exit_layers) < 1:
            raise ValueError("CE-CoLLM requires at least one exit layer")
        if ccfg.kv_dtype not in ("float32", "int8"):
            raise ValueError(f"kv_dtype must be 'float32' or 'int8', "
                             f"got {ccfg.kv_dtype!r}")
        if ccfg.kv_dtype == "int8" and ccfg.kv_layout != "paged":
            raise ValueError('kv_dtype="int8" requires kv_layout="paged" '
                             "(dense rings stay full precision)")
        if ccfg.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {ccfg.spec_k}")
        if ccfg.spec_k > 1 and not ccfg.speculative:
            raise ValueError("spec_k > 1 requires speculative=True "
                             "(drafting generalizes the speculative path)")
        if ccfg.chunked_prefill and ccfg.kv_layout != "paged":
            raise ValueError('chunked_prefill=True requires kv_layout='
                             '"paged" (chunks ride the paged write path)')
        if ccfg.prefix_share and not ccfg.chunked_prefill:
            raise ValueError("prefix_share=True requires chunked_prefill="
                             "True (suffix-only compute needs chunk-"
                             "granular admission)")
        if ccfg.cloud_mesh is not None:
            cm_ = tuple(ccfg.cloud_mesh)
            if len(cm_) != 2 or any(int(a) < 1 for a in cm_):
                raise ValueError(f"cloud_mesh must be a (data, model) pair "
                                 f"of positive ints, got "
                                 f"{ccfg.cloud_mesh!r}")
        self.model = model
        self.ccfg = ccfg
        self.l_ee1 = cfg.exit_layers[0]
        self.l_ee2 = cfg.exit_layers[-1]
        self.edge_segs = model.edge_segments(self.l_ee2)
        self.cloud_segs = model.cloud_segments(self.l_ee1)
        # segments strictly before l_ee1 (their output is the upload point)
        self.pre_segs = tuple(i for i, s in enumerate(model.segments)
                              if s.end <= self.l_ee1)

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def init_edge_cache(self, batch: int, max_seq: int, dtype=None):
        return self.model.init_cache(batch, max_seq, self.edge_segs,
                                     dtype=dtype)

    def init_cloud_cache(self, batch: int, max_seq: int, dtype=None):
        return self.model.init_cache(batch, max_seq, self.cloud_segs,
                                     dtype=dtype)

    def init_edge_cache_paged(self, batch: int, num_pages: int,
                              page_size: int, dtype=None):
        return self.model.init_paged_cache(batch, num_pages, page_size,
                                           self.edge_segs, dtype=dtype,
                                           kv_dtype=self.ccfg.kv_dtype)

    def init_cloud_cache_paged(self, batch: int, num_pages: int,
                               page_size: int, dtype=None):
        return self.model.init_paged_cache(batch, num_pages, page_size,
                                           self.cloud_segs, dtype=dtype,
                                           kv_dtype=self.ccfg.kv_dtype)

    # ------------------------------------------------------------------
    # prefill (prompt processing)
    # ------------------------------------------------------------------
    def edge_prefill(self, params: Params, batch: Dict[str, jax.Array],
                     caches: Dict[int, Pytree]):
        """Edge processes the prompt; returns (exit decisions at last pos,
        l_ee1 hidden sequence for upload, caches)."""
        x, exit_h, new_caches, ctx = self.model.prefill(
            params, batch, caches, self.edge_segs)
        h1_seq = exit_h[self.l_ee1]
        decisions = {l: evaluate_exit(
            self.model.exit_logits(params, l, h[:, -1:]))
            for l, h in exit_h.items()}
        return decisions, h1_seq, new_caches

    def cloud_prefill(self, params: Params, h1_seq: jax.Array,
                      caches: Dict[int, Pytree],
                      enc_out: Optional[jax.Array] = None):
        """Cloud builds its KV over the uploaded prompt hidden states."""
        from repro.models.blocks import BlockCtx
        ctx = BlockCtx(positions=jnp.arange(h1_seq.shape[1]), enc_out=enc_out,
                       dtype=self.model.compute_dtype)
        x, _, _, new_caches = self.model.run_segments(
            params, h1_seq, ctx, self.cloud_segs, caches=caches,
            collect_exits=False)
        logits = self.model.logits(params, x[:, -1:])
        return logits, new_caches

    # ------------------------------------------------------------------
    # right-padded prefill (shape-stable admission for the batch scheduler)
    # ------------------------------------------------------------------
    def edge_prefill_padded(self, params: Params, tokens: jax.Array,
                            true_len: jax.Array, caches: Dict[int, Pytree]):
        """Edge prefill over a right-padded prompt (tokens: (1, Lb)).

        Pad positions are causally invisible to real tokens, so the real
        activations are bit-identical to an unpadded prefill; pad cache slots
        are invalidated afterwards.  Exit decisions are evaluated at the TRUE
        last position.  Compiles once per length bucket, never per prompt."""
        x, exit_h, new_caches, _ = self.model.prefill(
            params, {"tokens": tokens}, caches, self.edge_segs)
        last = jnp.asarray(true_len, jnp.int32) - 1
        decisions = {l: evaluate_exit(self.model.exit_logits(
            params, l, jax.lax.dynamic_slice_in_dim(h, last, 1, axis=1)))
            for l, h in exit_h.items()}
        new_caches = self.model.invalidate_cache_after(new_caches, true_len)
        return decisions, exit_h[self.l_ee1], new_caches

    def cloud_prefill_padded(self, params: Params, h1_seq: jax.Array,
                             true_len: jax.Array, caches: Dict[int, Pytree],
                             enc_out: Optional[jax.Array] = None):
        """Cloud prefill over a right-padded prompt upload; logits taken at
        the true last position, pad cache slots invalidated."""
        from repro.models.blocks import BlockCtx
        ctx = BlockCtx(positions=jnp.arange(h1_seq.shape[1]), enc_out=enc_out,
                       dtype=self.model.compute_dtype)
        x, _, _, new_caches = self.model.run_segments(
            params, h1_seq, ctx, self.cloud_segs, caches=caches,
            collect_exits=False)
        last = jnp.asarray(true_len, jnp.int32) - 1
        logits = self.model.logits(
            params, jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1))
        new_caches = self.model.invalidate_cache_after(new_caches, true_len)
        return logits, new_caches

    def full_prefill_padded(self, params: Params, tokens: jax.Array,
                            true_len: jax.Array, caches: Dict[int, Pytree]):
        """Undivided-model prefill over a right-padded prompt (cloud
        baseline rows of the batch scheduler)."""
        x, _, new_caches, _ = self.model.prefill(
            params, {"tokens": tokens}, caches)
        last = jnp.asarray(true_len, jnp.int32) - 1
        logits = self.model.logits(
            params, jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1))
        new_caches = self.model.invalidate_cache_after(new_caches, true_len)
        return logits, new_caches

    # ------------------------------------------------------------------
    # chunked prefill (page-sized chunks interleaved with decode ticks)
    # ------------------------------------------------------------------
    def edge_prefill_chunk(self, params: Params, tokens: jax.Array,
                           pos0: jax.Array, chunk_len: jax.Array,
                           caches: Dict[int, Pytree],
                           block_tbl: jax.Array):
        """Edge prefill of ONE page-sized prompt chunk (tokens: (1, C),
        right-padded to the page size; ``pos0`` is the chunk's first
        absolute position, ``chunk_len`` its true token count).

        Rides the paged decode write path (``chunk_attention_paged``): KV
        rows land in the pages the block table maps, pad positions write to
        the trash page via the per-token write mask.  Shapes are fixed at
        (1, page_size) so every chunk of every stream compiles once and —
        crucially for prefix sharing — computes bit-identical page content
        for identical (tokens, pos0).  Returns (decisions at the chunk's
        true last position, l_ee1 hidden chunk for upload, caches)."""
        c = tokens.shape[1]
        wm = (jnp.arange(c, dtype=jnp.int32)[None, :]
              < jnp.asarray(chunk_len, jnp.int32))
        x, exit_h, new_caches = self.model.decode_step(
            params, tokens, caches, pos0, self.edge_segs,
            block_tbl=block_tbl, write_mask=wm)
        last = jnp.asarray(chunk_len, jnp.int32) - 1
        decisions = {l: evaluate_exit(self.model.exit_logits(
            params, l, jax.lax.dynamic_slice_in_dim(h, last, 1, axis=1)))
            for l, h in exit_h.items()}
        return decisions, exit_h[self.l_ee1], new_caches

    def cloud_prefill_chunk(self, params: Params, h1: jax.Array,
                            pos0: jax.Array, chunk_len: jax.Array,
                            caches: Dict[int, Pytree],
                            block_tbl: jax.Array):
        """Cloud prefill of one uploaded hidden chunk (h1: (1, C, d));
        returns (logits at the chunk's true last position, caches).  The
        logits only matter for the prompt's final chunk — earlier chunks
        call this purely for the KV side effect."""
        c = h1.shape[1]
        wm = (jnp.arange(c, dtype=jnp.int32)[None, :]
              < jnp.asarray(chunk_len, jnp.int32))
        x, _, new_caches = self.model.decode_from_hidden(
            params, h1, caches, pos0, self.cloud_segs,
            block_tbl=block_tbl, write_mask=wm)
        last = jnp.asarray(chunk_len, jnp.int32) - 1
        logits = self.model.logits(
            params, jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1))
        return logits[:, 0], new_caches

    # ------------------------------------------------------------------
    # decode steps
    # ------------------------------------------------------------------
    def edge_step(self, params: Params, token: jax.Array,
                  caches: Dict[int, Pytree], pos: jax.Array,
                  block_tbl: Optional[jax.Array] = None) -> EdgeStepOut:
        x, exit_h, new_caches = self.model.decode_step(
            params, token, caches, pos, self.edge_segs, block_tbl=block_tbl)
        decisions = {l: evaluate_exit(self.model.exit_logits(params, l, h))
                     for l, h in exit_h.items()}
        tok, exited, _ = first_confident_exit(decisions, self.ccfg.theta)
        upload = quantize(exit_h[self.l_ee1], self.ccfg.wire_format)
        return EdgeStepOut(decisions, tok, exited, upload, new_caches)

    def fused_exit_upload(self, params: Params, hidden: jax.Array, *,
                          interpret: Optional[bool] = None,
                          use_kernel: bool = True):
        """TPU hot path for the l_ee1 exit + upload: ONE Pallas launch
        (``kernels/exit_quant``) over the hidden tile computes the exit
        decision (confidence + argmax token) AND the int8 wire packet,
        replacing the two-launch exit_logits -> evaluate_exit -> quantize
        sequence of ``edge_step`` when ``wire_format="int8"``.

        ``hidden``: (B, 1, d) or (B, d).  Returns (confidence (B,),
        token (B,), packet) where ``packet`` has exactly the layout of
        ``transport.quantize(hidden, "int8")`` — the cloud opens it with
        the unmodified ``dequantize``."""
        from repro.kernels.exit_quant.ops import exit_quant
        shape = hidden.shape
        h2 = hidden.reshape(shape[0], shape[-1])
        conf, tok, _, q, s = exit_quant(
            h2, self.model.unembed_weight(params),
            params["exit_norms"][str(self.l_ee1)],
            eps=self.model.cfg.norm_eps, interpret=interpret,
            use_kernel=use_kernel)
        return conf, tok, {"data": q.reshape(shape),
                           "scale": s.reshape(shape[:-1] + (1,))}

    def cloud_step(self, params: Params, upload: Dict[str, jax.Array],
                   caches: Dict[int, Pytree], pos: jax.Array,
                   block_tbl: Optional[jax.Array] = None,
                   write_mask: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Dict[int, Pytree]]:
        """One uploaded hidden -> final logits (paper Algorithm 1 lines 29-37).
        Also used for KV backfill of early-exited positions.  ``pos`` may be
        a scalar or a per-row (B,) position vector."""
        hidden = dequantize(upload, self.model.compute_dtype)
        x, _, new_caches = self.model.decode_from_hidden(
            params, hidden, caches, pos, self.cloud_segs,
            block_tbl=block_tbl, write_mask=write_mask)
        return self.model.logits(params, x)[:, 0], new_caches

    def _caches_where_rows(self, mask: jax.Array, new: Dict[int, Pytree],
                           old: Dict[int, Pytree]) -> Dict[int, Pytree]:
        """Per-row cache merge: rows with mask=True take ``new``, others keep
        ``old``.  Stacked segments carry batch at axis 1, shared at axis 0.
        Paged self-attention nodes are passed through untouched: their
        masked rows already wrote to the trash page, so ``new`` is correct
        for every row without a merge."""
        def merge(a: Pytree, b: Pytree, axis: int) -> Pytree:
            if isinstance(a, dict):
                if "kp" in a:
                    return a
                return {k: merge(a[k], b[k], axis) for k in a}
            return _where_rows(mask, a, b, axis)

        out: Dict[int, Pytree] = {}
        for si in new:
            axis = 0 if self.model.segments[si].shared else 1
            out[si] = merge(new[si], old[si], axis)
        return out

    def cloud_step_masked(self, params: Params, upload: Dict[str, jax.Array],
                          caches: Dict[int, Pytree], pos: jax.Array,
                          mask: jax.Array,
                          block_tbl: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, Dict[int, Pytree]]:
        """Batched cloud step serving only the below-θ rows: rows with
        mask=False keep their caches untouched (their upload was not
        consumed), preserving the per-client release/gap semantics of the
        sequential path.  One call serves every needy row of a step.  With
        paged caches the mask becomes the KV ``write_mask`` (masked rows
        write to the trash page) and only non-paged state is merged."""
        logits, new_caches = self.cloud_step(params, upload, caches, pos,
                                             block_tbl=block_tbl,
                                             write_mask=mask)
        return logits, self._caches_where_rows(mask, new_caches, caches)

    def edge_step_masked(self, params: Params, token: jax.Array,
                         caches: Dict[int, Pytree], pos: jax.Array,
                         run_mask: jax.Array,
                         block_tbl: Optional[jax.Array] = None) -> EdgeStepOut:
        """Batched edge step that leaves masked-out rows' caches untouched.

        The async scheduler keeps ticking the pool while some rows are
        stalled on an in-flight cloud reply; those rows flow through the
        batched graph as placeholders.  For attention caches a placeholder
        write is harmless (the slot is overwritten before it is read when
        the row resumes), but recurrent state would advance irreversibly —
        so rows with ``run_mask=False`` keep their caches bit-for-bit
        (paged self-attention writes to the trash page via the KV
        ``write_mask``; everything else is merged per row)."""
        x, exit_h, new_caches = self.model.decode_step(
            params, token, caches, pos, self.edge_segs, block_tbl=block_tbl,
            write_mask=run_mask)
        decisions = {l: evaluate_exit(self.model.exit_logits(params, l, h))
                     for l, h in exit_h.items()}
        tok, exited, _ = first_confident_exit(decisions, self.ccfg.theta)
        upload = quantize(exit_h[self.l_ee1], self.ccfg.wire_format)
        return EdgeStepOut(decisions, tok, exited, upload,
                           self._caches_where_rows(run_mask, new_caches,
                                                   caches))

    def invalidate_rows_after(self, caches: Dict[int, Pytree],
                              cut: jax.Array,
                              block_tbl: Optional[jax.Array] = None
                              ) -> Dict[int, Pytree]:
        """Per-row KV rollback: mark each row's self-attention entries at
        positions >= ``cut[row]`` invalid (pos = -1).

        The speculative decode path rewinds a row when the cloud reply
        disagrees with its provisionally-committed token; the row's *cloud*
        KV written for discarded positions must disappear (a position the
        re-decoded stream never cloud-serves again would otherwise read
        stale K/V — in blocking mode it would be a release-semantics gap).
        Edge KV needs no repair: decode overwrites a slot before reading
        it.  Dense rings match on the stored pos marker (wrap-safe); paged
        nodes scatter a per-page threshold through the block table.  Rows
        that are not being rewound pass ``cut = INT32_MAX``.  Cross-attn
        caches and recurrent state are untouched (speculation is gated to
        attention-only models)."""
        cut = jnp.asarray(cut, jnp.int32)
        big = jnp.iinfo(jnp.int32).max

        def fix_dense(c: Pytree) -> Pytree:
            p = c["pos"]
            shape = [1] * p.ndim
            shape[p.ndim - 2] = cut.shape[0]       # batch axis of the ring
            return {**c, "pos": jnp.where(p >= cut.reshape(shape), -1, p)}

        def fix_paged(c: Pytree) -> Pytree:
            def one(pos_arr):
                thr = jnp.full((pos_arr.shape[0],), big, jnp.int32)
                dest = jnp.where(block_tbl >= 0, block_tbl, 0).reshape(-1)
                vals = jnp.repeat(cut, block_tbl.shape[1])
                # trash page (id 0) may collect several rows' thresholds;
                # its markers are always -1, never >= a non-negative cut
                thr = thr.at[dest].set(vals)
                return jnp.where(pos_arr >= thr[:, None], -1, pos_arr)
            if c["kp"].ndim == 5:                  # stacked: (L, P, ps, ...)
                return {**c, "pos": jax.vmap(one)(c["pos"])}
            return {**c, "pos": one(c["pos"])}

        def go(c: Pytree) -> Pytree:
            if isinstance(c, dict):
                if "kp" in c:
                    return fix_paged(c)
                if "pos" in c and "k" in c:
                    return fix_dense(c)
                return {k: (go(v) if k != "cross" else v)
                        for k, v in c.items()}
            return c

        return {si: go(c) for si, c in caches.items()}

    def ring_cloud_steps(self, params: Params, ring: Dict[str, jax.Array],
                         ring_pos: jax.Array, ring_valid: jax.Array,
                         caches: Dict[int, Pytree],
                         block_tbl: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, Dict[int, Pytree]]:
        """Drain a per-row upload ring through the cloud partition in order.

        ring:       packet dict of stacked leaves, leading ring axis —
                    e.g. {"data": (k, B, 1, d)}.
        ring_pos:   (k, B) per-entry positions.
        ring_valid: (k, B) bool; invalid entries leave the row's cache and
                    logits untouched.
        Returns (per-row logits of each row's LAST valid entry (B, V) f32,
        new caches)."""
        b = ring_pos.shape[1]
        vocab = self.model.cfg.vocab_size

        def body(carry, xs):
            c, final = carry
            pkt_i, pos_i, valid_i = xs
            logits_i, c = self.cloud_step_masked(params, pkt_i, c, pos_i,
                                                 valid_i, block_tbl=block_tbl)
            final = jnp.where(valid_i[:, None],
                              logits_i.astype(jnp.float32), final)
            return (c, final), None

        (caches, final), _ = jax.lax.scan(
            body, (caches, jnp.zeros((b, vocab), jnp.float32)),
            (ring, ring_pos, ring_valid))
        return final, caches

    def ring_cloud_steps_all(self, params: Params, ring: Dict[str, jax.Array],
                             ring_pos: jax.Array, ring_valid: jax.Array,
                             caches: Dict[int, Pytree],
                             block_tbl: Optional[jax.Array] = None
                             ) -> Tuple[jax.Array, jax.Array,
                                        Dict[int, Pytree]]:
        """``ring_cloud_steps`` that also returns EVERY entry's logits.

        Multi-token draft verification scores all k draft positions of a
        row in one masked ring pass: the engine needs the per-position
        logits to find the longest agreeing prefix, not just the last
        entry's.  Returns (last-valid logits (B, V) f32 — same contract as
        ``ring_cloud_steps`` — all per-entry logits (k, B, V) f32 with
        invalid entries zeroed, new caches)."""
        b = ring_pos.shape[1]
        vocab = self.model.cfg.vocab_size

        def body(carry, xs):
            c, final = carry
            pkt_i, pos_i, valid_i = xs
            logits_i, c = self.cloud_step_masked(params, pkt_i, c, pos_i,
                                                 valid_i, block_tbl=block_tbl)
            step = jnp.where(valid_i[:, None],
                             logits_i.astype(jnp.float32), 0.0)
            final = jnp.where(valid_i[:, None],
                              logits_i.astype(jnp.float32), final)
            return (c, final), step

        (caches, final), all_logits = jax.lax.scan(
            body, (caches, jnp.zeros((b, vocab), jnp.float32)),
            (ring, ring_pos, ring_valid))
        return final, all_logits, caches

    def standalone_step(self, params: Params, token: jax.Array,
                        caches: Dict[int, Pytree], pos: jax.Array,
                        block_tbl: Optional[jax.Array] = None):
        """Edge standalone (low-latency) mode: last exit is the output."""
        x, exit_h, new_caches = self.model.decode_step(
            params, token, caches, pos, self.edge_segs, block_tbl=block_tbl)
        d = evaluate_exit(self.model.exit_logits(params, self.l_ee2,
                                                 exit_h[self.l_ee2]))
        return d.token, d, new_caches

    def full_step(self, params: Params, token: jax.Array,
                  caches: Dict[int, Pytree], pos: jax.Array,
                  block_tbl: Optional[jax.Array] = None):
        """Undivided model — the cloud-deployment baseline."""
        x, _, new_caches = self.model.decode_step(
            params, token, caches, pos, collect_exits=False,
            block_tbl=block_tbl)
        logits = self.model.logits(params, x)[:, 0]
        return jnp.argmax(logits, -1).astype(jnp.int32), logits, new_caches

    # ------------------------------------------------------------------
    # fused adaptive step (single-graph; TPU-native cond-gated cloud)
    # ------------------------------------------------------------------
    def init_fused_state(self, batch: int, max_seq: int, dtype=None):
        d = self.model.cfg.d_model
        k = self.ccfg.max_pending
        dt = dtype or self.model.compute_dtype
        state = {
            "ring_h": jnp.zeros((k, batch, 1, d), dt),
            "ring_pos": jnp.zeros((k, batch), jnp.int32),
            "count": jnp.zeros((batch,), jnp.int32),
        }
        if self.ccfg.kv_layout == "paged":
            # single-graph mode cannot consult a host allocator, so every
            # row gets a statically identity-mapped run of pages covering
            # max_seq — same memory as dense, but the whole step runs
            # through the paged write/gather path.
            ps = self.ccfg.page_size
            n_lp = -(-max_seq // ps)
            state["block_tbl"] = (1 + jnp.arange(batch * n_lp, dtype=jnp.int32)
                                  ).reshape(batch, n_lp)
            state["edge"] = self.init_edge_cache_paged(batch, batch * n_lp,
                                                       ps, dtype)
            state["cloud"] = self.init_cloud_cache_paged(batch, batch * n_lp,
                                                         ps, dtype)
        else:
            state["edge"] = self.init_edge_cache(batch, max_seq, dtype)
            state["cloud"] = self.init_cloud_cache(batch, max_seq, dtype)
        return state

    def fused_edge_phase(self, params: Params, token: jax.Array,
                         state: Pytree, pos: jax.Array):
        """Edge half of the fused step: decode, exit gating, and the ring
        push — NO cloud compute.  Returns ``(out, rings, need_rows)`` where
        ``rings`` is the updated {ring_h, ring_pos, count}.  A pipelined
        driver runs this for tick t+1 while tick t's
        ``fused_cloud_phase`` result is still in flight, committing each
        needy row's provisional exit-head token in the meantime
        (docs/async_transport.md)."""
        model, ccfg = self.model, self.ccfg
        b = token.shape[0]
        k = ccfg.max_pending if ccfg.backfill else 1
        pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        tbl = state.get("block_tbl")
        out = self.edge_step(params, token, state["edge"], pos_b, tbl)

        # simulate the wire: quantize -> dequantize
        h1 = dequantize(out.upload, model.compute_dtype)
        # paper-faithful (no backfill): only the newest upload is retained —
        # the content manager releases the rest (gapped cloud KV).
        idx = state["count"] if ccfg.backfill else jnp.zeros((b,), jnp.int32)
        bidx = jnp.arange(b)
        ring_h = state["ring_h"].at[idx, bidx].set(
            h1.astype(state["ring_h"].dtype))
        ring_pos = state["ring_pos"].at[idx, bidx].set(pos_b)
        count = idx + 1

        need_rows = ~out.exited
        if ccfg.backfill:
            need_rows = need_rows | (count >= k)     # ring full -> flush
        if ccfg.speculative:
            need_rows = jnp.ones((b,), bool)
        rings = {"ring_h": ring_h, "ring_pos": ring_pos, "count": count}
        return out, rings, need_rows

    def fused_cloud_phase(self, params: Params, cloud_caches: Pytree,
                          rings: Pytree, need_rows: jax.Array,
                          block_tbl: Optional[jax.Array] = None):
        """Cloud half of the fused step: ``lax.cond``-gated drain of the
        needy rows' upload rings.  Returns (cloud_caches, cloud_logits
        (B, V) f32, new_count)."""
        ccfg = self.ccfg
        b = need_rows.shape[0]
        k = ccfg.max_pending if ccfg.backfill else 1
        vocab = self.model.cfg.vocab_size
        need_cloud = jnp.any(need_rows)

        def run_cloud(operand):
            caches, rh, rp, cnt = operand
            valid = (jnp.arange(k)[:, None] < cnt[None, :]) & need_rows[None]
            logits, caches = self.ring_cloud_steps(
                params, {"data": rh[:k]}, rp[:k], valid, caches,
                block_tbl=block_tbl)
            return caches, logits, jnp.where(need_rows, 0, cnt)

        def skip_cloud(operand):
            caches, rh, rp, cnt = operand
            return caches, jnp.zeros((b, vocab), jnp.float32), cnt

        return jax.lax.cond(
            need_cloud, run_cloud, skip_cloud,
            (cloud_caches, rings["ring_h"], rings["ring_pos"],
             rings["count"]))

    def fused_step(self, params: Params, token: jax.Array, state: Pytree,
                   pos: jax.Array):
        """token: (B,1); pos: scalar or per-row (B,) position vector.
        Returns (next_token (B,), info, new_state).

        Semantics: every step each row pushes its l_ee1 hidden into its own
        upload ring (paper's parallel upload; per-row ring slots).  Cloud
        compute fires only when some row is below θ or its ring is full; it
        then drains the rings of exactly the needy rows in order —
        *backfilling* their cloud KV (beyond-paper exact-KV mode) while
        leaving confident rows' rings accumulating.  Without backfill each
        ring holds only the newest upload (paper's release semantics: the
        cloud KV keeps gaps at early-exited positions).

        Composed of ``fused_edge_phase`` + ``fused_cloud_phase`` so a
        pipelined driver can overlap the two across ticks; calling this
        fused composition keeps single-graph semantics bit-identical."""
        tbl = state.get("block_tbl")
        out, rings, need_rows = self.fused_edge_phase(params, token, state,
                                                      pos)
        cloud_caches, cloud_logits, new_count = self.fused_cloud_phase(
            params, state["cloud"], rings, need_rows, block_tbl=tbl)

        cloud_tok = jnp.argmax(cloud_logits, -1).astype(jnp.int32)
        next_token = jnp.where(out.exited, out.token, cloud_tok)

        new_state = {"edge": out.caches, "cloud": cloud_caches,
                     "ring_h": rings["ring_h"], "ring_pos": rings["ring_pos"],
                     "count": new_count}
        if tbl is not None:
            new_state["block_tbl"] = tbl
        info = {"exited": out.exited, "need_cloud": jnp.any(need_rows),
                "need_rows": need_rows, "cloud_logits": cloud_logits,
                "confidences": {l: d.confidence
                                for l, d in out.decisions.items()}}
        return next_token, info, new_state
