"""Early-exit confidence logic (paper §4.1, Algorithm 1 lines 7-21).

Confidence = probability of the most likely token at an exit head's softmax
(paper Table 1).  A token exits at the FIRST exit whose confidence >= theta;
otherwise the cloud completes inference.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ExitDecision(NamedTuple):
    token: jax.Array        # (B,) argmax token at this exit
    confidence: jax.Array   # (B,) max softmax probability
    logits: jax.Array       # (B, V)


def evaluate_exit(logits: jax.Array) -> ExitDecision:
    """logits: (B, V) (or (B,1,V) squeezed) -> ExitDecision."""
    if logits.ndim == 3:
        logits = logits[:, -1]
    lf = logits.astype(jnp.float32)
    # max softmax prob via logsumexp — numerically identical to
    # softmax(logits).max() but never materializes the (B,V) softmax twice.
    lse = jax.nn.logsumexp(lf, axis=-1)
    mx = jnp.max(lf, axis=-1)
    conf = jnp.exp(mx - lse)
    token = jnp.argmax(lf, axis=-1).astype(jnp.int32)
    return ExitDecision(token=token, confidence=conf, logits=lf)


def select_exit_logits(decisions: Dict[int, ExitDecision], theta: float
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-row logits of the first confident exit (sampling-capable variant
    of ``first_confident_exit``).

    Returns (logits (B,V), exited (B,), exit_idx (B,)).  Rows that exit
    nowhere get the LAST exit's logits — callers overwrite those rows with
    cloud logits via the ``exited`` mask before sampling."""
    layers = sorted(decisions)
    _, exited, exit_idx = first_confident_exit(decisions, theta)
    stack = jnp.stack([decisions[l].logits for l in layers])     # (E, B, V)
    row = jnp.clip(exit_idx, 0, len(layers) - 1)
    sel = stack[row, jnp.arange(row.shape[0])]
    return sel, exited, exit_idx


def first_confident_exit(decisions: Dict[int, ExitDecision], theta: float
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Combine per-exit decisions (ordered by layer).

    Returns (token, exited_mask, exit_index) where exit_index is the index of
    the chosen exit (len(decisions) == needs cloud)."""
    layers = sorted(decisions)
    b = decisions[layers[0]].token.shape[0]
    token = jnp.zeros((b,), jnp.int32)
    exited = jnp.zeros((b,), bool)
    exit_idx = jnp.full((b,), len(layers), jnp.int32)
    for i, l in enumerate(layers):
        d = decisions[l]
        take = (~exited) & (d.confidence >= theta)
        token = jnp.where(take, d.token, token)
        exit_idx = jnp.where(take, i, exit_idx)
        exited = exited | take
    return token, exited, exit_idx
