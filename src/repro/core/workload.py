"""Workload generators for the serving simulator.

Two sources of per-token exit-confidence traces:

  * ``paper_calibrated_cases`` — synthetic confidences whose exceedance
    probabilities match the paper's measured request-cloud rates
    (Table 2: Alpaca 49.58% @0.8 / 58.00% @0.9; XSum 27.73% @0.8 /
    36.13% @0.9), with prompt/generation lengths drawn from the paper's
    described ranges.  Used to replay Tables 2/4 and Fig 4.

  * measured traces — produced by running the trained tiny EE model
    (examples/quickstart.py) and recording real exit confidences.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Sequence, Tuple

from repro.core.netsim import CaseTrace, TokenTrace


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    name: str
    prompt_range: Tuple[int, int]
    gen_range: Tuple[int, int]
    # P(conf2 >= 0.8), P(conf2 >= 0.9): calibrated from Table 2 request rates
    p2_ge_08: float
    p2_ge_09: float
    # fraction of edge-exits that already clear at the FIRST exit
    first_exit_share: float = 0.5


ALPACA = DatasetProfile("alpaca", (13, 43), (60, 120),
                        p2_ge_08=1 - 0.4958, p2_ge_09=1 - 0.5800)
XSUM = DatasetProfile("xsum", (200, 500), (60, 120),
                      p2_ge_08=1 - 0.2773, p2_ge_09=1 - 0.3613)


def _sample_conf(rng: random.Random, p_ge_08: float, p_ge_09: float) -> float:
    """Piecewise-uniform confidence with the target exceedance probs."""
    u = rng.random()
    if u < 1 - p_ge_08:
        return rng.uniform(0.05, 0.80)      # below both thresholds
    if u < 1 - p_ge_09:
        return rng.uniform(0.80, 0.90)
    return rng.uniform(0.90, 0.999)


def paper_calibrated_cases(profile: DatasetProfile, n_cases: int,
                           seed: int = 0) -> List[CaseTrace]:
    rng = random.Random(seed)
    cases = []
    for _ in range(n_cases):
        p = rng.randint(*profile.prompt_range)
        g = rng.randint(*profile.gen_range)
        toks = []
        for _ in range(g):
            c2 = _sample_conf(rng, profile.p2_ge_08, profile.p2_ge_09)
            # first exit clears for a share of the tokens the second clears
            if c2 >= 0.8 and rng.random() < profile.first_exit_share:
                c1 = c2 * rng.uniform(0.97, 1.0)
            else:
                c1 = c2 * rng.uniform(0.4, 0.9)
            toks.append(TokenTrace(conf1=min(c1, 0.999), conf2=c2))
        cases.append(CaseTrace(prompt_len=p, tokens=toks))
    return cases


def split_clients(cases: Sequence[CaseTrace], n_clients: int
                  ) -> List[List[CaseTrace]]:
    """Round-robin the case list over N edge clients (Fig 4 scaling)."""
    out: List[List[CaseTrace]] = [[] for _ in range(n_clients)]
    for i, c in enumerate(cases):
        out[i % n_clients].append(c)
    return out


def traces_from_confidences(prompt_lens: Sequence[int],
                            confs: Sequence[Sequence[Tuple[float, float]]]
                            ) -> List[CaseTrace]:
    """Build cases from measured (conf1, conf2) per generated token."""
    return [CaseTrace(prompt_len=p,
                      tokens=[TokenTrace(c1, c2) for c1, c2 in cs])
            for p, cs in zip(prompt_lens, confs)]
