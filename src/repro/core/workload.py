"""Workload generators for the serving simulator.

Two sources of per-token exit-confidence traces:

  * ``paper_calibrated_cases`` — synthetic confidences whose exceedance
    probabilities match the paper's measured request-cloud rates
    (Table 2: Alpaca 49.58% @0.8 / 58.00% @0.9; XSum 27.73% @0.8 /
    36.13% @0.9), with prompt/generation lengths drawn from the paper's
    described ranges.  Used to replay Tables 2/4 and Fig 4.

  * measured traces — produced by running the trained tiny EE model
    (examples/quickstart.py) and recording real exit confidences.

Plus the **open-loop arrival layer** (docs/fleet_sim.md): an
``ArrivalProcess`` describes when requests *arrive* (Poisson or bursty
gamma interarrivals, optionally modulated by a diurnal sinusoid), and
``arrival_times`` realizes it into virtual-time stamps.  Closed-loop
replay (every request queued at t=0) answers "how fast can we drain a
backlog"; open-loop replay answers the capacity-planning questions the
fleet bench gates on (tail latency, SLO attainment under bursts).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import List, Sequence, Tuple

from repro.core.netsim import CaseTrace, TokenTrace


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    name: str
    prompt_range: Tuple[int, int]
    gen_range: Tuple[int, int]
    # P(conf2 >= 0.8), P(conf2 >= 0.9): calibrated from Table 2 request rates
    p2_ge_08: float
    p2_ge_09: float
    # fraction of edge-exits that already clear at the FIRST exit
    first_exit_share: float = 0.5


ALPACA = DatasetProfile("alpaca", (13, 43), (60, 120),
                        p2_ge_08=1 - 0.4958, p2_ge_09=1 - 0.5800)
XSUM = DatasetProfile("xsum", (200, 500), (60, 120),
                      p2_ge_08=1 - 0.2773, p2_ge_09=1 - 0.3613)


def _sample_conf(rng: random.Random, p_ge_08: float, p_ge_09: float) -> float:
    """Piecewise-uniform confidence with the target exceedance probs."""
    u = rng.random()
    if u < 1 - p_ge_08:
        return rng.uniform(0.05, 0.80)      # below both thresholds
    if u < 1 - p_ge_09:
        return rng.uniform(0.80, 0.90)
    return rng.uniform(0.90, 0.999)


def paper_calibrated_cases(profile: DatasetProfile, n_cases: int,
                           seed: int = 0) -> List[CaseTrace]:
    rng = random.Random(seed)
    cases = []
    for _ in range(n_cases):
        p = rng.randint(*profile.prompt_range)
        g = rng.randint(*profile.gen_range)
        toks = []
        for _ in range(g):
            c2 = _sample_conf(rng, profile.p2_ge_08, profile.p2_ge_09)
            # first exit clears for a share of the tokens the second clears
            if c2 >= 0.8 and rng.random() < profile.first_exit_share:
                c1 = c2 * rng.uniform(0.97, 1.0)
            else:
                c1 = c2 * rng.uniform(0.4, 0.9)
            toks.append(TokenTrace(conf1=min(c1, 0.999), conf2=c2))
        cases.append(CaseTrace(prompt_len=p, tokens=toks))
    return cases


def split_clients(cases: Sequence[CaseTrace], n_clients: int
                  ) -> List[List[CaseTrace]]:
    """Round-robin the case list over N edge clients (Fig 4 scaling).

    Returns ``min(n_clients, len(cases))`` lists — never an empty one.
    Oversizing the fleet used to hand downstream engines empty case lists
    (each one an idle client silently starving its engine); capping the
    fan-out keeps every returned client busy, and multi-engine drivers
    must tolerate the smaller fleet (an idle engine's clock never
    advances, so it cannot skew the makespan)."""
    if n_clients < 1:
        raise ValueError(f"n_clients must be >= 1, got {n_clients}")
    if not cases:
        raise ValueError("split_clients needs at least one case")
    n = min(n_clients, len(cases))
    out: List[List[CaseTrace]] = [[] for _ in range(n)]
    for i, c in enumerate(cases):
        out[i % n].append(c)
    return out


# ---------------------------------------------------------------------------
# Open-loop arrival processes (fleet replay)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """An open-loop request arrival model in virtual time.

    ``rate`` is the long-run mean arrival rate (requests / virtual
    second).  ``kind="poisson"`` draws exponential interarrivals;
    ``kind="gamma"`` draws gamma interarrivals with squared coefficient
    of variation ``cv2`` (cv2=1 degenerates to Poisson, cv2>1 is bursty:
    clumps of near-simultaneous arrivals separated by long gaps).

    ``diurnal_amp`` in [0, 1) modulates the instantaneous rate as
    ``rate * (1 + diurnal_amp * sin(2*pi*t / diurnal_period_s))`` — the
    classic day/night ramp, realized exactly by time-rescaling the
    unit-rate process through the inverse cumulative intensity."""
    rate: float
    kind: str = "poisson"
    cv2: float = 1.0
    diurnal_amp: float = 0.0
    diurnal_period_s: float = 60.0

    def __post_init__(self):
        if self.rate <= 0:
            raise ValueError(f"rate must be > 0, got {self.rate}")
        if self.kind not in ("poisson", "gamma"):
            raise ValueError(f"unknown arrival kind {self.kind!r}")
        if self.cv2 <= 0:
            raise ValueError(f"cv2 must be > 0, got {self.cv2}")
        if not 0.0 <= self.diurnal_amp < 1.0:
            raise ValueError("diurnal_amp must be in [0, 1) so the "
                             "instantaneous rate stays positive")
        if self.diurnal_period_s <= 0:
            raise ValueError("diurnal_period_s must be > 0")

    # cumulative intensity Lambda(t) = integral of rate*(1 + amp*sin(...))
    def _cum_intensity(self, t: float) -> float:
        amp, period = self.diurnal_amp, self.diurnal_period_s
        w = 2.0 * math.pi / period
        return self.rate * (t + amp / w * (1.0 - math.cos(w * t)))

    def _invert(self, target: float) -> float:
        """Smallest t with Lambda(t) == target (Lambda is strictly
        increasing since amp < 1), by bisection."""
        lo, hi = 0.0, max(1.0, 2.0 * target / self.rate)
        while self._cum_intensity(hi) < target:
            hi *= 2.0
        for _ in range(80):
            mid = 0.5 * (lo + hi)
            if self._cum_intensity(mid) < target:
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)


def arrival_times(proc: ArrivalProcess, n: int, seed: int = 0
                  ) -> List[float]:
    """Realize ``n`` arrival timestamps of ``proc`` (sorted, seeded).

    Draws a unit-rate renewal process (exponential or gamma
    interarrivals with mean 1), then maps each cumulative event time
    through the inverse cumulative intensity — for ``diurnal_amp=0``
    this is just ``s / rate``; with modulation, arrivals thin out in the
    troughs and bunch at the peaks with the exact target density."""
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    rng = random.Random(seed)
    if proc.kind == "gamma" and proc.cv2 != 1.0:
        # Gamma(k, theta): mean k*theta = 1, cv^2 = 1/k  =>  k = 1/cv2
        k, theta = 1.0 / proc.cv2, proc.cv2
        draw = lambda: rng.gammavariate(k, theta)
    else:
        draw = lambda: rng.expovariate(1.0)
    out, s = [], 0.0
    for _ in range(n):
        s += draw()
        if proc.diurnal_amp == 0.0:
            out.append(s / proc.rate)
        else:
            out.append(proc._invert(s))
    return out


def stamp_arrivals(cases: Sequence[CaseTrace], times: Sequence[float]
                   ) -> List[CaseTrace]:
    """Copy ``cases`` with per-case virtual arrival timestamps attached
    (``netsim.simulate`` and the fleet bench replay them open-loop)."""
    if len(times) < len(cases):
        raise ValueError(f"{len(cases)} cases but only {len(times)} "
                         f"arrival times")
    return [dataclasses.replace(c, arrival_t=float(t))
            for c, t in zip(cases, times)]


def traces_from_confidences(prompt_lens: Sequence[int],
                            confs: Sequence[Sequence[Tuple[float, float]]]
                            ) -> List[CaseTrace]:
    """Build cases from measured (conf1, conf2) per generated token."""
    return [CaseTrace(prompt_len=p,
                      tokens=[TokenTrace(c1, c2) for c1, c2 in cs])
            for p, cs in zip(prompt_lens, confs)]
