"""Disaggregated two-tier CE-CoLLM runtime (DESIGN.md §2).

Pod 0 of the multi-pod mesh is the *edge tier* (layers 1..l_ee2 + exit
heads), pod 1 the *cloud tier* (layers l_ee1+1..L).  Each tier is its own
jit program on its own ("data","model") submesh — separate failure domains,
exactly like the paper's edge/cloud split (edge standalone keeps working if
the cloud program dies).  The l_ee1 hidden state crosses tiers as an fp16 /
int8 packet (``jax.device_put`` over DCN on real hardware); jax async
dispatch gives the paper's "parallel upload" for free: the edge program
continues running while the transfer is in flight.

Cloud requests go through ``DeviceTransferChannel`` — the
``transport.CloudChannel`` protocol implemented over real device
transfers, so the two-pod runtime and the simulated channels of the
batched engine share one request path (submit -> poll) instead of two
divergent ones (docs/async_transport.md)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.collm import CoLLM, CollmConfig
from repro.core.transport import (CloudChannel, dequantize, packet_bytes,
                                  quantize)
from repro.launch import sharding as shardlib
from repro.models.transformer import Model

Pytree = Any


class DeviceTransferChannel(CloudChannel):
    """``CloudChannel`` over real hardware: ``submit`` moves the quantized
    packet to the cloud tier with ``jax.device_put`` (DCN on a multi-pod
    mesh) and dispatches the cloud-tier jit program; both are
    asynchronous, so the edge tier keeps running until ``poll`` — which
    returns every submitted request (the *blocking point* is the caller
    materializing the reply logits, not the dispatch).  Wire bytes are
    accounted per request from the actual packet."""

    def __init__(self, cloud_step, params_cloud: Pytree, cloud_device):
        super().__init__()
        self._cloud = cloud_step
        self._pc = params_cloud
        self._dev = cloud_device
        self._caches: Optional[Pytree] = None

    def attach_caches(self, caches: Pytree) -> None:
        self._caches = caches

    @property
    def caches(self) -> Optional[Pytree]:
        return self._caches

    def submit_packet(self, packet: Pytree, pos, *, slot: int = 0,
                      seq: int = 0, now: float = 0.0) -> int:
        """Transfer + dispatch one cloud request; returns the handle."""
        pkt = jax.device_put(packet, self._dev)     # async DCN transfer
        logits, self._caches = self._cloud(self._pc, pkt, self._caches,
                                           jnp.asarray(pos, jnp.int32))
        return self.submit(slot=slot, seq=seq, pos=int(pos), reply=logits,
                           now=now, nbytes_up=packet_bytes(packet))


def stream_prompt_upload(channel: CloudChannel, h1: jax.Array, fmt: str,
                         cloud_dev, chunk: int) -> jax.Array:
    """Pipeline the prompt hidden-state upload in ``chunk``-token slices
    instead of one monolithic packet: each slice is quantized and its
    ``jax.device_put`` dispatched immediately, so slice i+1's quantize
    overlaps slice i's DCN transfer (the chunked-prefill admission path of
    the batched engine does the same thing one page at a time — later
    chunks cross the wire while earlier ones compute).  Wire bytes are
    billed per slice; quantization is per-slice too, which for int8 means
    per-slice scales — the same positions-on-the-wire layout the batched
    engine's per-chunk uploads produce.  Returns the dequantized on-cloud
    hidden sequence, ready for ``cloud_prefill``."""
    parts = []
    for i in range(0, h1.shape[1], chunk):
        sl = quantize(h1[:, i:i + chunk], fmt)
        channel.notify_upload(0, packet_bytes(sl), 0.0)
        parts.append(jax.device_put(sl, cloud_dev))
    return jnp.concatenate([dequantize(p) for p in parts], axis=1)


@dataclasses.dataclass
class TierPrograms:
    edge_step: Any
    cloud_step: Any
    edge_mesh: Any
    cloud_mesh: Any
    wire_bytes_per_token: int


class TwoTierRuntime:
    """Compiles the edge partition on one submesh and the cloud partition on
    the other; moves only quantized packets between them."""

    def __init__(self, model: Model, ccfg: CollmConfig, edge_mesh,
                 cloud_mesh):
        self.model = model
        self.collm = CoLLM(model, ccfg)
        self.ccfg = ccfg
        self.edge_mesh = edge_mesh
        self.cloud_mesh = cloud_mesh

    # -- lowering (also used by the technique dry-run) ----------------------
    def lower_tiers(self, batch: int, max_seq: int
                    ) -> Tuple[Any, Any, Dict]:
        co = self.collm
        model = self.model
        params = model.param_specs()

        def edge_step(params, token, caches, pos):
            out = co.edge_step(params, token, caches, pos)
            return out.token, out.exited, out.upload, out.caches

        def cloud_step(params, upload, caches, pos):
            logits, caches = co.cloud_step(params, upload, caches, pos)
            return jnp.argmax(logits, -1).astype(jnp.int32), caches

        token = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        e_caches = jax.eval_shape(
            lambda: co.init_edge_cache(batch, max_seq,
                                       dtype=model.compute_dtype))
        c_caches = jax.eval_shape(
            lambda: co.init_cloud_cache(batch, max_seq,
                                        dtype=model.compute_dtype))
        d = model.cfg.d_model
        wire_dtype = {"float32": jnp.float32, "float16": jnp.float16,
                      "int8": jnp.int8}[self.ccfg.wire_format]
        upload = {"data": jax.ShapeDtypeStruct((batch, 1, d), wire_dtype)}
        if self.ccfg.wire_format == "int8":
            upload["scale"] = jax.ShapeDtypeStruct((batch, 1, 1), jnp.float32)

        def shardings(mesh, caches):
            psh = shardlib.params_shardings(params, mesh, fsdp=False)
            tsh = NamedSharding(mesh, shardlib.input_pspec(token, mesh, batch))
            csh = shardlib.cache_shardings(caches, mesh, batch=batch)
            possh = NamedSharding(mesh, P())
            return psh, tsh, csh, possh

        e_psh, e_tsh, e_csh, e_possh = shardings(self.edge_mesh, e_caches)
        edge_lowered = jax.jit(
            edge_step, in_shardings=(e_psh, e_tsh, e_csh, e_possh),
            out_shardings=(None, None, None, e_csh),
            donate_argnums=(2,)).lower(params, token, e_caches, pos)

        c_psh, c_tsh, c_csh, c_possh = shardings(self.cloud_mesh, c_caches)
        upload_sh = jax.tree.map(
            lambda l: NamedSharding(self.cloud_mesh,
                                    shardlib.input_pspec(l, self.cloud_mesh,
                                                         batch)), upload)
        cloud_lowered = jax.jit(
            cloud_step, in_shardings=(c_psh, upload_sh, c_csh, c_possh),
            out_shardings=(None, c_csh),
            donate_argnums=(2,)).lower(params, upload, c_caches, pos)

        wire = packet_bytes(upload)
        return edge_lowered, cloud_lowered, {"wire_bytes_per_token": wire}

    # -- live serving (small models / tests) --------------------------------
    def build(self, params_edge: Pytree, params_cloud: Pytree):
        co = self.collm
        self._edge = jax.jit(co.edge_step)
        self._cloud = jax.jit(co.cloud_step)
        self._pe, self._pc = params_edge, params_cloud
        self.channel = DeviceTransferChannel(
            self._cloud, params_cloud, self.cloud_mesh.devices.flat[0])

    def decode(self, prompt: jax.Array, max_new: int, max_seq: int = 256,
               upload_chunk: int = 0):
        """Single-stream decode across the two tiers.  Every cloud request
        goes submit -> poll through ``self.channel`` (the same protocol
        the batched engine's simulated channels speak); the transfer and
        the cloud program are dispatched asynchronously and the edge only
        blocks when it materializes the reply token.  ``upload_chunk > 0``
        streams the prompt upload in that many-token slices
        (``stream_prompt_upload``) instead of one monolithic packet."""
        co = self.collm
        cloud_dev = self.cloud_mesh.devices.flat[0]
        chan = self.channel
        e_caches = co.init_edge_cache(1, max_seq)
        chan.attach_caches(co.init_cloud_cache(1, max_seq))
        _, h1, e_caches = co.edge_prefill(self._pe, {"tokens": prompt},
                                          e_caches)
        if upload_chunk > 0:
            h1c = stream_prompt_upload(chan, h1, self.ccfg.wire_format,
                                       cloud_dev, upload_chunk)
        else:
            h1q = quantize(h1, self.ccfg.wire_format)
            chan.notify_upload(0, packet_bytes(h1q), 0.0)
            h1q = jax.device_put(h1q, cloud_dev)       # prompt upload (DCN)
            h1c = dequantize(h1q)
        logits, c_caches = co.cloud_prefill(self._pc, h1c, chan.caches)
        chan.attach_caches(c_caches)
        tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        toks = [int(tok[0])]
        wire0 = chan.stats.bytes_up
        pos = prompt.shape[1]
        for _ in range(max_new - 1):
            out = self._edge(self._pe, tok[:, None], e_caches,
                             jnp.asarray(pos, jnp.int32))
            e_caches = out.caches
            if bool(out.exited[0]):
                # parallel upload: dispatch the transfer, edge continues
                chan.notify_upload(0, packet_bytes(out.upload), 0.0)
                jax.device_put(out.upload, cloud_dev)
                tok = out.token
            else:
                chan.submit_packet(out.upload, pos)
                (rep,) = chan.poll()
                tok = jnp.argmax(rep.reply, -1).astype(jnp.int32)
            toks.append(int(tok[0]))
            pos += 1
        return toks, {"wire_bytes": chan.stats.bytes_up - wire0,
                      "channel": chan.stats.as_row()}
