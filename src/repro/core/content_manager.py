"""Cloud-side content manager (paper §4.2).

Host-level component that coordinates per-client state on the cloud tier:

  * uploaded hidden-state packets (parallel upload lands here *before* the
    matching inference request arrives — paper fig 3 step 4); the batched
    scheduler uses the ``*_batch`` variants so one tick touches every
    below-θ client with per-client accounting intact;
  * per-client KV / recurrent caches for the cloud LLM partition on the
    sequential path (``get_cache``/``put_cache``).  The batched
    ``BatchScheduler`` does NOT park caches here: it owns pooled
    device caches (one row — or one set of KV pages under
    ``kv_layout="paged"`` — per slot) and only uses the upload and
    end-of-sequence APIs;
  * release of consumed hidden states and end-of-sequence cleanup
    (paper fig 3 step 6).

It deliberately mirrors the paper's dual-API split: ``upload`` is the data
receive API, ``take_upload``/``take_uploads_upto`` back the inference API.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

from repro.core.transport import StatePacket

Pytree = Any


@dataclasses.dataclass
class ClientState:
    device_id: str
    pending_uploads: Dict[int, StatePacket] = dataclasses.field(default_factory=dict)
    cache: Optional[Pytree] = None          # cloud-partition KV / ssm states
    cloud_slot: Optional[int] = None        # row in the CloudBatcher's pool
    last_active: float = 0.0
    uploads_received: int = 0
    uploads_consumed: int = 0
    uploads_released: int = 0
    bytes_received: int = 0
    requests_served: int = 0
    prefix_reused_tokens: int = 0   # prompt tokens deduped against another
                                    # client's cached upload (never re-sent)


class ContentManager:
    """Multi-client cloud state store."""

    def __init__(self, max_pending_per_client: int = 8,
                 clock: Callable[[], float] = time.monotonic):
        self._clients: Dict[str, ClientState] = {}
        self._max_pending = max_pending_per_client
        self._clock = clock

    # -- data-receive API ---------------------------------------------------
    def upload(self, device_id: str, pos: int, packet: StatePacket) -> None:
        c = self._client(device_id)
        c.pending_uploads[pos] = packet
        c.uploads_received += 1
        c.bytes_received += packet.nbytes()
        c.last_active = self._clock()
        # continuously release stale hidden states (paper §4.2): any upload
        # older than the window can no longer be requested.
        while len(c.pending_uploads) > self._max_pending:
            oldest = min(c.pending_uploads)
            del c.pending_uploads[oldest]
            c.uploads_released += 1

    # -- inference API ------------------------------------------------------
    def take_upload(self, device_id: str, pos: int) -> StatePacket:
        c = self._client(device_id)
        if pos not in c.pending_uploads:
            raise KeyError(
                f"client {device_id}: no uploaded state for position {pos} "
                f"(have {sorted(c.pending_uploads)})")
        pkt = c.pending_uploads.pop(pos)
        # token inference for pos invalidates earlier speculative uploads
        for stale in [p for p in c.pending_uploads if p < pos]:
            del c.pending_uploads[stale]
            c.uploads_released += 1
        c.uploads_consumed += 1
        c.requests_served += 1
        c.last_active = self._clock()
        return pkt

    def take_upload_keep(self, device_id: str, pos: int) -> StatePacket:
        """Pop exactly ``pos`` WITHOUT invalidating earlier pendings.

        Multi-token drafting holds each draft position's packet at the
        edge of the engine (so the window eviction in ``upload`` cannot
        release a position still awaiting verification) while the
        *backfill* ring of not-yet-consumed earlier uploads must survive
        untouched until the draft's single verification request drains
        them together.  ``take_upload`` would release those earlier
        entries; this variant takes only ``pos``."""
        c = self._client(device_id)
        if pos not in c.pending_uploads:
            raise KeyError(
                f"client {device_id}: no uploaded state for position {pos} "
                f"(have {sorted(c.pending_uploads)})")
        pkt = c.pending_uploads.pop(pos)
        c.uploads_consumed += 1
        c.last_active = self._clock()
        return pkt

    def take_uploads_upto(self, device_id: str, pos: int):
        """Backfill mode: pop ALL pending uploads with position <= pos, in
        order (beyond-paper exact-KV mode; see DESIGN.md)."""
        c = self._client(device_id)
        out = []
        for p in sorted(k for k in c.pending_uploads if k <= pos):
            out.append((p, c.pending_uploads.pop(p)))
            c.uploads_consumed += 1
        c.requests_served += 1
        c.last_active = self._clock()
        return out

    # -- batched APIs (continuous-batching scheduler) -----------------------
    # One scheduler tick touches every below-θ slot at once; these keep the
    # per-client accounting identical to the sequential API while letting the
    # engine build a single dense cloud call out of the returned packets.
    def upload_batch(self, items) -> None:
        """items: iterable of (device_id, pos, StatePacket)."""
        for device_id, pos, packet in items:
            self.upload(device_id, pos, packet)

    def take_upload_batch(self, items):
        """items: iterable of (device_id, pos) -> [StatePacket, ...] in order.
        Per-entry semantics match ``take_upload`` (stale invalidation)."""
        return [self.take_upload(d, p) for d, p in items]

    def take_uploads_upto_batch(self, items):
        """Backfill variant: items (device_id, pos) -> list of per-client
        [(pos, StatePacket), ...] pending rings, oldest first."""
        return [self.take_uploads_upto(d, p) for d, p in items]

    def has_upload(self, device_id: str, pos: int) -> bool:
        c = self._clients.get(device_id)
        return bool(c and pos in c.pending_uploads)

    # -- prefix dedup ledger -------------------------------------------------
    def note_prefix_reuse(self, device_id: str, tokens: int) -> None:
        """Record that ``tokens`` prompt tokens of this client were served
        from another client's cached cloud prefix (shared KV pages) and
        therefore never crossed the wire.  Pure accounting — the dedup
        decision itself lives in the engine/batcher admission path — but it
        keeps the §4.2 content-management story auditable: received bytes +
        reused tokens together cover every prompt position."""
        c = self._client(device_id)
        c.prefix_reused_tokens += tokens
        c.last_active = self._clock()

    def prefix_reused_tokens(self, device_id: Optional[str] = None) -> int:
        if device_id is not None:
            c = self._clients.get(device_id)
            return 0 if c is None else c.prefix_reused_tokens
        return sum(c.prefix_reused_tokens for c in self._clients.values())

    # -- preemption checkpoint support ---------------------------------------
    # A preempted stream's pending uploads move into its host-side
    # checkpoint and come back verbatim at resume.  Neither direction is a
    # wire event (the packets crossed the wire when first uploaded), so
    # these bypass the received/consumed/released counters on purpose —
    # the stats of a preempted run stay comparable to an un-preempted one.
    def pending_positions(self, device_id: str):
        c = self._clients.get(device_id)
        return sorted(c.pending_uploads) if c else []

    def take_all_uploads(self, device_id: str):
        """Checkpoint: pop every pending upload, oldest first."""
        c = self._clients.get(device_id)
        if c is None:
            return []
        out = [(p, c.pending_uploads.pop(p))
               for p in sorted(c.pending_uploads)]
        return out

    def restore_uploads(self, device_id: str, items) -> None:
        """Resume: re-insert a checkpoint's pending uploads."""
        c = self._client(device_id)
        for pos, packet in items:
            c.pending_uploads[pos] = packet

    # -- per-client cloud cache ----------------------------------------------
    def get_cache(self, device_id: str) -> Optional[Pytree]:
        return self._client(device_id).cache

    def put_cache(self, device_id: str, cache: Pytree) -> None:
        c = self._client(device_id)
        c.cache = cache
        c.last_active = self._clock()

    # -- cloud slot pool (CloudBatcher) --------------------------------------
    # The batcher serves every client out of ONE pooled, batch-major cloud
    # cache; the manager owns the device_id -> pool-row mapping so the
    # per-client state (uploads, slot, lifecycle) lives in one place.
    def init_cloud_slots(self, num_slots: int) -> None:
        self._cloud_free_slots = list(range(num_slots - 1, -1, -1))

    def assign_cloud_slot(self, device_id: str) -> int:
        c = self._client(device_id)
        if c.cloud_slot is not None:
            return c.cloud_slot
        if not getattr(self, "_cloud_free_slots", None):
            raise RuntimeError(
                f"cloud slot pool exhausted assigning {device_id} "
                "(release a finished client first)")
        c.cloud_slot = self._cloud_free_slots.pop()
        return c.cloud_slot

    def cloud_slot(self, device_id: str) -> Optional[int]:
        c = self._clients.get(device_id)
        return None if c is None else c.cloud_slot

    def release_cloud_slot(self, device_id: str) -> Optional[int]:
        c = self._clients.get(device_id)
        if c is None or c.cloud_slot is None:
            return None
        slot, c.cloud_slot = c.cloud_slot, None
        self._cloud_free_slots.append(slot)
        return slot

    def cloud_slots_free(self) -> int:
        return len(getattr(self, "_cloud_free_slots", ()))

    # -- lifecycle ------------------------------------------------------------
    def end_of_sequence(self, device_id: str) -> None:
        """Paper step 6: clear KV caches + hidden states on completion."""
        c = self._clients.get(device_id)
        if c is None:
            return
        c.uploads_released += len(c.pending_uploads)
        c.pending_uploads.clear()
        c.cache = None

    def drop_client(self, device_id: str) -> None:
        self._clients.pop(device_id, None)

    def stats(self) -> Dict[str, Dict[str, int]]:
        return {
            d: {"uploads_received": c.uploads_received,
                "uploads_consumed": c.uploads_consumed,
                "uploads_released": c.uploads_released,
                "bytes_received": c.bytes_received,
                "requests_served": c.requests_served,
                "prefix_reused_tokens": c.prefix_reused_tokens,
                "pending": len(c.pending_uploads)}
            for d, c in self._clients.items()
        }

    def clients(self):
        return list(self._clients)

    def _client(self, device_id: str) -> ClientState:
        if device_id not in self._clients:
            self._clients[device_id] = ClientState(device_id=device_id,
                                                   last_active=self._clock())
        return self._clients[device_id]
