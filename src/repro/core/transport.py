"""Edge<->cloud transport: wire formats, quantization, and the async
cloud channel (paper §4.2/§4.3).

The paper uploads hidden states in float16 (validated range ±65504).  We
implement fp16 (paper-faithful) plus an int8 per-row-scaled format
(beyond-paper: 2x fewer bytes, evaluated in EXPERIMENTS.md §Perf).

For SSM/hybrid architectures the packet carries the recurrent state
snapshots at the partition boundary in addition to the token activation
(see DESIGN.md §4) — the cloud cannot reconstruct them from a single
token's hidden state.

Besides wire formats, this module defines the **CloudChannel** protocol —
the asynchronous edge->cloud request path used by the batched serving
engine, the sequential reference loop, and the two-tier runtime
(docs/async_transport.md):

  * ``submit(...) -> handle``   — dispatch one cloud request; the caller
    keeps decoding while the reply is in flight (paper's latency hiding);
  * ``poll(now) -> replies``    — drain the replies that have arrived by
    virtual time ``now``;
  * every request carries a **deadline**; the engine commits the edge
    token when the reply misses it (paper's latency-aware early exit).

``SyncChannel`` (zero latency, infinite deadline) reproduces a blocking
call exactly; ``AsyncSimChannel`` prices each request with
``netsim.NetworkParams``-style link parameters in virtual time;
``ScriptedChannel`` replays an explicit per-request latency trace (tests,
deterministic benchmarks).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

FORMATS = ("float32", "float16", "int8")

# Wire size of one token id + framing — the single source of truth shared
# by the netsim simulator and the serving engine (they can never disagree
# on transmitted MB).
TOKEN_BYTES = 8


def draft_request_bytes(k: int) -> int:
    """Wire size of a k-token draft verification request: the k provisional
    token ids ride the request control message (the k hidden states were
    already billed by their per-tick ``notify_upload`` calls — parallel
    upload, paper fig 3).  Single source of truth for the engine and the
    wire-accounting tests."""
    return int(k) * TOKEN_BYTES


def hidden_wire_bytes(d_model: int, fmt: str, seq: int = 1) -> int:
    """Wire size of a ``seq``-long hidden-state upload in format ``fmt``,
    computed from the quantized packet ABSTRACTLY (eval_shape: no device
    work), so int8 runs report int8 bytes, not hardcoded fp16."""
    spec = jax.eval_shape(
        lambda: quantize(jnp.zeros((1, seq, d_model), jnp.float32), fmt))
    return packet_bytes(spec)


def prompt_upload_bytes(d_model: int, fmt: str, prompt_len: int,
                        hit_tokens: int = 0) -> int:
    """Wire size of one stream's prompt hidden-state upload after prefix
    dedup: only the ``prompt_len - hit_tokens`` suffix positions cross the
    wire (the hit prefix already lives at the cloud service point as shared
    KV pages; a whole-prompt hit uploads nothing).  Single source of truth
    for the engine's admission billing and the bench's upload-byte gate."""
    send = max(0, int(prompt_len) - int(hit_tokens))
    if send == 0:
        return 0
    return hidden_wire_bytes(d_model, fmt, seq=send)


def quantize(x: jax.Array, fmt: str) -> Dict[str, jax.Array]:
    if fmt == "float32":
        return {"data": x.astype(jnp.float32)}
    if fmt == "float16":
        return {"data": x.astype(jnp.float16)}
    if fmt == "int8":
        xf = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return {"data": q, "scale": scale}
    raise ValueError(fmt)


def dequantize(packet: Dict[str, jax.Array], dtype=jnp.float32) -> jax.Array:
    data = packet["data"]
    if data.dtype == jnp.int8:
        return (data.astype(jnp.float32) * packet["scale"]).astype(dtype)
    return data.astype(dtype)


def packet_bytes(packet: Pytree) -> int:
    """Wire size of a (possibly nested) packet in bytes."""
    leaves = jax.tree.leaves(packet)
    return int(sum(l.size * l.dtype.itemsize for l in leaves))


def packet_breakdown(packet: Pytree) -> Dict[str, int]:
    """Wire bytes of a (possibly nested) packet split by role:
    ``{"data": ..., "scale": ...}``.

    Every int8 leaf packet is a ``{"data", "scale"}`` dict, so a recurrent
    ``states`` tree quantized with ``quantize_tree`` carries one fp32
    scale tensor PER LEAF — those scales are real wire bytes and must be
    billed per-leaf, not assumed amortized into the data payload.  Keyed
    dicts are walked explicitly (``jax.tree.leaves`` would flatten the
    roles away)."""
    out = {"data": 0, "scale": 0}

    def walk(node):
        if isinstance(node, dict) and "data" in node:
            for key, leaf in node.items():
                role = "scale" if key == "scale" else "data"
                out[role] += int(leaf.size * leaf.dtype.itemsize)
            return
        for child in (node.values() if isinstance(node, dict) else
                      node if isinstance(node, (list, tuple)) else ()):
            walk(child)
        if not isinstance(node, (dict, list, tuple)):
            out["data"] += int(node.size * node.dtype.itemsize)

    walk(packet)
    return out


def quantize_tree(tree: Pytree, fmt: str) -> Pytree:
    """Quantize every array leaf of a state snapshot."""
    return jax.tree.map(lambda x: quantize(x, fmt), tree)


def dequantize_tree(tree: Pytree, dtype=jnp.float32) -> Pytree:
    is_packet = lambda t: isinstance(t, dict) and "data" in t
    return jax.tree.map(lambda p: dequantize(p, dtype), tree,
                        is_leaf=is_packet)


@dataclasses.dataclass
class StatePacket:
    """What crosses the edge->cloud boundary for one upload (paper fig 3
    step 3): the l_ee1 token activation, and (SSM/hybrid only) boundary
    recurrent-state snapshots."""
    hidden: Dict[str, jax.Array]                   # quantized (B,1,d)
    states: Optional[Pytree] = None                # quantized recurrent states
    pos: Optional[jax.Array] = None                # token position

    def nbytes(self) -> int:
        return sum(self.wire_breakdown().values())

    def wire_breakdown(self) -> Dict[str, int]:
        """Wire bytes split into ``{"data", "scale", "pos"}``.

        ``scale`` bills every per-leaf fp32 scale tensor of int8 packets
        explicitly — for an SSM/hybrid ``states`` tree each quantized leaf
        carries its own scale, and those add up (a (B,1,d) hidden has one
        (B,1,1) scale, but a states tree with K leaves has K of them).
        ``nbytes`` is the sum, so total billing can never drift from the
        audited breakdown."""
        bd = packet_breakdown(self.hidden)
        if self.states is not None:
            sbd = packet_breakdown(self.states)
            bd = {k: bd[k] + sbd[k] for k in bd}
        # positions go over the wire as int32 — one per row.  A batched
        # upload carries a (B,) position vector and must bill all B
        # entries, not a flat 4 bytes.
        bd["pos"] = (4 * int(np.asarray(self.pos).size)
                     if self.pos is not None else 0)
        return bd


def make_packet(hidden: jax.Array, fmt: str, *, states: Pytree = None,
                pos: jax.Array = None) -> StatePacket:
    return StatePacket(
        hidden=quantize(hidden, fmt),
        states=quantize_tree(states, fmt) if states is not None else None,
        pos=pos,
    )


def open_packet(pkt: StatePacket, dtype=jnp.float32
                ) -> Tuple[jax.Array, Optional[Pytree]]:
    hidden = dequantize(pkt.hidden, dtype)
    states = (dequantize_tree(pkt.states, dtype)
              if pkt.states is not None else None)
    return hidden, states


# ---------------------------------------------------------------------------
# Cloud service point (the shared cloud server queue, in virtual time)
# ---------------------------------------------------------------------------
class CloudServicePoint:
    """The cloud server's service queue, shared by every client channel.

    This replaces the scalar ``_cloud_free`` FIFO: with the default knobs
    (``batch_window_s=0``, ``max_batch=1``) every request occupies the
    server for ``service_s`` back-to-back — N concurrent clients serialize,
    which is the saturation knee of the paper's Fig 4.  With batching
    enabled, requests that become ready within ``batch_window_s`` of the
    first one (up to ``max_batch``) share ONE ``service_s`` — the masked
    batched cloud step the ``CloudBatcher`` actually executes — so the
    knee moves from N*service_s to service_s + window.

    ``service(ready_t, service_s=None)`` books one request that is ready
    (uploaded + request arrived) at virtual time ``ready_t`` and returns
    its completion time.  A joining request may carry a larger per-request
    service cost (e.g. backfill rings); the batch's completion extends to
    cover it.  Both ``netsim.simulate`` and ``AsyncSimChannel`` price the
    cloud through this class, so the simulator and the live engine agree
    on the batched knee by construction.
    """

    def __init__(self, service_s: float = 0.0, *,
                 batch_window_s: float = 0.0, max_batch: int = 1,
                 window_controller: Any = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if batch_window_s > 0.0 and max_batch == 1:
            # the window would delay every request with nothing ever
            # joining a batch — strictly worse than FIFO, silently
            raise ValueError("batch_window_s > 0 requires max_batch > 1 "
                             "(a window with max_batch=1 never coalesces)")
        self.service_s = float(service_s)
        self.batch_window_s = float(batch_window_s)
        self._init_window_s = self.batch_window_s
        self.max_batch = int(max_batch)
        # optional adaptive controller (serving.adaptive.WindowController):
        # consulted on every booking with the request's ready time, it
        # returns the accumulation window to use from the observed arrival
        # rate — None keeps the static knob
        self.window_controller = window_controller
        self.reset()

    def reset(self) -> None:
        """Forget all virtual-time state (a fresh run on a reused point)."""
        self._free = 0.0           # when the server is next idle
        self._close_t = -math.inf  # open batch's accumulation window end
        self._start_t = 0.0        # open batch's service start
        self._done_t = 0.0         # open batch's completion
        self._count = 0            # requests in the open batch
        self.batches = 0           # total batched service steps booked
        self.requests = 0
        self.busy_s = 0.0          # summed server busy time (per batch,
                                   # not per request — coalescing shrinks it)
        self.batch_window_s = self._init_window_s
        if self.window_controller is not None:
            self.window_controller.reset()

    @property
    def batched(self) -> bool:
        return self.max_batch > 1 or self.batch_window_s > 0.0

    def service(self, ready_t: float, service_s: Optional[float] = None
                ) -> float:
        svc = self.service_s if service_s is None else float(service_s)
        self.requests += 1
        if self.window_controller is not None:
            self.batch_window_s = float(
                self.window_controller.observe(ready_t, self))
        if self._count and self._count < self.max_batch \
                and ready_t <= self._close_t:
            # join the open batch: one masked step serves this request too;
            # a costlier member (backfill ring) stretches the completion
            self._count += 1
            stretched = max(self._done_t, self._start_t + svc)
            self.busy_s += stretched - self._done_t
            self._done_t = stretched
            self._free = max(self._free, self._done_t)
            return self._done_t
        # open a new batch: wait out the accumulation window, then serve
        self.batches += 1
        self._count = 1
        self._close_t = ready_t + self.batch_window_s
        self._start_t = max(self._close_t, self._free)
        self._done_t = self._start_t + svc
        self._free = self._done_t
        self.busy_s += svc
        return self._done_t


# ---------------------------------------------------------------------------
# Cloud channel (async edge->cloud request path)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CloudRequest:
    """One in-flight cloud request.

    ``slot``/``seq`` identify the engine slot *generation* that issued the
    request: a reply whose (slot, seq) no longer matches the live slot is
    late — it must be dropped, never applied to the slot's successor.
    ``reply`` is an opaque caller payload (the engine stores the batched
    device logits + row index so materialization can be deferred until the
    reply is drained — jax async dispatch overlaps the cloud compute with
    the edge decode in wall-clock time, the channel overlaps it in virtual
    time)."""
    handle: int
    slot: int
    seq: int
    pos: int
    reply: Any
    submit_t: float
    arrival_t: float
    deadline_t: float
    nbytes_up: int = 0
    nbytes_down: int = 0


@dataclasses.dataclass
class ChannelStats:
    requests: int = 0
    replies: int = 0
    dropped: int = 0            # submitted but never delivered (reset /
                                # end-of-run drain): zero flight billed
    bytes_up: int = 0           # requests + notified uploads
    bytes_down: int = 0         # delivered replies only
    flight_s: float = 0.0       # summed virtual in-flight time of
                                # DELIVERED replies (billed at poll)

    def as_row(self) -> Dict[str, float]:
        return {"requests": self.requests, "replies": self.replies,
                "dropped": self.dropped,
                "bytes_up": self.bytes_up, "bytes_down": self.bytes_down,
                "flight_s": round(self.flight_s, 4)}


class CloudChannel:
    """Base channel: immediate arrival (a blocking call in disguise).

    Subclasses override ``_latency`` (virtual seconds between submit and
    reply arrival) and optionally ``notify_upload`` (the per-tick l_ee1
    hidden-state upload occupies the uplink even when no request rides on
    it).  ``deadline_s`` is the per-request reply budget; ``math.inf``
    disables the latency-aware early exit."""

    def __init__(self, deadline_s: float = math.inf):
        self.deadline_s = float(deadline_s)
        self._next_handle = 0
        self._inflight: Dict[int, CloudRequest] = {}
        self.stats = ChannelStats()

    # -- protocol -----------------------------------------------------------
    def submit(self, *, slot: int = 0, seq: int = 0, pos: int = 0,
               reply: Any = None, now: float = 0.0, nbytes_up: int = 0,
               nbytes_down: int = 0) -> int:
        handle = self._next_handle
        self._next_handle += 1
        arrival = now + self._latency(slot, now, nbytes_up, nbytes_down)
        self._inflight[handle] = CloudRequest(
            handle=handle, slot=slot, seq=seq, pos=pos, reply=reply,
            submit_t=now, arrival_t=arrival,
            deadline_t=now + self.deadline_s,
            nbytes_up=nbytes_up, nbytes_down=nbytes_down)
        # only the request side is billed here: the reply's downlink bytes
        # and its flight time are billed when the reply is actually
        # delivered by ``poll`` — a request discarded by ``reset``/
        # ``drop_in_flight`` must not count virtual flight it never flew
        self.stats.requests += 1
        self.stats.bytes_up += nbytes_up
        return handle

    def poll(self, now: float = math.inf) -> List[CloudRequest]:
        """Drain every reply that has arrived by virtual time ``now``
        (in arrival order).  Late replies still arrive — the caller is
        responsible for dropping the ones whose slot moved on."""
        due = sorted((r for r in self._inflight.values()
                      if r.arrival_t <= now), key=lambda r: r.arrival_t)
        for r in due:
            del self._inflight[r.handle]
            self.stats.bytes_down += r.nbytes_down
            self.stats.flight_s += r.arrival_t - r.submit_t
        self.stats.replies += len(due)
        return due

    def next_arrival(self) -> Optional[float]:
        """Earliest pending arrival (the engine advances its virtual clock
        here when every row is blocked on the channel)."""
        if not self._inflight:
            return None
        return min(r.arrival_t for r in self._inflight.values())

    def arrival_of(self, handle: int) -> Optional[float]:
        """Arrival time of one in-flight request (None once drained) —
        the blocking drain waits for a whole dispatch batch with this."""
        req = self._inflight.get(handle)
        return None if req is None else req.arrival_t

    def in_flight(self) -> int:
        return len(self._inflight)

    def notify_upload(self, slot: int, nbytes: int, now: float) -> None:
        """Account a parallel upload that is not itself a request."""
        del slot, now
        self.stats.bytes_up += nbytes

    def drop_in_flight(self) -> int:
        """Discard every in-flight request without billing it: the reply
        was never consumed (end-of-run drain, slot teardown), so its
        flight time and downlink bytes never happened.  Returns the count
        (the ``dropped`` stat increments by the same amount)."""
        n = len(self._inflight)
        self._inflight.clear()
        self.stats.dropped += n
        return n

    def reset(self) -> None:
        """Forget virtual-time state between ``generate()`` runs.

        A reused channel would otherwise inherit the previous run's link /
        service bookkeeping (virtual times far beyond the new run's clock)
        and skew the second run's latency trace.  Cumulative counters
        (``stats``) survive; any stale in-flight request is dropped
        unbilled (it counts as ``dropped``, never as flight)."""
        self.drop_in_flight()

    # -- latency model ------------------------------------------------------
    def _latency(self, slot: int, now: float, nbytes_up: int,
                 nbytes_down: int) -> float:
        del slot, now, nbytes_up, nbytes_down
        return 0.0


class SyncChannel(CloudChannel):
    """Zero-latency, infinite-deadline channel: the engine behaves exactly
    like the pre-channel blocking implementation (token-for-token)."""

    def __init__(self):
        super().__init__(deadline_s=math.inf)


class AsyncSimChannel(CloudChannel):
    """Virtual-time network channel priced by ``netsim.NetworkParams``.

    Each engine slot owns its WiFi-class link (paper §5: one link per edge
    client); the cloud is a ``CloudServicePoint`` shared by every request —
    exactly the accounting ``netsim.simulate`` uses, so the simulator and
    the live engine price the same trace identically.  Passing one
    ``service`` instance to several channels models N edge clients sharing
    one cloud server: their requests contend in (and, with batching knobs,
    coalesce at) the same queue.

      arrival = cloud_done + rtt/2 + nbytes_down / down_bw
      cloud_done = service.service(uplink_arrival)
      uplink_arrival = max(now, uplink_free[slot]) + nbytes_up/up_bw + rtt/2

    ``net`` is duck-typed: anything with up_bw / down_bw / rtt fields
    (``netsim.NetworkParams``) works."""

    def __init__(self, net: Any, *, service_s: float = 0.0,
                 deadline_s: float = math.inf,
                 service: Optional[CloudServicePoint] = None):
        super().__init__(deadline_s=deadline_s)
        self.net = net
        self._own_service = service is None
        self.service = (CloudServicePoint(service_s) if service is None
                        else service)
        self._uplink_free: Dict[int, float] = {}

    def _latency(self, slot: int, now: float, nbytes_up: int,
                 nbytes_down: int) -> float:
        link_free = max(now, self._uplink_free.get(slot, 0.0))
        up_arr = link_free + nbytes_up / self.net.up_bw + self.net.rtt / 2
        self._uplink_free[slot] = link_free + nbytes_up / self.net.up_bw
        cloud_done = self.service.service(up_arr)
        arrival = cloud_done + self.net.rtt / 2 + nbytes_down / self.net.down_bw
        return arrival - now

    def notify_upload(self, slot: int, nbytes: int, now: float) -> None:
        super().notify_upload(slot, nbytes, now)
        # the l_ee1 upload occupies this client's uplink: a request issued
        # right after it queues behind it (paper's parallel upload still
        # costs link time, it just overlaps edge compute)
        link_free = max(now, self._uplink_free.get(slot, 0.0))
        self._uplink_free[slot] = link_free + nbytes / self.net.up_bw

    def reset(self) -> None:
        super().reset()
        self._uplink_free.clear()
        # a shared service point is coordinated by the multi-engine driver
        # (one reset per run, not one per channel)
        if self._own_service:
            self.service.reset()


class ScriptedChannel(CloudChannel):
    """Replay an explicit per-request latency trace (request i takes
    ``latencies[i % len]`` virtual seconds).  Deterministic harness for the
    deadline-miss and reply-reordering tests."""

    def __init__(self, latencies, *, deadline_s: float = math.inf):
        super().__init__(deadline_s=deadline_s)
        self.latencies = list(latencies)
        if not self.latencies:
            raise ValueError("ScriptedChannel needs at least one latency")
        self._i = 0

    def _latency(self, slot: int, now: float, nbytes_up: int,
                 nbytes_down: int) -> float:
        lat = float(self.latencies[self._i % len(self.latencies)])
        self._i += 1
        return lat

    def reset(self) -> None:
        super().reset()
        self._i = 0          # a reused channel replays the trace from the top
