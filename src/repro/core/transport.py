"""Edge<->cloud transport: wire formats and quantization (paper §4.3).

The paper uploads hidden states in float16 (validated range ±65504).  We
implement fp16 (paper-faithful) plus an int8 per-row-scaled format
(beyond-paper: 2x fewer bytes, evaluated in EXPERIMENTS.md §Perf).

For SSM/hybrid architectures the packet carries the recurrent state
snapshots at the partition boundary in addition to the token activation
(see DESIGN.md §4) — the cloud cannot reconstruct them from a single
token's hidden state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

FORMATS = ("float32", "float16", "int8")


def quantize(x: jax.Array, fmt: str) -> Dict[str, jax.Array]:
    if fmt == "float32":
        return {"data": x.astype(jnp.float32)}
    if fmt == "float16":
        return {"data": x.astype(jnp.float16)}
    if fmt == "int8":
        xf = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return {"data": q, "scale": scale}
    raise ValueError(fmt)


def dequantize(packet: Dict[str, jax.Array], dtype=jnp.float32) -> jax.Array:
    data = packet["data"]
    if data.dtype == jnp.int8:
        return (data.astype(jnp.float32) * packet["scale"]).astype(dtype)
    return data.astype(dtype)


def packet_bytes(packet: Pytree) -> int:
    """Wire size of a (possibly nested) packet in bytes."""
    leaves = jax.tree.leaves(packet)
    return int(sum(l.size * l.dtype.itemsize for l in leaves))


def quantize_tree(tree: Pytree, fmt: str) -> Pytree:
    """Quantize every array leaf of a state snapshot."""
    return jax.tree.map(lambda x: quantize(x, fmt), tree)


def dequantize_tree(tree: Pytree, dtype=jnp.float32) -> Pytree:
    is_packet = lambda t: isinstance(t, dict) and "data" in t
    return jax.tree.map(lambda p: dequantize(p, dtype), tree,
                        is_leaf=is_packet)


@dataclasses.dataclass
class StatePacket:
    """What crosses the edge->cloud boundary for one upload (paper fig 3
    step 3): the l_ee1 token activation, and (SSM/hybrid only) boundary
    recurrent-state snapshots."""
    hidden: Dict[str, jax.Array]                   # quantized (B,1,d)
    states: Optional[Pytree] = None                # quantized recurrent states
    pos: Optional[jax.Array] = None                # token position

    def nbytes(self) -> int:
        n = packet_bytes(self.hidden)
        if self.states is not None:
            n += packet_bytes(self.states)
        if self.pos is not None:
            n += 4
        return n


def make_packet(hidden: jax.Array, fmt: str, *, states: Pytree = None,
                pos: jax.Array = None) -> StatePacket:
    return StatePacket(
        hidden=quantize(hidden, fmt),
        states=quantize_tree(states, fmt) if states is not None else None,
        pos=pos,
    )


def open_packet(pkt: StatePacket, dtype=jnp.float32
                ) -> Tuple[jax.Array, Optional[Pytree]]:
    hidden = dequantize(pkt.hidden, dtype)
    states = (dequantize_tree(pkt.states, dtype)
              if pkt.states is not None else None)
    return hidden, states
