"""Mesh-aware serving execution (docs/sharding.md).

One ``MeshContext`` per ``CoLLM`` owns

  * the cloud ``Mesh`` built from ``CollmConfig.cloud_mesh`` (or no mesh
    at all — the single-device default), plus its ``ShardingPolicy``;
  * every jitted step wrapper the serving stack uses.  This absorbs the
    old per-CoLLM ``_jit`` memoization that lived in ``cloud_batcher``:
    the memoization is what guarantees N engines driving one CoLLM share
    a single trace per step, so it stays — but cloud-partition steps are
    now traced under the sharding policy, baking ``constrain_residual``
    / ``constrain_logits`` constraints into the compiled graph;
  * placement: params via role-based ``params_shardings`` and the pooled
    batch-major cloud KV via ``cache_shardings``.  jit then propagates
    ``NamedSharding``s from the committed inputs, and the activation
    constraints pin the interior (GSPMD fills in the rest).

With ``cloud_mesh=None`` (the default) there is no mesh, no policy and
no placement — ``jit_step`` degenerates to plain ``jax.jit`` and the
single-device path is byte-for-byte what it was before this layer
existed.

This module must not import ``repro.serving.engine`` or
``repro.serving.cloud_batcher`` (they import us).
"""
from __future__ import annotations

import collections
import functools
from typing import Any, Callable, Dict, Optional

import jax

from repro.launch import sharding as shardlib
from repro.launch.mesh import make_cloud_mesh

Pytree = Any

# Steps that run on the cloud partition: traced under the sharding
# policy so residual/logits constraints land in the jaxpr.  Edge-side
# steps stay policy-free — the mesh shards the *cloud* service; the edge
# is a different machine in the deployment this emulates.
CLOUD_STEPS = frozenset({
    "cloud_step", "cloud_step_masked",
    "ring_cloud_steps", "ring_cloud_steps_all",
    "cloud_prefill_padded", "cloud_prefill_chunk",
    "invalidate_rows_after",
    "full_step", "full_prefill_padded",      # mode="cloud" baseline
})


class MeshContext:
    """Owns a cloud mesh + policy and the per-CoLLM jitted step cache."""

    def __init__(self, mesh=None, *, head_dim: int = 0):
        self.mesh = mesh
        self.head_dim = head_dim     # head-aligned attention sharding
        self.policy = (shardlib.ShardingPolicy(mesh, batch=1)
                       if mesh is not None else None)
        self._steps: Dict[str, Callable] = {}
        self._jitted: Dict[str, Callable] = {}   # underlying jax.jit objects
        # name -> number of times jax actually (re)traced the step; the
        # counter lives inside the traced python function, so cache hits
        # never bump it (bench/tests assert no re-trace per engine)
        self.trace_counts: collections.Counter = collections.Counter()

    @property
    def active(self) -> bool:
        return self.mesh is not None

    # -- jit ---------------------------------------------------------------
    def jit(self, name: str, fn: Callable) -> Callable:
        cached = self._steps.get(name)
        if cached is not None:
            return cached
        counts = self.trace_counts

        @functools.wraps(fn)
        def traced(*a, **kw):
            counts[name] += 1            # runs only while jax traces
            return fn(*a, **kw)

        jf = jax.jit(traced)
        self._jitted[name] = jf
        if self.policy is not None and name in CLOUD_STEPS:
            policy = self.policy

            def stepped(*a, **kw):
                with shardlib.use_policy(policy):
                    return jf(*a, **kw)

            cached = stepped
        else:
            cached = jf
        self._steps[name] = cached
        return cached

    def jitted(self, name: str) -> Optional[Callable]:
        """Underlying ``jax.jit`` object (e.g. for ``.lower()``)."""
        return self._jitted.get(name)

    # -- placement ---------------------------------------------------------
    def shard_params(self, params: Pytree, *, fsdp: bool = False) -> Pytree:
        if not self.active:
            return params
        sh = shardlib.params_shardings(params, self.mesh, fsdp=fsdp,
                                       head_dim=self.head_dim)
        return jax.device_put(params, sh)

    def shard_caches(self, caches: Pytree, *, batch: int) -> Pytree:
        if not self.active:
            return caches
        sh = shardlib.cache_shardings(caches, self.mesh, batch=batch)
        return jax.device_put(caches, sh)


def mesh_context(collm) -> MeshContext:
    """The CoLLM's MeshContext, built on first use from
    ``collm.ccfg.cloud_mesh`` and cached on the object (all engines and
    batchers of one CoLLM share it — and therefore share traces)."""
    mc = getattr(collm, "_mesh_ctx", None)
    if mc is None:
        spec = getattr(collm.ccfg, "cloud_mesh", None)
        mesh = make_cloud_mesh(spec) if spec is not None else None
        mc = collm._mesh_ctx = MeshContext(
            mesh, head_dim=collm.model.cfg.resolved_head_dim)
    return mc


def jit_step(collm, name: str) -> Callable:
    """Memoized jit of a bound CoLLM step method (the old ``_jit``)."""
    return mesh_context(collm).jit(name, getattr(collm, name))
