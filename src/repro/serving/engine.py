"""Host-level multi-client serving — the CE-CoLLM system at scale.

Topology (paper fig 2/3): N edge clients, each running the edge LLM
partition with exits at l_ee1/l_ee2; one cloud server running the cloud
partition behind a ContentManager.  Per generated token (Algorithm 1):

  1. edge computes layers 1..l_ee1, evaluates exit 1, and dispatches the
     quantized l_ee1 hidden to the cloud (parallel upload);
  2. if conf1 < θ, edge continues to l_ee2, evaluates exit 2;
  3. if conf2 < θ, the edge requests cloud inference; the cloud pops the
     uploaded state from the content manager and completes layers
     l_ee1+1..L, returning one token (single-token response);
  4. the content manager releases unused uploads (paper) or backfills them
     through the cloud partition (beyond-paper exact-KV mode).

Two execution engines implement that contract:

  * ``BatchScheduler`` (default) — a continuous-batching engine.  A fixed
    pool of B slots, each holding one client's stream, is stepped by a
    single jitted batched edge step with per-row positions and per-row exit
    gating; one masked cloud call serves every below-θ row of a step.
    Finished slots are recycled and refilled from the request queue without
    recompiling (prompt lengths are bucketed; the decode graph is compiled
    once per pool size).  KV lives either in per-slot dense rings
    (``kv_layout="dense"``: memory B x max_seq) or in a block-paged pool
    shared across slots (``kv_layout="paged"``: memory num_pages x
    page_size, per-slot block tables, admission back-pressure when pages
    run out, and per-stream context up to max_ctx > max_seq).  See
    docs/serving.md for the slot lifecycle and docs/kv_paging.md for the
    paged layout.
  * ``ServingSystem.generate_sequential`` — the seed's per-client loop
    (batch=1, one Python iteration per token).  Kept as the reference
    implementation: the batched engine is token-for-token equivalent to it
    under greedy decoding, and the throughput bench measures one against
    the other.

Everything is measured: per-token exit level, cloud request rate, wire
bytes, partition wall-times (feeds the netsim), and agreement vs. the
undivided model (the paper's ROUGE-L proxy).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collm import CoLLM, CollmConfig
from repro.core.content_manager import ContentManager
from repro.core.exits import select_exit_logits
from repro.core.paging import PagePool, pages_needed
from repro.core.transport import StatePacket, packet_bytes, quantize
from repro.models.attention import paged_reset_pages, paged_scatter_prefill
from repro.models.transformer import Model
from repro.serving import sampler as samplerlib

Pytree = Any


@dataclasses.dataclass
class GenStats:
    tokens: int = 0
    exits_l1: int = 0
    exits_l2: int = 0
    cloud_requests: int = 0
    upload_bytes: int = 0
    edge_time: float = 0.0
    cloud_time: float = 0.0
    confidences: List[tuple] = dataclasses.field(default_factory=list)

    @property
    def request_rate(self) -> float:
        return self.cloud_requests / max(self.tokens, 1)


def _aggregate(stats: Sequence[GenStats]) -> GenStats:
    agg = GenStats()
    for st in stats:
        agg.tokens += st.tokens
        agg.exits_l1 += st.exits_l1
        agg.exits_l2 += st.exits_l2
        agg.cloud_requests += st.cloud_requests
        agg.upload_bytes += st.upload_bytes
        agg.edge_time += st.edge_time
        agg.cloud_time += st.cloud_time
        agg.confidences.extend(st.confidences)
    return agg


def _prompt_wire_bytes(shape, compute_dtype, wire_format: str) -> int:
    """Wire size of the prompt's h1 upload in the configured format —
    computed from the quantized packet ABSTRACTLY (eval_shape: no device
    work), so int8 runs report int8 bytes, not hardcoded fp16."""
    spec = jax.eval_shape(
        lambda: quantize(jnp.zeros(shape, compute_dtype), wire_format))
    return packet_bytes(spec)


class CloudServer:
    """Cloud partition + content manager (one per deployment)."""

    def __init__(self, collm: CoLLM, params: Pytree, max_clients_pending: int = 8):
        self.collm = collm
        self.params = params
        self.cm = ContentManager(max_pending_per_client=max_clients_pending)
        self._cloud_step = jax.jit(collm.cloud_step)

    def register(self, device_id: str, batch: int, max_seq: int,
                 h1_prompt: Optional[jax.Array] = None,
                 enc_out: Optional[jax.Array] = None):
        caches = self.collm.init_cloud_cache(batch, max_seq)
        logits = None
        if h1_prompt is not None:
            logits, caches = self.collm.cloud_prefill(self.params, h1_prompt,
                                                      caches, enc_out=enc_out)
        self.cm.put_cache(device_id, caches)
        return logits

    def receive_upload(self, device_id: str, pos: int,
                       packet: StatePacket) -> None:
        self.cm.upload(device_id, pos, packet)

    def infer(self, device_id: str, pos: int, *, backfill: bool) -> jax.Array:
        """Single-token response (paper §4.2)."""
        caches = self.cm.get_cache(device_id)
        if backfill:
            pending = self.cm.take_uploads_upto(device_id, pos)
        else:
            pkt = self.cm.take_upload(device_id, pos)
            pending = [(pos, pkt)]
        logits = None
        for p, pkt in pending:
            logits, caches = self._cloud_step(
                self.params, pkt.hidden, caches, jnp.asarray(p, jnp.int32))
        self.cm.put_cache(device_id, caches)
        return logits

    def finish(self, device_id: str) -> None:
        self.cm.end_of_sequence(device_id)


class EdgeClient:
    """Edge partition runtime for one device."""

    def __init__(self, collm: CoLLM, params: Pytree, device_id: str,
                 batch: int, max_seq: int):
        self.collm = collm
        self.params = params
        self.device_id = device_id
        self.caches = collm.init_edge_cache(batch, max_seq)
        self._edge_step = jax.jit(collm.edge_step)
        self.pos = 0

    def prefill(self, batch: Dict[str, jax.Array]):
        decisions, h1_seq, self.caches = self.collm.edge_prefill(
            self.params, batch, self.caches)
        self.pos = h1_seq.shape[1]
        return decisions, h1_seq

    def step(self, token: jax.Array):
        out = self._edge_step(self.params, token, self.caches,
                              jnp.asarray(self.pos, jnp.int32))
        self.caches = out.caches
        self.pos += 1
        return out


# ---------------------------------------------------------------------------
# continuous-batching engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    """One client stream queued for the scheduler."""
    device_id: str
    prompt: np.ndarray
    max_new: int
    eos_id: Optional[int] = None
    index: int = 0                   # submission order (result slot)


@dataclasses.dataclass
class _Slot:
    """One row of the batched pool.  Lifecycle:
    FREE -> (admit: prefill + scatter row caches) ACTIVE
         -> (decode ticks) ... -> (EOS / max_new) FINISHED -> FREE."""
    index: int
    req: Optional[Request] = None
    stats: Optional[GenStats] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0
    last_token: int = 0
    active: bool = False


def _bucket(n: int, floor: int = 8) -> int:
    """Next power-of-two length bucket >= n (bounds prefill recompiles)."""
    b = floor
    while b < n:
        b *= 2
    return b


def _put_row(f: jax.Array, r: jax.Array, j) -> jax.Array:
    """Insert one cache row into a pooled leaf; the batch axis is located
    by shape mismatch (stacked segments carry batch at axis 1, shared
    segments at axis 0)."""
    if f.shape == r.shape:                          # pool of size 1
        return r.astype(f.dtype)
    axis = next(i for i, (a, b) in enumerate(zip(f.shape, r.shape))
                if a != b)
    return jax.lax.dynamic_update_slice_in_dim(f, r.astype(f.dtype), j, axis)


def _scatter_row(full: Pytree, row: Pytree, j) -> Pytree:
    """Insert a single-row cache pytree into a batched pool at row j."""
    return jax.tree.map(lambda f, r: _put_row(f, r, j), full, row)


def _scatter_row_paged(full: Pytree, row: Pytree, j,
                       pages: jax.Array) -> Pytree:
    """Paged admission scatter: self-attention K/V of the prefilled row is
    written into its allocated physical pages (``pages``: one id per
    logical prompt page, -1 entries redirect to the trash page); every
    other cache leaf (cross-attn, recurrent state) is a dense per-row
    scatter at row j exactly like the dense layout."""
    def go(f: Pytree, r: Pytree) -> Pytree:
        if isinstance(f, dict):
            if "kp" in f:
                if f["kp"].ndim == 5:       # stacked: (L, P, ps, KV, d)
                    return jax.vmap(paged_scatter_prefill,
                                    in_axes=(0, 0, None))(f, r, pages)
                return paged_scatter_prefill(f, r, pages)
            return {k: go(f[k], r[k]) for k in f}
        return _put_row(f, r, j)
    return {si: go(full[si], row[si]) for si in full}


def _reset_pages_tree(caches: Pytree, pages: jax.Array) -> Pytree:
    """Invalidate freed physical pages across every paged cache node, so a
    page returned to the free list never leaks a retired stream's K/V."""
    def go(c: Pytree) -> Pytree:
        if isinstance(c, dict):
            if "kp" in c:
                if c["kp"].ndim == 5:
                    return jax.vmap(paged_reset_pages,
                                    in_axes=(0, None))(c, pages)
                return paged_reset_pages(c, pages)
            return {k: go(v) for k, v in c.items()}
        return c
    return {si: go(c) for si, c in caches.items()}


class BatchScheduler:
    """Continuous-batching multi-slot decode engine.

    Replaces the seed's per-client Python loops: B client streams advance
    together under one jitted edge step with per-row positions; exits are
    gated per row; one masked cloud call serves all below-θ rows of a tick;
    finished slots are refilled from the queue without recompiling.

    With ``CollmConfig.kv_layout="paged"`` the scheduler also owns a
    ``PagePool``: admission reserves the worst-case page count (and
    back-pressures when the pool is exhausted), prefill scatters the
    prompt's K/V into freshly allocated pages, each decode tick allocates a
    page only when a row crosses a page boundary, and retirement bulk-frees
    the slot's pages and invalidates them on device.  The block table is
    shared by the edge/cloud/full cache pools (same token positions) and is
    passed into every jitted step.
    """

    def __init__(self, collm: CoLLM, params: Pytree, cm: ContentManager,
                 num_slots: int, max_seq: int, mode: str = "collm",
                 sampler: str = "greedy", temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0,
                 max_ctx: Optional[int] = None,
                 num_pages: Optional[int] = None):
        if mode not in ("collm", "standalone", "cloud"):
            raise ValueError(mode)
        self.collm = collm
        self.model = collm.model
        self.ccfg = collm.ccfg
        self.params = params
        self.cm = cm
        self.B = num_slots
        self.max_seq = max_seq
        self.mode = mode
        self.sampler = sampler
        self.temperature = temperature
        self.top_k = top_k
        self._rng = jax.random.PRNGKey(seed)
        self.slots = [_Slot(index=i) for i in range(num_slots)]

        # KV layout.  dense: every slot owns a max_seq ring (pool memory
        # B x max_seq; a slot can never hold more than max_seq).  paged:
        # slots share num_pages x page_size tokens of K/V through per-slot
        # block tables — one stream may grow to max_ctx (> max_seq) as long
        # as pages are free, and admission back-pressures on the pool
        # instead of failing (docs/kv_paging.md).
        self.layout = self.ccfg.kv_layout
        if self.layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout {self.layout!r}")
        self.pool: Optional[PagePool] = None
        self._tbl_device: Optional[jax.Array] = None   # cached device table
        if self.layout == "paged":
            ps = self.ccfg.page_size
            self.max_ctx = max_ctx or max_seq
            n_pages = num_pages or num_slots * pages_needed(max_seq, ps)
            self.pool = PagePool(n_pages, ps, num_slots,
                                 pages_needed(self.max_ctx, ps))
            row_seq = _bucket(self.max_ctx)
        else:
            self.max_ctx = max_seq
            row_seq = max_seq
        self._row_seq = row_seq        # single-row prefill cache capacity

        # pooled caches (compiled once per pool size; refills only scatter)
        if mode == "cloud":
            self.main_caches = self._init_pool_cache(self.model.init_cache,
                                                     self.model.init_paged_cache)
            self._full_row0 = self.model.init_cache(1, row_seq)
        else:
            self.edge_caches = self._init_pool_cache(
                collm.init_edge_cache, collm.init_edge_cache_paged)
            self._edge_row0 = collm.init_edge_cache(1, row_seq)
            if mode == "collm":
                self.cloud_caches = self._init_pool_cache(
                    collm.init_cloud_cache, collm.init_cloud_cache_paged)
                self._cloud_row0 = collm.init_cloud_cache(1, row_seq)

        self._edge_step = jax.jit(collm.edge_step)
        self._full_step = jax.jit(collm.full_step)
        self._cloud_masked = jax.jit(collm.cloud_step_masked)
        self._ring_cloud = jax.jit(collm.ring_cloud_steps)
        self._scatter = jax.jit(_scatter_row)
        self._scatter_paged = jax.jit(_scatter_row_paged)
        self._reset_pages = jax.jit(_reset_pages_tree)
        self._edge_prefill = jax.jit(collm.edge_prefill_padded)
        self._cloud_prefill = jax.jit(collm.cloud_prefill_padded)
        self._full_prefill = jax.jit(collm.full_prefill_padded)
        # recurrent segments can't absorb right-padding (their state would
        # advance through pad tokens) -> exact-length prefill for them
        self._pad_ok = self.model.attention_only()

    def _init_pool_cache(self, dense_init, paged_init):
        if self.layout == "paged":
            return paged_init(self.B, self.pool.num_pages,
                              self.pool.page_size)
        return dense_init(self.B, self.max_seq)

    def kv_cache_bytes(self) -> int:
        """Device bytes held by the pooled KV/state caches (the number the
        paged layout shrinks: num_pages x page_size instead of B x max_seq)."""
        total = 0
        for name in ("main_caches", "edge_caches", "cloud_caches"):
            c = getattr(self, name, None)
            if c is not None:
                total += sum(l.size * l.dtype.itemsize
                             for l in jax.tree.leaves(c))
        return total

    def _block_tbl(self) -> Optional[jax.Array]:
        """Device copy of the pool's block table, re-uploaded only after an
        alloc/free actually changed it (most ticks change nothing)."""
        if self.pool is None:
            return None
        if self._tbl_device is None:
            self._tbl_device = jnp.asarray(self.pool.block_table)
        return self._tbl_device

    # -- sampling -----------------------------------------------------------
    def _pick(self, logits: np.ndarray) -> np.ndarray:
        """logits (B, V) -> tokens (B,) under the configured sampler."""
        if self.sampler == "greedy":
            return np.argmax(logits, axis=-1).astype(np.int32)
        self._rng, sub = jax.random.split(self._rng)
        return np.asarray(samplerlib.sample(
            jnp.asarray(logits), method=self.sampler, rng=sub,
            temperature=self.temperature, top_k=self.top_k))

    # -- admission ----------------------------------------------------------
    def _admissible(self, req: Request, p_len: int, pad: int) -> bool:
        """Capacity check.  Impossible requests raise; a request the paged
        pool could serve but not *right now* stays queued (back-pressure)."""
        if p_len + req.max_new > self.max_ctx or pad > self._row_seq:
            raise ValueError(
                f"request {req.device_id}: prompt {p_len} + max_new "
                f"{req.max_new} exceeds max context {self.max_ctx}")
        if self.pool is None:
            return True
        need = pages_needed(p_len + req.max_new, self.pool.page_size)
        if need > self.pool.num_pages:
            raise ValueError(
                f"request {req.device_id}: needs {need} pages but the pool "
                f"only has {self.pool.num_pages}")
        return self.pool.can_admit(p_len + req.max_new)

    def _admit_pages(self, slot: _Slot, p_len: int, pad: int,
                     max_new: int) -> np.ndarray:
        """Reserve the worst case, allocate the prompt's pages now, and
        return the scatter table (one physical id per logical bucket page;
        -1 = trash for bucket padding past the prompt)."""
        pool = self.pool
        pool.reserve(slot.index, p_len + max_new)
        n_prompt = pages_needed(p_len, pool.page_size)
        for lp in range(n_prompt):
            pool.alloc(slot.index, lp)
        pages = np.full((pages_needed(pad, pool.page_size),), -1, np.int32)
        pages[:n_prompt] = pool.block_table[slot.index, :n_prompt]
        self._tbl_device = None
        return pages

    def _scatter_admit(self, full: Pytree, row: Pytree, slot: _Slot,
                       pages: Optional[np.ndarray]) -> Pytree:
        if pages is None:
            return self._scatter(full, row, slot.index)
        return self._scatter_paged(full, row, slot.index, jnp.asarray(pages))

    def _admit(self, queue) -> None:
        for slot in self.slots:
            if slot.active or not queue:
                continue
            req: Request = queue[0]
            prompt = np.asarray(req.prompt, np.int32)
            p_len = len(prompt)
            pad = _bucket(p_len) if self._pad_ok else p_len
            if not self._admissible(req, p_len, pad):
                break                       # FIFO back-pressure: wait for pages
            queue.popleft()
            pages = (self._admit_pages(slot, p_len, pad, req.max_new)
                     if self.pool is not None else None)
            tokens = np.zeros((1, pad), np.int32)
            tokens[0, :p_len] = prompt
            st = GenStats()
            if self.mode == "cloud":
                t0 = time.perf_counter()
                logits, row = self._full_prefill(self.params, tokens, p_len,
                                                 self._full_row0)
                self.main_caches = self._scatter_admit(self.main_caches, row,
                                                       slot, pages)
                first = self._pick(np.asarray(logits)[:, 0])
                st.cloud_time += time.perf_counter() - t0
                tok = int(first[0])
            else:
                t0 = time.perf_counter()
                decisions, h1_seq, row = self._edge_prefill(
                    self.params, tokens, p_len, self._edge_row0)
                self.edge_caches = self._scatter_admit(self.edge_caches, row,
                                                       slot, pages)
                fetched = jax.device_get(
                    {l: (d.token, d.confidence, d.logits)
                     for l, d in decisions.items()})
                st.edge_time += time.perf_counter() - t0

                prefill_logits = None
                if self.mode == "collm":
                    t0 = time.perf_counter()
                    logits, crow = self._cloud_prefill(
                        self.params, h1_seq, p_len, self._cloud_row0)
                    self.cloud_caches = self._scatter_admit(
                        self.cloud_caches, crow, slot, pages)
                    prefill_logits = np.asarray(logits)[:, 0]
                    st.cloud_time += time.perf_counter() - t0
                    st.upload_bytes += _prompt_wire_bytes(
                        (1, p_len, self.model.cfg.d_model),
                        self.model.compute_dtype, self.ccfg.wire_format)

                tok = self._first_token(fetched, prefill_logits, st)
            st.tokens = 1
            slot.req, slot.stats = req, st
            slot.tokens = [tok]
            slot.last_token = tok
            slot.pos = p_len
            slot.active = True
            self._maybe_finish(slot)

    def _first_token(self, fetched: Dict, prefill_logits, st: GenStats) -> int:
        """First token from the prompt's last position — same decision tree
        as the sequential path."""
        layers = sorted(fetched)
        if self.mode == "standalone":
            l2 = layers[-1]
            if self.sampler == "greedy":
                return int(fetched[l2][0][0])
            return int(self._pick(np.asarray(fetched[l2][2]))[0])
        for l in layers:
            tok_l, conf_l, logits_l = fetched[l]
            if float(conf_l[0]) >= self.ccfg.theta:
                if self.sampler == "greedy":
                    return int(tok_l[0])
                return int(self._pick(np.asarray(logits_l))[0])
        # cloud already prefilled through the prompt: its last-position
        # logits ARE the cloud answer for the first token
        st.cloud_requests += 1
        return int(self._pick(prefill_logits)[0])

    # -- slot retirement ----------------------------------------------------
    def _maybe_finish(self, slot: _Slot) -> bool:
        req = slot.req
        done = (len(slot.tokens) >= req.max_new
                or (req.eos_id is not None
                    and slot.tokens[-1] == req.eos_id))
        if done:
            if self.mode == "collm":
                self.cm.end_of_sequence(req.device_id)
            slot.active = False
            if self.pool is not None:
                self._free_pages(slot)
        return done

    def _free_pages(self, slot: _Slot) -> None:
        """Bulk-free a retired slot's pages and invalidate them on device
        (pos = -1) so reallocation can never leak its K/V."""
        freed = self.pool.free_slot(slot.index)
        self._tbl_device = None
        if not freed:
            return
        ids = np.full((self.pool.max_logical,), -1, np.int32)
        ids[:len(freed)] = freed
        ids = jnp.asarray(ids)
        for name in ("main_caches", "edge_caches", "cloud_caches"):
            c = getattr(self, name, None)
            if c is not None:
                setattr(self, name, self._reset_pages(c, ids))

    # -- one decode tick ----------------------------------------------------
    def tick(self) -> None:
        active = [s for s in self.slots if s.active]
        if not active:
            return
        tokens = np.zeros((self.B, 1), np.int32)
        pos = np.zeros((self.B,), np.int32)
        for s in active:
            tokens[s.index, 0] = s.last_token
            pos[s.index] = s.pos
            if self.pool is not None:
                # alloc-on-write: this tick writes KV at s.pos
                lp = s.pos // self.pool.page_size
                if self.pool.block_table[s.index, lp] == -1:
                    self.pool.alloc(s.index, lp)
                    self._tbl_device = None

        if self.mode == "cloud":
            self._tick_cloud(active, tokens, pos)
        else:
            self._tick_edge(active, tokens, pos)

        for s in active:
            s.pos += 1
            self._maybe_finish(s)

    def _tick_cloud(self, active, tokens, pos) -> None:
        t0 = time.perf_counter()
        tok, logits, self.main_caches = self._full_step(
            self.params, jnp.asarray(tokens), self.main_caches,
            jnp.asarray(pos), self._block_tbl())
        if self.sampler == "greedy":
            next_tok = np.asarray(tok)
        else:
            next_tok = self._pick(np.asarray(logits))
        dt = (time.perf_counter() - t0) / len(active)
        for s in active:
            s.stats.cloud_time += dt
            self._emit(s, int(next_tok[s.index]))

    def _tick_edge(self, active, tokens, pos) -> None:
        collm, ccfg = self.collm, self.ccfg
        t0 = time.perf_counter()
        out = self._edge_step(self.params, jnp.asarray(tokens),
                              self.edge_caches, jnp.asarray(pos),
                              self._block_tbl())
        self.edge_caches = out.caches
        want_logits = self.sampler != "greedy"
        get = {
            "token": out.token, "exited": out.exited,
            "conf": {l: d.confidence for l, d in out.decisions.items()},
            "tok2": out.decisions[collm.l_ee2].token,
            "upload": out.upload,
        }
        if want_logits:
            if self.mode == "standalone":
                get["logits_l2"] = out.decisions[collm.l_ee2].logits
            else:
                # per-row logits of the chosen exit (sampling path)
                get["sel_logits"] = select_exit_logits(
                    out.decisions, ccfg.theta)[0]
        fetched = jax.device_get(get)
        edge_dt = (time.perf_counter() - t0) / len(active)
        exited = fetched["exited"]
        confs = fetched["conf"]

        for s in active:
            s.stats.edge_time += edge_dt
            s.stats.tokens += 1
            c1 = float(confs.get(collm.l_ee1, np.zeros(self.B))[s.index])
            c2 = float(confs.get(collm.l_ee2, np.zeros(self.B))[s.index])
            s.stats.confidences.append((c1, c2))

        if self.mode == "standalone":
            toks = (fetched["tok2"] if self.sampler == "greedy"
                    else self._pick(fetched["logits_l2"]))
            for s in active:
                c1 = s.stats.confidences[-1][0]
                if c1 >= ccfg.theta:
                    s.stats.exits_l1 += 1
                else:
                    s.stats.exits_l2 += 1
                self._emit(s, int(toks[s.index]))
            return

        # parallel upload (always dispatched at l_ee1) — batched receive
        up = fetched["upload"]
        pkts = {s.index: StatePacket(
            hidden={k: v[s.index:s.index + 1] for k, v in up.items()},
            pos=s.pos) for s in active}
        self.cm.upload_batch((s.req.device_id, s.pos, pkts[s.index])
                             for s in active)
        for s in active:
            s.stats.upload_bytes += pkts[s.index].nbytes()

        needy = [s for s in active if not bool(exited[s.index])]
        cloud_np = None
        if needy:
            cloud_np = self._serve_cloud(needy, pos)
        exit_toks = (fetched["token"] if self.sampler == "greedy"
                     else self._pick(fetched["sel_logits"]))

        for s in active:
            if bool(exited[s.index]):
                if s.stats.confidences[-1][0] >= ccfg.theta:
                    s.stats.exits_l1 += 1
                else:
                    s.stats.exits_l2 += 1
                tok = int(exit_toks[s.index])
            else:
                tok = int(cloud_np[s.index])
            self._emit(s, tok)

    def _serve_cloud(self, needy: List[_Slot], pos: np.ndarray) -> np.ndarray:
        """One masked cloud call serves every below-θ slot of the tick."""
        ccfg = self.ccfg
        mask = np.zeros((self.B,), bool)
        for s in needy:
            mask[s.index] = True
            s.stats.cloud_requests += 1

        t0 = time.perf_counter()
        if ccfg.backfill:
            rings = self.cm.take_uploads_upto_batch(
                [(s.req.device_id, s.pos) for s in needy])
            depth = _bucket(max(len(r) for r in rings), floor=1)
            keys = rings[0][0][1].hidden.keys() if rings[0] else ()
            ring = {k: np.zeros((depth, self.B) + np.shape(
                rings[0][0][1].hidden[k])[1:],
                np.asarray(rings[0][0][1].hidden[k]).dtype) for k in keys}
            ring_pos = np.zeros((depth, self.B), np.int32)
            valid = np.zeros((depth, self.B), bool)
            for s, pend in zip(needy, rings):
                for i, (p, pkt) in enumerate(pend):
                    for k in keys:
                        ring[k][i, s.index] = np.asarray(pkt.hidden[k])[0]
                    ring_pos[i, s.index] = p
                    valid[i, s.index] = True
            logits, self.cloud_caches = self._ring_cloud(
                self.params, {k: jnp.asarray(v) for k, v in ring.items()},
                jnp.asarray(ring_pos), jnp.asarray(valid), self.cloud_caches,
                self._block_tbl())
        else:
            pkts = self.cm.take_upload_batch(
                [(s.req.device_id, s.pos) for s in needy])
            keys = pkts[0].hidden.keys()
            dense = {k: np.zeros((self.B,) + np.shape(pkts[0].hidden[k])[1:],
                                 np.asarray(pkts[0].hidden[k]).dtype)
                     for k in keys}
            for s, pkt in zip(needy, pkts):
                for k in keys:
                    dense[k][s.index] = np.asarray(pkt.hidden[k])[0]
            logits, self.cloud_caches = self._cloud_masked(
                self.params, {k: jnp.asarray(v) for k, v in dense.items()},
                self.cloud_caches, jnp.asarray(pos), jnp.asarray(mask),
                self._block_tbl())

        if self.sampler == "greedy":
            cloud_tok = np.argmax(np.asarray(logits), axis=-1)
        else:
            cloud_tok = self._pick(np.asarray(logits))
        dt = (time.perf_counter() - t0) / len(needy)
        for s in needy:
            s.stats.cloud_time += dt
        return cloud_tok

    def _emit(self, slot: _Slot, tok: int) -> None:
        slot.tokens.append(tok)
        slot.last_token = tok
        if self.mode == "cloud":
            slot.stats.tokens += 1

    # -- driver -------------------------------------------------------------
    def _collect(self, results, stats) -> None:
        """Retire finished slots (frees them for the next admission)."""
        for s in self.slots:
            if s.req is not None and not s.active:
                results[s.req.index] = s.tokens
                stats[s.req.index] = s.stats
                s.req = None

    def run(self, requests: Sequence[Request]):
        """Drain a request list through the slot pool; returns
        (token lists, per-request GenStats) in submission order."""
        for i, r in enumerate(requests):
            r.index = i
        queue = collections.deque(requests)
        results: List[Optional[List[int]]] = [None] * len(requests)
        stats: List[Optional[GenStats]] = [None] * len(requests)
        while queue or any(s.active for s in self.slots):
            self._admit(queue)
            self._collect(results, stats)     # finished at admission
            if any(s.active for s in self.slots):
                self.tick()
                self._collect(results, stats)
            elif queue:
                # nothing active yet the head request could not be admitted:
                # no tick can ever free pages, so fail loudly instead of
                # spinning (cannot happen with reservation accounting).
                raise RuntimeError(
                    f"scheduler wedged: {len(queue)} queued, 0 active, "
                    f"pool {self.pool and self.pool.available_pages} pages")
        return results, stats


class ServingSystem:
    """End-to-end multi-client co-inference."""

    def __init__(self, model: Model, params: Pytree,
                 ccfg: CollmConfig = CollmConfig()):
        self.model = model
        self.params = params
        self.ccfg = ccfg
        self.collm = CoLLM(model, ccfg)
        self.cloud = CloudServer(self.collm, params)
        self._schedulers: Dict[tuple, BatchScheduler] = {}

    # ------------------------------------------------------------------
    def generate(self, prompts: Sequence[np.ndarray], max_new: int,
                 mode: str = "collm", max_seq: Optional[int] = None,
                 *, num_slots: Optional[int] = None,
                 sampler: str = "greedy", temperature: float = 1.0,
                 top_k: int = 0, eos_id: Optional[int] = None,
                 seed: int = 0, max_ctx: Optional[int] = None,
                 num_pages: Optional[int] = None) -> Dict[str, Any]:
        """mode: collm | standalone | cloud.  One client per prompt, decoded
        by the continuous-batching ``BatchScheduler`` (num_slots streams in
        flight; defaults to min(len(prompts), 8)).  The KV layout follows
        ``CollmConfig.kv_layout``; ``max_ctx``/``num_pages`` size the paged
        pool (defaults: max_ctx = max_seq, num_pages = dense-equivalent)."""
        slots = num_slots or max(1, min(len(prompts), 8))
        longest = max(len(p) for p in prompts)
        max_seq = max_seq or (longest + max_new + 8)
        max_seq = max(max_seq, _bucket(longest))
        key = (mode, slots, max_seq, sampler, temperature, top_k, seed,
               max_ctx, num_pages)
        sched = self._schedulers.get(key)
        if sched is None:
            # bounded cache: each scheduler owns pooled device caches
            # (slots x max_seq x layers), so evict oldest beyond a few
            while len(self._schedulers) >= 4:
                self._schedulers.pop(next(iter(self._schedulers)))
            sched = BatchScheduler(
                self.collm, self.params, self.cloud.cm, slots, max_seq,
                mode=mode, sampler=sampler, temperature=temperature,
                top_k=top_k, seed=seed, max_ctx=max_ctx, num_pages=num_pages)
            self._schedulers[key] = sched
        reqs = [Request(device_id=f"edge-{i}", prompt=np.asarray(p),
                        max_new=max_new, eos_id=eos_id)
                for i, p in enumerate(prompts)]
        results, stats = sched.run(reqs)
        return {"tokens": results, "stats": _aggregate(stats),
                "per_client": stats, "cm_stats": self.cloud.cm.stats(),
                "num_slots": slots}

    # ------------------------------------------------------------------
    def generate_sequential(self, prompts: Sequence[np.ndarray], max_new: int,
                            mode: str = "collm",
                            max_seq: Optional[int] = None) -> Dict[str, Any]:
        """The seed's per-client loops (batch=1, one Python iteration per
        token) — reference implementation and throughput baseline."""
        max_seq = max_seq or (max(len(p) for p in prompts) + max_new + 8)
        results, stats = [], []
        for i, prompt in enumerate(prompts):
            toks, st = self._generate_one(f"edge-{i}", np.asarray(prompt),
                                          max_new, mode, max_seq)
            results.append(toks)
            stats.append(st)
        return {"tokens": results, "stats": _aggregate(stats),
                "per_client": stats, "cm_stats": self.cloud.cm.stats()}

    # ------------------------------------------------------------------
    def _generate_one(self, device_id: str, prompt: np.ndarray, max_new: int,
                      mode: str, max_seq: int):
        model, collm, params = self.model, self.collm, self.params
        st = GenStats()
        batch = {"tokens": jnp.asarray(prompt[None, :])}

        if mode == "cloud":
            caches = model.init_cache(1, max_seq)
            t0 = time.perf_counter()
            x, _, caches, _ = model.prefill(params, batch, caches)
            tok = jnp.argmax(model.logits(params, x[:, -1:])[:, 0], -1)
            toks = [int(tok[0])]
            pos = len(prompt)
            for _ in range(max_new - 1):
                tok, _, caches = collm.full_step(
                    params, tok[:, None].astype(jnp.int32), caches,
                    jnp.asarray(pos, jnp.int32))
                toks.append(int(tok[0]))
                pos += 1
            st.cloud_time += time.perf_counter() - t0
            st.tokens = len(toks)
            return toks, st

        client = EdgeClient(collm, params, device_id, 1, max_seq)
        t0 = time.perf_counter()
        decisions, h1_seq = client.prefill(batch)
        st.edge_time += time.perf_counter() - t0

        prefill_logits = None
        if mode == "collm":
            enc = None  # enc-dec handled by uploading enc_out once (DESIGN)
            t0 = time.perf_counter()
            prefill_logits = self.cloud.register(device_id, 1, max_seq,
                                                 h1_prompt=h1_seq, enc_out=enc)
            st.cloud_time += time.perf_counter() - t0
            # prompt upload crosses the wire in the configured format
            st.upload_bytes += _prompt_wire_bytes(
                h1_seq.shape, model.compute_dtype, self.ccfg.wire_format)

        # first token from the prompt's last position
        from repro.core.exits import first_confident_exit
        tok_arr, exited, _ = first_confident_exit(decisions, collm.ccfg.theta)
        if mode == "standalone":
            tok = int(decisions[collm.l_ee2].token[0])
        elif bool(exited[0]) or mode != "collm":
            tok = int(tok_arr[0])
        else:
            # cloud already prefilled through the prompt: its last-position
            # logits ARE the cloud answer for the first token
            st.cloud_requests += 1
            tok = int(jnp.argmax(prefill_logits[0, 0]))
        toks = [tok]
        st.tokens += 1

        for _ in range(max_new - 1):
            t0 = time.perf_counter()
            out = client.step(jnp.asarray([[tok]], jnp.int32))
            st.edge_time += time.perf_counter() - t0
            st.tokens += 1
            confs = {l: float(d.confidence[0])
                     for l, d in out.decisions.items()}
            st.confidences.append((confs.get(collm.l_ee1, 0.0),
                                   confs.get(collm.l_ee2, 0.0)))

            if mode == "standalone":
                tok = int(out.decisions[collm.l_ee2].token[0])
                if confs.get(collm.l_ee1, 0.0) >= collm.ccfg.theta:
                    st.exits_l1 += 1
                else:
                    st.exits_l2 += 1
                toks.append(tok)
                continue

            # parallel upload (always dispatched at l_ee1)
            pkt = StatePacket(hidden=out.upload,
                              pos=jnp.asarray(client.pos - 1))
            self.cloud.receive_upload(device_id, client.pos - 1, pkt)
            st.upload_bytes += pkt.nbytes()

            if bool(out.exited[0]):
                if confs.get(collm.l_ee1, 0.0) >= collm.ccfg.theta:
                    st.exits_l1 += 1
                else:
                    st.exits_l2 += 1
                tok = int(out.token[0])
            else:
                t0 = time.perf_counter()
                logits = self.cloud.infer(device_id, client.pos - 1,
                                          backfill=self.ccfg.backfill)
                st.cloud_time += time.perf_counter() - t0
                st.cloud_requests += 1
                tok = int(jnp.argmax(logits[0]))
            toks.append(tok)

        if mode == "collm":
            self.cloud.finish(device_id)
        return toks, st


def token_agreement(a: Sequence[int], b: Sequence[int]) -> float:
    """Longest-common-subsequence F1 — the ROUGE-L proxy used in
    EXPERIMENTS.md to compare strategies' generations."""
    a, b = list(a), list(b)
    if not a or not b:
        return 0.0
    m, n = len(a), len(b)
    dp = np.zeros((m + 1, n + 1), np.int32)
    for i in range(m):
        for j in range(n):
            dp[i + 1, j + 1] = (dp[i, j] + 1 if a[i] == b[j]
                                else max(dp[i, j + 1], dp[i + 1, j]))
    lcs = dp[m, n]
    prec, rec = lcs / m, lcs / n
    return 0.0 if lcs == 0 else 2 * prec * rec / (prec + rec)
