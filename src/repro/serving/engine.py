"""Host-level multi-client serving engine — the faithful CE-CoLLM system.

Topology (paper fig 2/3): N edge clients, each running the edge LLM
partition with exits at l_ee1/l_ee2; one cloud server running the cloud
partition behind a ContentManager.  Per generated token (Algorithm 1):

  1. edge computes layers 1..l_ee1, evaluates exit 1, and dispatches the
     quantized l_ee1 hidden to the cloud (parallel upload);
  2. if conf1 < θ, edge continues to l_ee2, evaluates exit 2;
  3. if conf2 < θ, the edge requests cloud inference; the cloud pops the
     uploaded state from the content manager and completes layers
     l_ee1+1..L, returning one token (single-token response);
  4. the content manager releases unused uploads (paper) or backfills them
     through the cloud partition (beyond-paper exact-KV mode).

Everything is measured: per-token exit level, cloud request rate, wire
bytes, partition wall-times (feeds the netsim), and agreement vs. the
undivided model (the paper's ROUGE-L proxy).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collm import CoLLM, CollmConfig
from repro.core.content_manager import ContentManager
from repro.core.transport import StatePacket, dequantize, packet_bytes
from repro.models.transformer import Model

Pytree = Any


@dataclasses.dataclass
class GenStats:
    tokens: int = 0
    exits_l1: int = 0
    exits_l2: int = 0
    cloud_requests: int = 0
    upload_bytes: int = 0
    edge_time: float = 0.0
    cloud_time: float = 0.0
    confidences: List[tuple] = dataclasses.field(default_factory=list)

    @property
    def request_rate(self) -> float:
        return self.cloud_requests / max(self.tokens, 1)


class CloudServer:
    """Cloud partition + content manager (one per deployment)."""

    def __init__(self, collm: CoLLM, params: Pytree, max_clients_pending: int = 8):
        self.collm = collm
        self.params = params
        self.cm = ContentManager(max_pending_per_client=max_clients_pending)
        self._cloud_step = jax.jit(collm.cloud_step)

    def register(self, device_id: str, batch: int, max_seq: int,
                 h1_prompt: Optional[jax.Array] = None,
                 enc_out: Optional[jax.Array] = None):
        caches = self.collm.init_cloud_cache(batch, max_seq)
        logits = None
        if h1_prompt is not None:
            logits, caches = self.collm.cloud_prefill(self.params, h1_prompt,
                                                      caches, enc_out=enc_out)
        self.cm.put_cache(device_id, caches)
        return logits

    def receive_upload(self, device_id: str, pos: int,
                       packet: StatePacket) -> None:
        self.cm.upload(device_id, pos, packet)

    def infer(self, device_id: str, pos: int, *, backfill: bool) -> jax.Array:
        """Single-token response (paper §4.2)."""
        caches = self.cm.get_cache(device_id)
        if backfill:
            pending = self.cm.take_uploads_upto(device_id, pos)
        else:
            pkt = self.cm.take_upload(device_id, pos)
            pending = [(pos, pkt)]
        logits = None
        for p, pkt in pending:
            logits, caches = self._cloud_step(
                self.params, pkt.hidden, caches, jnp.asarray(p, jnp.int32))
        self.cm.put_cache(device_id, caches)
        return logits

    def finish(self, device_id: str) -> None:
        self.cm.end_of_sequence(device_id)


class EdgeClient:
    """Edge partition runtime for one device."""

    def __init__(self, collm: CoLLM, params: Pytree, device_id: str,
                 batch: int, max_seq: int):
        self.collm = collm
        self.params = params
        self.device_id = device_id
        self.caches = collm.init_edge_cache(batch, max_seq)
        self._edge_step = jax.jit(collm.edge_step)
        self.pos = 0

    def prefill(self, batch: Dict[str, jax.Array]):
        decisions, h1_seq, self.caches = self.collm.edge_prefill(
            self.params, batch, self.caches)
        self.pos = h1_seq.shape[1]
        return decisions, h1_seq

    def step(self, token: jax.Array):
        out = self._edge_step(self.params, token, self.caches,
                              jnp.asarray(self.pos, jnp.int32))
        self.caches = out.caches
        self.pos += 1
        return out


class ServingSystem:
    """End-to-end multi-client co-inference."""

    def __init__(self, model: Model, params: Pytree,
                 ccfg: CollmConfig = CollmConfig()):
        self.model = model
        self.params = params
        self.ccfg = ccfg
        self.collm = CoLLM(model, ccfg)
        self.cloud = CloudServer(self.collm, params)

    # ------------------------------------------------------------------
    def generate(self, prompts: Sequence[np.ndarray], max_new: int,
                 mode: str = "collm", max_seq: Optional[int] = None
                 ) -> Dict[str, Any]:
        """mode: collm | standalone | cloud.  One client per prompt; each
        client decodes its own stream (paper's per-client loops)."""
        max_seq = max_seq or (max(len(p) for p in prompts) + max_new + 8)
        results, stats = [], []
        for i, prompt in enumerate(prompts):
            toks, st = self._generate_one(f"edge-{i}", np.asarray(prompt),
                                          max_new, mode, max_seq)
            results.append(toks)
            stats.append(st)
        agg = GenStats()
        for st in stats:
            agg.tokens += st.tokens
            agg.exits_l1 += st.exits_l1
            agg.exits_l2 += st.exits_l2
            agg.cloud_requests += st.cloud_requests
            agg.upload_bytes += st.upload_bytes
            agg.edge_time += st.edge_time
            agg.cloud_time += st.cloud_time
            agg.confidences.extend(st.confidences)
        return {"tokens": results, "stats": agg, "per_client": stats,
                "cm_stats": self.cloud.cm.stats()}

    # ------------------------------------------------------------------
    def _generate_one(self, device_id: str, prompt: np.ndarray, max_new: int,
                      mode: str, max_seq: int):
        model, collm, params = self.model, self.collm, self.params
        st = GenStats()
        batch = {"tokens": jnp.asarray(prompt[None, :])}

        if mode == "cloud":
            caches = model.init_cache(1, max_seq)
            t0 = time.perf_counter()
            x, _, caches, _ = model.prefill(params, batch, caches)
            tok = jnp.argmax(model.logits(params, x[:, -1:])[:, 0], -1)
            toks = [int(tok[0])]
            pos = len(prompt)
            for _ in range(max_new - 1):
                tok, _, caches = collm.full_step(
                    params, tok[:, None].astype(jnp.int32), caches,
                    jnp.asarray(pos, jnp.int32))
                toks.append(int(tok[0]))
                pos += 1
            st.cloud_time += time.perf_counter() - t0
            st.tokens = len(toks)
            return toks, st

        client = EdgeClient(collm, params, device_id, 1, max_seq)
        t0 = time.perf_counter()
        decisions, h1_seq = client.prefill(batch)
        st.edge_time += time.perf_counter() - t0

        prefill_logits = None
        if mode == "collm":
            enc = None  # enc-dec handled by uploading enc_out once (DESIGN)
            t0 = time.perf_counter()
            prefill_logits = self.cloud.register(device_id, 1, max_seq,
                                                 h1_prompt=h1_seq, enc_out=enc)
            st.cloud_time += time.perf_counter() - t0
            st.upload_bytes += int(h1_seq.size * 2)   # fp16 prompt upload

        # first token from the prompt's last position
        from repro.core.exits import first_confident_exit
        tok_arr, exited, _ = first_confident_exit(decisions, collm.ccfg.theta)
        if mode == "standalone":
            tok = int(decisions[collm.l_ee2].token[0])
        elif bool(exited[0]) or mode != "collm":
            tok = int(tok_arr[0])
        else:
            # cloud already prefilled through the prompt: its last-position
            # logits ARE the cloud answer for the first token
            st.cloud_requests += 1
            tok = int(jnp.argmax(prefill_logits[0, 0]))
        toks = [tok]
        st.tokens += 1

        for _ in range(max_new - 1):
            t0 = time.perf_counter()
            out = client.step(jnp.asarray([[tok]], jnp.int32))
            st.edge_time += time.perf_counter() - t0
            st.tokens += 1
            confs = {l: float(d.confidence[0])
                     for l, d in out.decisions.items()}
            st.confidences.append((confs.get(collm.l_ee1, 0.0),
                                   confs.get(collm.l_ee2, 0.0)))

            if mode == "standalone":
                tok = int(out.decisions[collm.l_ee2].token[0])
                if confs.get(collm.l_ee1, 0.0) >= collm.ccfg.theta:
                    st.exits_l1 += 1
                else:
                    st.exits_l2 += 1
                toks.append(tok)
                continue

            # parallel upload (always dispatched at l_ee1)
            pkt = StatePacket(hidden=out.upload,
                              pos=jnp.asarray(client.pos - 1))
            self.cloud.receive_upload(device_id, client.pos - 1, pkt)
            st.upload_bytes += pkt.nbytes()

            if bool(out.exited[0]):
                if confs.get(collm.l_ee1, 0.0) >= collm.ccfg.theta:
                    st.exits_l1 += 1
                else:
                    st.exits_l2 += 1
                tok = int(out.token[0])
            else:
                t0 = time.perf_counter()
                logits = self.cloud.infer(device_id, client.pos - 1,
                                          backfill=self.ccfg.backfill)
                st.cloud_time += time.perf_counter() - t0
                st.cloud_requests += 1
                tok = int(jnp.argmax(logits[0]))
            toks.append(tok)

        if mode == "collm":
            self.cloud.finish(device_id)
        return toks, st


def token_agreement(a: Sequence[int], b: Sequence[int]) -> float:
    """Longest-common-subsequence F1 — the ROUGE-L proxy used in
    EXPERIMENTS.md to compare strategies' generations."""
    a, b = list(a), list(b)
    if not a or not b:
        return 0.0
    m, n = len(a), len(b)
    dp = np.zeros((m + 1, n + 1), np.int32)
    for i in range(m):
        for j in range(n):
            dp[i + 1, j + 1] = (dp[i, j] + 1 if a[i] == b[j]
                                else max(dp[i, j + 1], dp[i + 1, j]))
    lcs = dp[m, n]
    prec, rec = lcs / m, lcs / n
    return 0.0 if lcs == 0 else 2 * prec * rec / (prec + rec)
