"""Host-level multi-client serving — the CE-CoLLM system at scale.

Topology (paper fig 2/3): N edge clients, each running the edge LLM
partition with exits at l_ee1/l_ee2; one cloud server running the cloud
partition behind a ContentManager.  Per generated token (Algorithm 1):

  1. edge computes layers 1..l_ee1, evaluates exit 1, and dispatches the
     quantized l_ee1 hidden to the cloud (parallel upload);
  2. if conf1 < θ, edge continues to l_ee2, evaluates exit 2;
  3. if conf2 < θ, the edge requests cloud inference; the cloud pops the
     uploaded state from the content manager and completes layers
     l_ee1+1..L, returning one token (single-token response);
  4. the content manager releases unused uploads (paper) or backfills them
     through the cloud partition (beyond-paper exact-KV mode).

Two execution engines implement that contract:

  * ``BatchScheduler`` (default) — a continuous-batching engine.  A fixed
    pool of B slots, each holding one client's stream, is stepped by a
    single jitted batched edge step with per-row positions and per-row exit
    gating; one masked cloud call serves every below-θ row of a step.
    Finished slots are recycled and refilled from the request queue without
    recompiling (prompt lengths are bucketed; the decode graph is compiled
    once per pool size).  KV lives either in per-slot dense rings
    (``kv_layout="dense"``: memory B x max_seq) or in a block-paged pool
    shared across slots (``kv_layout="paged"``: memory num_pages x
    page_size, per-slot block tables, admission back-pressure when pages
    run out, and per-stream context up to max_ctx > max_seq).  See
    docs/serving.md for the slot lifecycle and docs/kv_paging.md for the
    paged layout.
  * ``ServingSystem.generate_sequential`` — the seed's per-client loop
    (batch=1, one Python iteration per token).  Kept as the reference
    implementation: the batched engine is token-for-token equivalent to it
    under greedy decoding, and the throughput bench measures one against
    the other.

Cloud requests travel through a ``repro.core.transport.CloudChannel`` —
the scheduler is a two-stage pipeline (dispatch this tick's below-θ
requests, keep decoding every unblocked row while they are in flight,
drain replies with a per-row deadline), so cloud latency hides behind
edge compute instead of stalling the pool.  A reply that misses its
deadline commits the row's edge exit token (the paper's latency-aware
early exit), and ``fallback_after`` consecutive misses switch the row to
standalone mode (the paper's unstable-link fallback).  The default
``SyncChannel`` reproduces the blocking engine token-for-token; see
docs/async_transport.md.

Everything is measured: per-token exit level, cloud request rate, wire
bytes, deadline misses, virtual stall/overlap time, partition wall-times
(feeds the netsim), and agreement vs. the undivided model (the paper's
ROUGE-L proxy).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collm import CoLLM, CollmConfig
from repro.core.content_manager import ContentManager
from repro.core.exits import select_exit_logits
from repro.core.paging import (PREEMPT_POLICIES, OutOfPages, PagePool,
                               SwapPool, VictimCandidate, pages_needed,
                               select_victim)
from repro.core.transport import (TOKEN_BYTES, ChannelStats, CloudChannel,
                                  StatePacket, SyncChannel,
                                  draft_request_bytes, hidden_wire_bytes)
from repro.models.transformer import Model
from repro.serving import sampler as samplerlib
from repro.serving.adaptive import (AdaptiveConfig, AdaptiveController,
                                    ResumeCostModel)
from repro.serving.cloud_batcher import (COPY_PAGES, RESET_PAGES, SCATTER,
                                         SCATTER_PAGED, WRITE_PAGES,
                                         CloudBatcher, _bucket, _jit,
                                         all_paged, build_upload_ring,
                                         gather_slot_pages,
                                         rebind_slot_pages)
from repro.serving.mesh_exec import mesh_context

Pytree = Any


@dataclasses.dataclass
class GenStats:
    tokens: int = 0
    exits_l1: int = 0
    exits_l2: int = 0
    cloud_requests: int = 0       # tokens actually served by a cloud reply
    deadline_misses: int = 0      # replies that missed their deadline
    spec_rewinds: int = 0         # speculative reconciles that disagreed
    fallbacks: int = 0            # switches to standalone fallback
    preemptions: int = 0          # times this stream was checkpointed out
    # multi-token drafting (CollmConfig.spec_k): provisional tokens shipped
    # in verification requests, and how many of them the cloud validated.
    # Both are event counters like deadline_misses — a rewind never unwinds
    # them — so accepted_tokens / draft_tokens is the draft acceptance rate.
    draft_tokens: int = 0         # draft tokens dispatched for verification
    accepted_tokens: int = 0      # draft tokens the cloud reply validated
    # prefix sharing / chunked prefill (CollmConfig.prefix_share /
    # .chunked_prefill): prompt tokens served from shared pages instead of
    # prefill compute, copy-on-write page splits this stream triggered,
    # and page-sized prefill chunk ticks it took to admit
    prefix_hit_tokens: int = 0
    cow_copies: int = 0
    prefill_chunks: int = 0
    upload_bytes: int = 0
    edge_time: float = 0.0
    cloud_time: float = 0.0
    stall_s: float = 0.0          # virtual time stalled on in-flight replies
    overlap_s: float = 0.0        # virtual flight time hidden behind decode
    confidences: List[tuple] = dataclasses.field(default_factory=list)
    # accepted-prefix length of each verified draft reply (0..k); the
    # accept-length histogram of the bench / property tests
    accept_lens: List[int] = dataclasses.field(default_factory=list)
    # fleet replay metrics (docs/fleet_sim.md): per retired stream, the
    # virtual time from its open-loop arrival to its first token, and the
    # virtual gap between consecutive committed tokens (the per-token
    # latency whose p50/p99 the fleet bench gates).  ``slo_total`` counts
    # streams that carried an SLO; ``slo_met`` the ones that met it.
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    token_lat_s: List[float] = dataclasses.field(default_factory=list)
    slo_total: int = 0
    slo_met: int = 0

    @property
    def request_rate(self) -> float:
        """Fraction of emitted tokens served by the cloud.  A
        deadline-missed request commits the edge token, so it counts under
        ``deadline_misses`` (and ``exits_l2``), never as a cloud request;
        zero-token streams have rate 0, not ``cloud_requests / 1``."""
        if self.tokens <= 0:
            return 0.0
        return self.cloud_requests / self.tokens

    def ttft_p(self, q: float) -> float:
        """Time-to-first-token percentile (virtual s), 0 when unmeasured."""
        return float(np.percentile(self.ttft_s, q)) if self.ttft_s else 0.0

    def token_lat_p(self, q: float) -> float:
        """Inter-token latency percentile (virtual s), 0 when unmeasured."""
        return (float(np.percentile(self.token_lat_s, q))
                if self.token_lat_s else 0.0)

    @property
    def slo_attainment(self) -> float:
        """Fraction of SLO-carrying streams that met every armed target
        (vacuously 1.0 when no stream carried an SLO)."""
        return self.slo_met / self.slo_total if self.slo_total else 1.0

    @property
    def preemption_rate(self) -> float:
        return self.preemptions / self.tokens if self.tokens else 0.0

    @property
    def deadline_miss_rate(self) -> float:
        return self.deadline_misses / self.tokens if self.tokens else 0.0


def _aggregate(stats: Sequence[Optional[GenStats]]) -> GenStats:
    """Field-generic aggregation (scalars sum, lists concatenate) — new
    counters can never be silently dropped, and ``None`` entries
    (unserved requests) don't crash zero-token aggregations."""
    agg = GenStats()
    for st in stats:
        if st is None:
            continue
        for f in dataclasses.fields(GenStats):
            v = getattr(st, f.name)
            if isinstance(v, list):
                getattr(agg, f.name).extend(v)
            else:
                setattr(agg, f.name, getattr(agg, f.name) + v)
    return agg


class CloudServer:
    """Cloud partition + content manager (one per deployment).

    Inference speaks the ``CloudChannel`` protocol: ``request`` pops the
    uploaded state(s), dispatches the cloud partition step, and submits
    the still-on-device logits into the caller's channel — the same
    cloud-request path the batched engine uses.  jit dispatch is
    asynchronous, so the edge loop keeps running until it drains the
    reply."""

    def __init__(self, collm: CoLLM, params: Pytree, max_clients_pending: int = 8):
        self.collm = collm
        self.params = params
        self.cm = ContentManager(max_pending_per_client=max_clients_pending)
        self._cloud_step = jax.jit(collm.cloud_step)

    def register(self, device_id: str, batch: int, max_seq: int,
                 h1_prompt: Optional[jax.Array] = None,
                 enc_out: Optional[jax.Array] = None):
        caches = self.collm.init_cloud_cache(batch, max_seq)
        logits = None
        if h1_prompt is not None:
            logits, caches = self.collm.cloud_prefill(self.params, h1_prompt,
                                                      caches, enc_out=enc_out)
        self.cm.put_cache(device_id, caches)
        return logits

    def receive_upload(self, device_id: str, pos: int,
                       packet: StatePacket) -> None:
        self.cm.upload(device_id, pos, packet)

    def request(self, channel: CloudChannel, device_id: str, pos: int, *,
                now: float = 0.0, backfill: bool = False, slot: int = 0,
                seq: int = 0) -> int:
        """Dispatch one single-token cloud inference (paper §4.2) into
        ``channel``; returns the in-flight handle.  The reply payload is
        the cloud logits, still on device.

        Wire accounting: the hidden-state packets this request consumes
        (one, or the whole pending ring under ``backfill``) already
        crossed the wire when they were uploaded — they are billed once,
        at upload time, via ``channel.notify_upload``.  The request itself
        is a token-sized control message (``nbytes_up=TOKEN_BYTES``)
        whether it consumes one upload or a backfill ring of ten; billing
        the consumed uploads here again would double-count them (and
        billing them *only* here would skip the ones ``backfill`` drains).
        ``tests/test_cloud_batcher.py`` asserts this parity with netsim."""
        caches = self.cm.get_cache(device_id)
        if backfill:
            pending = self.cm.take_uploads_upto(device_id, pos)
        else:
            pending = [(pos, self.cm.take_upload(device_id, pos))]
        logits = None
        for p, pkt in pending:
            logits, caches = self._cloud_step(
                self.params, pkt.hidden, caches, jnp.asarray(p, jnp.int32))
        self.cm.put_cache(device_id, caches)
        return channel.submit(slot=slot, seq=seq, pos=pos, reply=logits,
                              now=now, nbytes_up=TOKEN_BYTES,
                              nbytes_down=TOKEN_BYTES)

    def finish(self, device_id: str) -> None:
        self.cm.end_of_sequence(device_id)


class EdgeClient:
    """Edge partition runtime for one device."""

    def __init__(self, collm: CoLLM, params: Pytree, device_id: str,
                 batch: int, max_seq: int):
        self.collm = collm
        self.params = params
        self.device_id = device_id
        self.caches = collm.init_edge_cache(batch, max_seq)
        self._edge_step = jax.jit(collm.edge_step)
        self.pos = 0

    def prefill(self, batch: Dict[str, jax.Array]):
        decisions, h1_seq, self.caches = self.collm.edge_prefill(
            self.params, batch, self.caches)
        self.pos = h1_seq.shape[1]
        return decisions, h1_seq

    def step(self, token: jax.Array):
        out = self._edge_step(self.params, token, self.caches,
                              jnp.asarray(self.pos, jnp.int32))
        self.caches = out.caches
        self.pos += 1
        return out


# ---------------------------------------------------------------------------
# continuous-batching engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    """One client stream queued for the scheduler.

    ``arrival_t`` is the stream's open-loop virtual arrival time: the
    scheduler never admits it earlier (closed-loop replay leaves it 0).
    ``slo_ttft_s`` / ``slo_tpot_s`` arm per-stream service objectives —
    time-to-first-token and mean time-per-output-token budgets checked at
    retirement (``GenStats.slo_attainment``)."""
    device_id: str
    prompt: np.ndarray
    max_new: int
    eos_id: Optional[int] = None
    index: int = 0                   # submission order (result slot)
    arrival_t: float = 0.0
    slo_ttft_s: Optional[float] = None
    slo_tpot_s: Optional[float] = None


@dataclasses.dataclass
class _DraftTok:
    """One provisional token of a slot's edge draft (speculative path).

    The upload packet is popped from the ContentManager at draft time —
    the window eviction must never release a position still awaiting
    verification — and held here until the draft flushes into one
    verification request.  ``ring_idx`` is the entry's index in that
    request's upload ring (set at flush; the reply's per-position logits
    are indexed with it)."""
    pos: int
    tok_index: int           # index in slot.tokens of the provisional token
    provisional: int
    pkt: Any                 # the popped StatePacket
    ring_idx: int = 0


@dataclasses.dataclass
class _Pending:
    """One in-flight cloud request of a slot.

    Speculative mode ships k-token drafts: ``draft`` lists the request's
    provisional tokens in position order, ``tok_index``/``provisional``
    mirror the FIRST entry (preemption cuts at the earliest unvalidated
    token) and ``pos`` the LAST entry (a rewind's "drop requests past the
    cut" test sees the whole group).  Non-speculative requests leave
    ``draft`` as None."""
    pos: int                 # decode position the request serves
    tok_index: int           # index in slot.tokens its token lands at
    provisional: int         # edge l_ee2 token committed on deadline miss
    stall_from: float        # virtual submit time
    deadline_t: float
    idle_at: float = 0.0     # engine idle integral at submit (overlap_s)
    draft: Optional[List[_DraftTok]] = None


@dataclasses.dataclass
class _Slot:
    """One row of the batched pool.  Lifecycle:
    FREE -> (admit: prefill + scatter row caches) ACTIVE
         -> (decode ticks) ... -> (EOS / max_new) FINISHED -> FREE.

    ``seq`` is the slot *generation*: it increments at every admission, so
    a cloud reply issued by a retired stream can never be applied to the
    slot's successor.  ``pending`` tracks in-flight cloud requests
    (at most one without speculation — the row stalls; any number with
    ``CollmConfig.speculative`` — the row keeps decoding on provisional
    tokens).  ``events`` records each emitted token's origin
    ("admit"/"l1"/"l2"/"cloud"/"spec"/"full") so a speculative rewind can
    unwind the per-token counters exactly."""
    index: int
    req: Optional[Request] = None
    stats: Optional[GenStats] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    # virtual commit time of each entry of ``tokens`` (kept in lockstep
    # through rewinds/preemption): the raw material of the per-token
    # latency and TTFT metrics finalized at retirement
    emit_ts: List[float] = dataclasses.field(default_factory=list)
    pos: int = 0
    last_token: int = 0
    active: bool = False
    seq: int = 0
    pending: Dict[int, _Pending] = dataclasses.field(default_factory=dict)
    events: List[str] = dataclasses.field(default_factory=list)
    miss_streak: int = 0
    standalone: bool = False     # latency fallback engaged (stops uploading)
    admit_seq: int = 0           # global admission order (victim policies)
    # buffered (not yet dispatched) draft tokens of the speculative path:
    # up to CollmConfig.spec_k below-θ provisional tokens accumulate here,
    # then flush as ONE verification request (_flush_drafts)
    draft: List[_DraftTok] = dataclasses.field(default_factory=list)
    # uploads the cloud actually consumed for this stream, in consumption
    # order — a preemption checkpoint replays them to rebuild the cloud KV
    # (gaps included) without recomputing the hidden states.  Tracked only
    # when preemption is enabled.
    cloud_pkts: List[tuple] = dataclasses.field(default_factory=list)
    # chunked-prefill state machine (CollmConfig.chunked_prefill): while
    # ``prefill_prompt`` is set the slot is mid-prefill — each tick computes
    # ONE page-sized chunk starting at ``prefill_pos``; the remaining
    # prompt (``prefill_remaining = len(prefill_prompt) - prefill_pos``)
    # shrinks by page_size per tick.  ``prefill_wait`` /
    # ``prefill_wait_cloud`` list shared page ids (engine pool / batcher
    # pool) still being computed by their owning stream: the sharer stalls
    # until they are marked filled, then computes only its suffix.
    prefill_prompt: Optional[np.ndarray] = None
    prefill_pos: int = 0
    prefill_wait: List[int] = dataclasses.field(default_factory=list)
    prefill_wait_cloud: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Checkpoint:
    """A preempted stream, frozen between its slot generations.

    Everything needed to resume is host-side: the emitted tokens (the
    resume point is ``len(prompt) + len(tokens) - 1`` — the last emitted
    token is re-fed, so an interrupted in-flight edge pass is simply
    re-run), the per-stream stats/events, the ContentManager uploads that
    were still pending, and the cloud-consumed upload packets whose replay
    reconstructs the cloud KV exactly (release-semantics gaps included).
    ``swap_key`` points into the scheduler's ``SwapPool`` when the device
    pages were swapped out instead of dropped."""
    req: Request
    stats: GenStats
    tokens: List[int]
    emit_ts: List[float]
    events: List[str]
    cloud_pkts: List[tuple]               # [(pos, StatePacket)] pos < resume
    uploads: List[tuple]                  # pending CM uploads, pos < resume
    standalone: bool
    miss_streak: int
    swap_key: Optional[int] = None        # SwapPool key (swap mode)
    swap_pages: int = 0                   # pages the snapshot restores
    batcher_swap: Optional[dict] = None   # CloudBatcher.swap_out snapshot


class BatchScheduler:
    """Continuous-batching multi-slot decode engine.

    Replaces the seed's per-client Python loops: B client streams advance
    together under one jitted edge step with per-row positions; exits are
    gated per row; one masked cloud call serves all below-θ rows of a tick;
    finished slots are refilled from the queue without recompiling.

    With ``CollmConfig.kv_layout="paged"`` the scheduler also owns a
    ``PagePool``.  Admission is no longer all-or-nothing monolithic
    prefill by construction: the default path still prefills the whole
    prompt in one padded call and scatters its K/V into freshly allocated
    pages, but with ``CollmConfig.chunked_prefill`` the prompt is
    prefilled ONE page-sized chunk per tick through the paged decode
    write path, interleaved with the other slots' decode (per-slot
    ``prefill_remaining`` state machine; see docs/serving.md).  With
    ``CollmConfig.prefix_share`` on top, admission first consults the
    pool's radix prefix index: prompt pages another live stream (or the
    cache) already holds are mapped by reference (suffix-only prefill,
    deduped uploads), whole-prompt *terminal* hits skip prefill entirely,
    and the first divergent write into a shared page splits it
    copy-on-write (docs/kv_paging.md §Prefix sharing).  Each decode tick
    allocates a page only when a row crosses a page boundary, and
    retirement bulk-frees the slot's unshared pages and invalidates them
    on device.  Admission follows
    ``CollmConfig.preemption``: ``"off"`` keeps the conservative
    worst-case check (an admitted stream can always finish), while
    ``"recompute"``/``"swap"`` admit optimistically on the prompt's pages
    alone and answer a decode-time ``OutOfPages`` by preempting a victim
    stream — checkpoint, free its pages, resume later by re-prefill or a
    host-side page swap (docs/kv_paging.md §Preemption).  Preemption is
    invisible in output space: greedy streams are token-identical to an
    un-preempted run.  The block table is shared by the edge/cloud/full
    cache pools (same token positions) and is passed into every jitted
    step.

    Cloud requests travel through ``channel`` (a
    ``transport.CloudChannel``) and each tick is a two-stage pipeline:

      1. **edge pass** over every runnable row (rows stalled on an
         in-flight reply flow through as placeholders whose outputs and —
         for recurrent models — cache writes are discarded);
      2. **dispatch** of this tick's below-θ rows: one masked cloud call
         computes them all, the still-on-device logits enter the channel
         per row, and the engine keeps decoding while they are in flight
         (virtual time from the channel's latency model; wall-clock
         overlap from jax async dispatch, materialization deferred to the
         drain).

    Replies drain against a per-row deadline: a miss commits the row's
    edge l_ee2 token (the paper's latency-aware early exit), and
    ``fallback_after`` consecutive misses flip the row to standalone mode.
    With ``CollmConfig.speculative`` a below-θ row does not stall at all —
    it commits the provisional edge token, keeps decoding, and
    reconciles on arrival (keep on match, rewind-and-replace on
    mismatch).  ``overlap=False`` degrades stage 2 to a blocking drain
    (the whole pool waits) — the baseline the throughput bench compares
    against.  The default ``SyncChannel`` (zero latency) reproduces the
    blocking engine token-for-token.
    """

    def __init__(self, collm: CoLLM, params: Pytree, cm: ContentManager,
                 num_slots: int, max_seq: int, mode: str = "collm",
                 sampler: str = "greedy", temperature: float = 1.0,
                 top_k: int = 0, seed: int = 0,
                 max_ctx: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 channel: Optional[CloudChannel] = None,
                 tick_time_s: float = 0.0, overlap: bool = True,
                 fallback_after: int = 0,
                 cloud_batcher: Optional[CloudBatcher] = None,
                 watermark: int = 0,
                 preempt_schedule: Optional[Sequence] = None,
                 adaptive: Optional[AdaptiveConfig] = None,
                 resume_cost: Optional[ResumeCostModel] = None):
        if mode not in ("collm", "standalone", "cloud"):
            raise ValueError(mode)
        # cloud compute delegated to a shared CloudBatcher (multi-engine
        # mode): this engine keeps NO cloud caches of its own — below-θ
        # rows are submitted to the batcher, which coalesces them with
        # other engines' requests into one masked cloud step
        self._batcher = cloud_batcher if mode == "collm" else None
        self.collm = collm
        self.model = collm.model
        self.ccfg = collm.ccfg
        # cloud_mesh placement (docs/sharding.md): identity without a mesh
        self._mesh = mesh_context(collm)
        self.params = self._mesh.shard_params(params)
        self.cm = cm
        self.B = num_slots
        self.max_seq = max_seq
        self.mode = mode
        self.sampler = sampler
        self.temperature = temperature
        self.top_k = top_k
        self._rng = jax.random.PRNGKey(seed)
        self.slots = [_Slot(index=i) for i in range(num_slots)]

        # async cloud channel + virtual clock (docs/async_transport.md)
        self.channel = channel if channel is not None else SyncChannel()
        self.tick_time_s = float(tick_time_s)
        self.overlap = bool(overlap)
        self.fallback_after = int(fallback_after)
        self.vnow = 0.0
        self.last_virtual_time = 0.0
        self.late_drops = 0          # replies dropped after slot moved on
        self._idle_s = 0.0           # virtual time nobody decoded (waits)
        self._spec = bool(self.ccfg.speculative) and mode == "collm"
        # draft length of the speculative path: below-θ rows accumulate up
        # to spec_k provisional tokens into one verification request
        self._spec_k = int(self.ccfg.spec_k) if self._spec else 1
        if self._spec and sampler != "greedy":
            raise ValueError("speculative decode reconciles token ids and "
                             "requires greedy sampling")
        if self._spec and not self.model.attention_only():
            raise ValueError("speculative decode rewinds positions; "
                             "recurrent state cannot rewind")
        # chunked prefill + radix prefix sharing (docs/serving.md,
        # docs/kv_paging.md §Prefix sharing): admission maps shared-prefix
        # pages and prefills the suffix one page-sized chunk per tick
        self._chunked = bool(self.ccfg.chunked_prefill)
        self._prefix_share = bool(self.ccfg.prefix_share)
        if self._chunked:
            if mode == "cloud":
                raise ValueError(
                    "chunked_prefill is implemented for the edge-resident "
                    'modes ("collm"/"standalone"), not mode="cloud"')
            if not self.model.attention_only():
                raise ValueError(
                    "chunked_prefill writes chunks through the paged decode "
                    "path and requires an attention-only model (recurrent "
                    "state cannot resume mid-prompt)")
        if self._prefix_share and sampler != "greedy":
            raise ValueError(
                "prefix_share memoizes greedy first tokens (terminal hits) "
                "and requires greedy sampling")
        # recurrent state cannot absorb the placeholder steps stalled rows
        # take through the batched graph -> masked edge step merges them out.
        # Chunked mode also masks: a mid-prefill row's placeholder write
        # would otherwise land in its (possibly shared) page-0 prefix.
        self._mask_edge = ((mode == "collm"
                            and not self.model.attention_only())
                           or self._chunked)

        # KV layout.  dense: every slot owns a max_seq ring (pool memory
        # B x max_seq; a slot can never hold more than max_seq).  paged:
        # slots share num_pages x page_size tokens of K/V through per-slot
        # block tables — one stream may grow to max_ctx (> max_seq) as long
        # as pages are free, and admission back-pressures on the pool
        # instead of failing (docs/kv_paging.md).
        self.layout = self.ccfg.kv_layout
        if self.layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout {self.layout!r}")
        self.pool: Optional[PagePool] = None
        self._tbl_device: Optional[jax.Array] = None   # cached device table
        if self.layout == "paged":
            ps = self.ccfg.page_size
            self.max_ctx = max_ctx or max_seq
            n_pages = num_pages or num_slots * pages_needed(max_seq, ps)
            self.pool = PagePool(n_pages, ps, num_slots,
                                 pages_needed(self.max_ctx, ps),
                                 watermark=watermark,
                                 prefix_cache=self._prefix_share)
            row_seq = _bucket(self.max_ctx)
        else:
            self.max_ctx = max_seq
            row_seq = max_seq
        self._row_seq = row_seq        # single-row prefill cache capacity

        # preemption (docs/kv_paging.md §Preemption): admission is
        # optimistic — a decode-time OutOfPages checkpoints a victim
        # stream and resumes it later by re-prefill ("recompute") or a
        # host-side page round-trip ("swap").  "off" restores the old
        # conservative worst-case admission check.
        self.preemption = self.ccfg.preemption
        if self.preemption not in ("off", "recompute", "swap"):
            raise ValueError(f"preemption {self.preemption!r}")
        self.preempt_policy = self.ccfg.preempt_policy
        if self.preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"preempt_policy {self.preempt_policy!r} "
                             f"(choose from {PREEMPT_POLICIES})")
        if self.preemption != "off" and sampler != "greedy":
            raise ValueError(
                "preemption requires greedy sampling: per-stream sampler "
                "state cannot be checkpointed out of the shared rng")
        if self.preemption == "swap" and self.layout != "paged":
            raise ValueError('preemption="swap" swaps KV pages and needs '
                             'kv_layout="paged" (use "recompute" on dense)')
        self._preempted: "collections.deque[_Checkpoint]" = collections.deque()
        self.swap = SwapPool() if self.preemption == "swap" else None
        self._swap_key = 0
        self._admit_counter = 0
        self._tick_no = 0
        self.preemptions = 0          # scheduler-lifetime preempt events
        self.oops = 0                 # scheduler-lifetime OutOfPages events
        self._arrival_hint: Optional[float] = None   # next queued arrival
        # resume pricing + adaptive control (docs/fleet_sim.md): the cost
        # model is physics shared by every configuration; the controller
        # is the optional loop that tunes watermark / admission / resume
        # mode against it
        self._resume_cost = resume_cost
        self._adaptive: Optional[AdaptiveController] = None
        self._kv_tok_bytes: Optional[float] = None
        if adaptive is not None:
            if self.pool is None:
                raise ValueError("adaptive control tunes the paged pool's "
                                 "watermark and admission; needs "
                                 'kv_layout="paged"')
            self._adaptive = AdaptiveController(adaptive)
            self._adaptive.attach(self.pool, resume_cost)
        self._preempt_schedule: Dict[int, List[int]] = {}
        if preempt_schedule:
            if self.preemption == "off":
                raise ValueError("preempt_schedule needs preemption enabled")
            for t, idx in preempt_schedule:
                self._preempt_schedule.setdefault(int(t), []).append(int(idx))

        # pooled caches (compiled once per pool size; refills only scatter)
        if mode == "cloud":
            self.main_caches = self._mesh.shard_caches(
                self._init_pool_cache(
                    self.model.init_cache,
                    lambda b, n, ps: self.model.init_paged_cache(
                        b, n, ps, kv_dtype=self.ccfg.kv_dtype)),
                batch=num_slots)
            self._full_row0 = self.model.init_cache(1, row_seq)
        else:
            self.edge_caches = self._init_pool_cache(
                collm.init_edge_cache, collm.init_edge_cache_paged)
            self._edge_row0 = collm.init_edge_cache(1, row_seq)
            if mode == "collm" and self._batcher is None:
                # the cloud half of this engine's caches lives on the
                # cloud mesh (identity when cloud_mesh is unset)
                self.cloud_caches = self._mesh.shard_caches(
                    self._init_pool_cache(
                        collm.init_cloud_cache, collm.init_cloud_cache_paged),
                    batch=num_slots)
                self._cloud_row0 = collm.init_cloud_cache(1, row_seq)

        self._write_pages = WRITE_PAGES
        self._edge_step = _jit(collm, "edge_step")
        self._edge_masked = _jit(collm, "edge_step_masked")
        self._full_step = _jit(collm, "full_step")
        self._cloud_masked = _jit(collm, "cloud_step_masked")
        self._invalidate_rows = _jit(collm, "invalidate_rows_after")
        self._ring_cloud = _jit(collm, "ring_cloud_steps")
        self._ring_cloud_all = _jit(collm, "ring_cloud_steps_all")
        self._scatter = SCATTER
        self._scatter_paged = SCATTER_PAGED
        self._reset_pages = RESET_PAGES
        self._edge_prefill = _jit(collm, "edge_prefill_padded")
        self._cloud_prefill = _jit(collm, "cloud_prefill_padded")
        self._full_prefill = _jit(collm, "full_prefill_padded")
        self._edge_chunk = _jit(collm, "edge_prefill_chunk")
        self._cloud_chunk = _jit(collm, "cloud_prefill_chunk")
        self._copy_pages = COPY_PAGES
        # recurrent segments can't absorb right-padding (their state would
        # advance through pad tokens) -> exact-length prefill for them
        self._pad_ok = self.model.attention_only()

        if self.preemption == "swap":
            # a page-only snapshot would silently lose dense cache leaves
            # (recurrent state, cross-attention) — gate swap to trees where
            # everything lives in pages; recompute covers the rest
            trees = [getattr(self, n) for n in
                     ("main_caches", "edge_caches", "cloud_caches")
                     if getattr(self, n, None) is not None]
            if self._batcher is not None:
                trees.append(self._batcher.caches)
            if not all(all_paged(t) for t in trees):
                raise ValueError(
                    'preemption="swap" requires every cache node to be '
                    'paged (attention-only models); use "recompute"')

    def _init_pool_cache(self, dense_init, paged_init):
        if self.layout == "paged":
            return paged_init(self.B, self.pool.num_pages,
                              self.pool.page_size)
        return dense_init(self.B, self.max_seq)

    def kv_cache_bytes(self) -> int:
        """Device bytes held by the pooled KV/state caches (the number the
        paged layout shrinks: num_pages x page_size instead of B x max_seq)."""
        total = 0
        for name in ("main_caches", "edge_caches", "cloud_caches"):
            c = getattr(self, name, None)
            if c is not None:
                total += sum(l.size * l.dtype.itemsize
                             for l in jax.tree.leaves(c))
        return total

    def _block_tbl(self) -> Optional[jax.Array]:
        """Device copy of the pool's block table, re-uploaded only after an
        alloc/free actually changed it (most ticks change nothing)."""
        if self.pool is None:
            return None
        if self._tbl_device is None:
            self._tbl_device = jnp.asarray(self.pool.block_table)
        return self._tbl_device

    # -- sampling -----------------------------------------------------------
    def _pick(self, logits: np.ndarray) -> np.ndarray:
        """logits (B, V) -> tokens (B,) under the configured sampler."""
        if self.sampler == "greedy":
            return np.argmax(logits, axis=-1).astype(np.int32)
        self._rng, sub = jax.random.split(self._rng)
        return np.asarray(samplerlib.sample(
            jnp.asarray(logits), method=self.sampler, rng=sub,
            temperature=self.temperature, top_k=self.top_k))

    # -- admission ----------------------------------------------------------
    def _outstanding_pages(self) -> int:
        """Worst-case pages still owed to the active streams — the
        never-preempt (``preemption="off"``) admission check re-derives the
        old reservation-ledger number from slot state so an admitted
        stream can always finish."""
        out = 0
        for s in self.slots:
            if not s.active or s.req is None:
                continue
            worst = pages_needed(len(s.req.prompt) + s.req.max_new,
                                 self.pool.page_size)
            out += max(0, worst - self.pool.owned_pages(s.index))
            if self.pool.prefix_cache:
                # a stream's first decode write may hit a still-shared tail
                # page: the copy-on-write split consumes one extra free page
                # beyond ``worst`` (owned count is unchanged by a CoW)
                lp_tail = len(s.req.prompt) // self.pool.page_size
                pg = self.pool.block_table[s.index, lp_tail]
                if pg >= 0 and self.pool.is_shared(int(pg)):
                    out += 1
        return out

    def _fits_now(self, need_pages: int) -> bool:
        """Optimistic admission: do ``need_pages`` fit the free list right
        now?  The watermark holds back decode headroom — except when
        nothing is running, where it would wedge the pool instead of
        protecting it (last-resort progress guarantee)."""
        free = self.pool.available_pages
        if not any(s.active for s in self.slots):
            # reclaimable = prefix-cache pages nobody maps: evictable on
            # demand, so an idle pool full of cached prefixes never wedges
            free = self.pool.free_pages + self.pool.reclaimable_pages
        return need_pages <= free

    def _admissible(self, req: Request, p_len: int, pad: int,
                    hit_pages: int = 0, batcher_hit: int = 0) -> bool:
        """Capacity check.  Impossible requests raise; a request the paged
        pool could serve but not *right now* stays queued (back-pressure).
        With preemption enabled the check is optimistic — only the
        *prompt's* pages must fit (decode pages come from alloc-on-write,
        backstopped by preemption); with ``preemption="off"`` it stays the
        conservative worst case, so a decode alloc can never fail.
        ``hit_pages`` prompt pages come from the radix prefix cache
        (shared mappings, not fresh allocations) and are discounted;
        prefix-cached pages on the free side are counted reclaimable —
        ``PagePool.can_admit`` is the same arithmetic pool-side."""
        if p_len + req.max_new > self.max_ctx or pad > self._row_seq:
            raise ValueError(
                f"request {req.device_id}: prompt {p_len} + max_new "
                f"{req.max_new} exceeds max context {self.max_ctx}")
        if self._batcher is not None \
                and not self._batcher.can_admit(p_len + req.max_new,
                                                hit_pages=batcher_hit):
            return False        # shared cloud pool full: wait for a release
        if self.pool is None:
            return True
        need_worst = pages_needed(p_len + req.max_new, self.pool.page_size)
        if self._prefix_share and p_len % self.pool.page_size:
            need_worst += 1     # CoW split of the shared/cached tail page
        if need_worst > self.pool.num_pages:
            raise ValueError(
                f"request {req.device_id}: needs {need_worst} pages but the "
                f"pool only has {self.pool.num_pages}")
        if self.preemption == "off":
            return need_worst - hit_pages <= (
                self.pool.free_pages + self.pool.reclaimable_pages
                - self._outstanding_pages())
        need_now = max(0, pages_needed(p_len, self.pool.page_size)
                       - hit_pages)
        if not self._fits_now(need_now):
            return False
        if self._adaptive is not None and any(s.active for s in self.slots):
            # fluid-ODE admission gate (docs/fleet_sim.md): hold the
            # request while its worst-case residency would overcommit the
            # capacity curve.  Skipped when nothing runs — the gate
            # protects running streams from churn, never wedges an idle
            # engine (mirrors the _fits_now last-resort rule).
            resident = (self.pool.num_pages - self.pool.free_pages
                        - self.pool.reclaimable_pages) * self.pool.page_size
            n_active = sum(1 for s in self.slots if s.active)
            if not self._adaptive.admit_ok(resident, n_active,
                                           p_len + req.max_new):
                return False
        return True

    def _next_admit_seq(self) -> int:
        self._admit_counter += 1
        return self._admit_counter

    def _reset_freed(self, freed: List[int]) -> None:
        """Invalidate freed physical pages (pos = -1) on every cache tree
        this engine holds, so reallocation can never leak their K/V."""
        if not freed:
            return
        ids = np.full((max(self.pool.max_logical, len(freed)),), -1,
                      np.int32)
        ids[:len(freed)] = freed
        ids = jnp.asarray(ids)
        for name in ("main_caches", "edge_caches", "cloud_caches"):
            c = getattr(self, name, None)
            if c is not None:
                setattr(self, name, self._reset_pages(c, ids))

    def _alloc_page(self, idx: int, lp: int) -> None:
        """``pool.alloc`` with prefix-cache reclaim: when the free list
        alone cannot serve, evict LRU radix-cache pages nobody maps (and
        invalidate them on device) before giving up.  Raises ``OutOfPages``
        only when free + reclaimable are both exhausted."""
        try:
            self.pool.alloc(idx, lp)
        except OutOfPages:
            freed = self.pool.evict_prefix(1)
            if not freed:
                raise
            self._reset_freed(freed)
            self.pool.alloc(idx, lp)
        self._tbl_device = None

    def _admit_pages(self, slot: _Slot, p_len: int, pad: int) -> np.ndarray:
        """Allocate the prompt's pages now (later pages are alloc-on-write)
        and return the scatter table (one physical id per logical bucket
        page; -1 = trash for bucket padding past the prompt)."""
        pool = self.pool
        n_prompt = pages_needed(p_len, pool.page_size)
        for lp in range(n_prompt):
            self._alloc_page(slot.index, lp)
        pages = np.full((pages_needed(pad, pool.page_size),), -1, np.int32)
        pages[:n_prompt] = pool.block_table[slot.index, :n_prompt]
        self._tbl_device = None
        return pages

    def _scatter_admit(self, full: Pytree, row: Pytree, slot: _Slot,
                       pages: Optional[np.ndarray]) -> Pytree:
        if pages is None:
            return self._scatter(full, row, slot.index)
        return self._scatter_paged(full, row, slot.index, jnp.asarray(pages))

    def _admit(self, queue) -> bool:
        # preempted streams resume first (they hold finished work and the
        # head-of-line must not starve behind fresh admissions); while any
        # still waits for pages, new requests stay queued
        admitted = self._resume_preempted()
        if self._preempted:
            return admitted
        for slot in self.slots:
            if slot.active or slot.req is not None or not queue:
                # a finished-but-uncollected slot keeps its req until
                # _collect copies the results out — never reuse it here
                continue
            req: Request = queue[0]
            if req.arrival_t > self.vnow:
                # open-loop replay: the head request hasn't arrived yet,
                # and the queue is arrival-sorted so nothing behind it is
                # due either — the run loop jumps the clock when idle
                break
            prompt = np.asarray(req.prompt, np.int32)
            p_len = len(prompt)
            pad = _bucket(p_len) if self._pad_ok else p_len
            # radix prefix hit: full prompt pages already resident in the
            # pool(s).  A *terminal* hit (whole prompt, memoized first
            # token) skips prefill compute entirely; otherwise the hit is
            # capped at (p_len-1)//ps full pages so the final chunk always
            # recomputes into a private page (suffix-only prefill starts
            # at the hit point).  With a shared CloudBatcher the usable
            # hit is the MIN of both pools' hits — edge and cloud pages
            # must cover the same positions.
            hit, hit_pages, b_hit, terminal = None, 0, 0, None
            if self._prefix_share:
                hit = self.pool.match_prefix([int(t) for t in prompt])
                cap = max(0, (p_len - 1) // self.pool.page_size)
                if self._batcher is not None:
                    b_hit = min(self._batcher.prefix_hit(prompt),
                                len(hit.pages), cap)
                    hit_pages = b_hit
                elif hit.terminal is not None:
                    terminal = hit.terminal
                    hit_pages = len(hit.pages) + (
                        1 if terminal[0] is not None else 0)
                else:
                    hit_pages = min(len(hit.pages), cap)
            if not self._admissible(req, p_len, pad, hit_pages=hit_pages,
                                    batcher_hit=b_hit):
                break                       # FIFO back-pressure: wait for pages
            queue.popleft()
            if self._chunked:
                st = GenStats()
                self._admit_chunked(slot, req, prompt, p_len, st, hit,
                                    hit_pages, terminal)
                admitted = True
                if slot.prefill_prompt is None:   # terminal fast path
                    self._maybe_finish(slot)
                continue
            pages = (self._admit_pages(slot, p_len, pad)
                     if self.pool is not None else None)
            tokens = np.zeros((1, pad), np.int32)
            tokens[0, :p_len] = prompt
            st = GenStats()
            if self.mode == "cloud":
                t0 = time.perf_counter()
                logits, row = self._full_prefill(self.params, tokens, p_len,
                                                 self._full_row0)
                self.main_caches = self._scatter_admit(self.main_caches, row,
                                                       slot, pages)
                first = self._pick(np.asarray(logits)[:, 0])
                st.cloud_time += time.perf_counter() - t0
                tok = int(first[0])
            else:
                t0 = time.perf_counter()
                decisions, h1_seq, row = self._edge_prefill(
                    self.params, tokens, p_len, self._edge_row0)
                self.edge_caches = self._scatter_admit(self.edge_caches, row,
                                                       slot, pages)
                fetched = jax.device_get(
                    {l: (d.token, d.confidence, d.logits)
                     for l, d in decisions.items()})
                st.edge_time += time.perf_counter() - t0

                prefill_logits = None
                if self.mode == "collm":
                    t0 = time.perf_counter()
                    if self._batcher is not None:
                        logits = self._batcher.admit(
                            req.device_id, h1_seq, p_len,
                            p_len + req.max_new)
                    else:
                        logits, crow = self._cloud_prefill(
                            self.params, h1_seq, p_len, self._cloud_row0)
                        self.cloud_caches = self._scatter_admit(
                            self.cloud_caches, crow, slot, pages)
                    prefill_logits = np.asarray(logits)[:, 0]
                    st.cloud_time += time.perf_counter() - t0
                    st.upload_bytes += hidden_wire_bytes(
                        self.model.cfg.d_model, self.ccfg.wire_format,
                        seq=p_len)

                tok = self._first_token(fetched, prefill_logits, st)
            st.tokens = 1
            slot.req, slot.stats = req, st
            slot.tokens = [tok]
            slot.emit_ts = [self.vnow]
            slot.events = ["admit"]
            slot.last_token = tok
            slot.pos = p_len
            slot.active = True
            slot.seq += 1            # late replies of the predecessor drop
            slot.pending = {}
            slot.draft = []
            slot.miss_streak = 0
            slot.standalone = False
            slot.admit_seq = self._next_admit_seq()
            slot.cloud_pkts = []
            admitted = True
            self._maybe_finish(slot)
        return admitted

    def _admit_chunked(self, slot: _Slot, req: Request, prompt: np.ndarray,
                       p_len: int, st: GenStats, hit, hit_pages: int,
                       terminal) -> None:
        """Chunked admission (CollmConfig.chunked_prefill): map the
        shared-prefix pages, allocate the remaining prompt pages upfront
        (mid-prefill slots are not preemptible, so they must never trigger
        a mid-flight allocation), then either emit the memoized first
        token (whole-prompt *terminal* hit — zero prefill compute) or arm
        the per-slot prefill state machine that ``tick`` advances one
        page-sized chunk at a time, interleaved with other slots'
        decode."""
        pool, ps = self.pool, self.pool.page_size
        dev = req.device_id
        n_full_shared = min(hit_pages, len(hit.pages)) if hit else 0
        shared = list(hit.pages[:n_full_shared]) if hit else []
        for lp, page in enumerate(shared):
            pool.share_page(slot.index, lp, page)
        tail_page = terminal[0] if terminal is not None else None
        if tail_page is not None:
            pool.share_page(slot.index, len(shared), tail_page)
        hit_toks = p_len if terminal is not None else n_full_shared * ps
        if hit_toks:
            pool.stats.prefix_hit_tokens += hit_toks
            st.prefix_hit_tokens += hit_toks
            if self.mode == "collm":
                # dedup ledger: these prompt positions never cross the wire
                self.cm.note_prefix_reuse(dev, hit_toks)
        n_prompt = pages_needed(p_len, ps)
        first_alloc = len(shared) + (1 if tail_page is not None else 0)
        for lp in range(first_alloc, n_prompt):
            self._alloc_page(slot.index, lp)
        if self._prefix_share:
            # register this prompt's full chunks in the radix trie NOW
            # (unfilled): a prompt admitted next tick maps them already
            # and stalls until this stream's chunk compute fills them
            pool.insert_prefix(slot.index, [int(t) for t in prompt])
        self._tbl_device = None
        slot.req, slot.stats = req, st
        slot.pending = {}
        slot.draft = []
        slot.miss_streak = 0
        slot.standalone = False
        slot.admit_seq = self._next_admit_seq()
        slot.cloud_pkts = []
        slot.seq += 1
        slot.active = True
        slot.prefill_wait = []
        slot.prefill_wait_cloud = []
        if terminal is not None:
            # the memoized greedy first token stands in for the whole
            # prefill: edge exit decisions and cloud logits are
            # deterministic functions of the (identical) prompt
            tok = int(terminal[1])
            st.tokens = 1
            slot.tokens = [tok]
            slot.emit_ts = [self.vnow]
            slot.events = ["admit"]
            slot.last_token = tok
            slot.pos = p_len
            slot.prefill_prompt = None
            return
        slot.tokens = []
        slot.emit_ts = []
        slot.events = []
        slot.last_token = 0
        slot.pos = 0                 # meaningless until prefill completes
        slot.prefill_prompt = np.asarray(prompt, np.int32)
        slot.prefill_pos = n_full_shared * ps
        slot.prefill_wait = [p for p in shared
                             if not pool.pages_filled([p])]
        if self._batcher is not None:
            b_shared = self._batcher.admit_begin(
                dev, prompt, p_len, p_len + req.max_new,
                hit_pages=n_full_shared)
            slot.prefill_wait_cloud = [
                p for p in b_shared if not self._batcher.pages_filled([p])]

    def _prefill_tick(self, s: _Slot) -> None:
        """Advance one mid-prefill slot by ONE page-sized chunk.  A sharer
        whose mapped shared pages are still being computed by their owning
        stream stalls (never deadlocks: the owner was admitted into an
        earlier tick or slot and advances every tick).  The final chunk
        yields the first-token decision exactly like monolithic
        admission, then flips the slot to normal decode."""
        pool = self.pool
        if s.prefill_wait:
            if not pool.pages_filled(s.prefill_wait):
                return
            s.prefill_wait = []
        if s.prefill_wait_cloud:
            if self._batcher is not None \
                    and not self._batcher.pages_filled(s.prefill_wait_cloud):
                return
            s.prefill_wait_cloud = []
        st, req = s.stats, s.req
        prompt = s.prefill_prompt
        p_len = len(prompt)
        ps = pool.page_size
        pos0 = s.prefill_pos
        clen = min(ps, p_len - pos0)
        chunk = np.zeros((1, ps), np.int32)
        chunk[0, :clen] = prompt[pos0:pos0 + clen]
        row_tbl = jnp.asarray(pool.block_table[s.index:s.index + 1])
        t0 = time.perf_counter()
        decisions, h1, self.edge_caches = self._edge_chunk(
            self.params, jnp.asarray(chunk), jnp.asarray(pos0, jnp.int32),
            clen, self.edge_caches, row_tbl)
        st.edge_time += time.perf_counter() - t0
        st.prefill_chunks += 1
        final = pos0 + clen >= p_len
        prefill_logits = None
        if self.mode == "collm":
            t0 = time.perf_counter()
            if self._batcher is not None:
                logits = self._batcher.admit_chunk(req.device_id, h1,
                                                   pos0, clen)
            else:
                logits, self.cloud_caches = self._cloud_chunk(
                    self.params, h1, jnp.asarray(pos0, jnp.int32), clen,
                    self.cloud_caches, row_tbl)
            st.cloud_time += time.perf_counter() - t0
            # only the not-shared suffix crosses the wire, chunk by chunk
            # (true chunk length, not the padded page — byte-identical in
            # sum to the monolithic upload of the same suffix)
            st.upload_bytes += hidden_wire_bytes(
                self.model.cfg.d_model, self.ccfg.wire_format, seq=clen)
            if final:
                prefill_logits = np.asarray(logits)
        if clen == ps and self._prefix_share:
            pool.mark_filled(int(pool.block_table[s.index, pos0 // ps]))
        s.prefill_pos = pos0 + clen
        if not final:
            return
        fetched = jax.device_get(
            {l: (d.token, d.confidence, d.logits)
             for l, d in decisions.items()})
        tok = self._first_token(fetched, prefill_logits, st)
        st.tokens += 1
        s.prefill_prompt = None
        s.tokens = [tok]
        s.emit_ts = [self.vnow]
        s.events = ["admit"]
        s.last_token = tok
        s.pos = p_len
        if self._prefix_share and self._batcher is None:
            # terminal insertion at admission: a later identical prompt
            # reuses the partial tail page + this first token, and THIS
            # stream's own first decode write CoWs the now-shared tail
            pool.insert_terminal(s.index, [int(t) for t in prompt], tok)
        self._maybe_finish(s)

    def _first_token(self, fetched: Dict, prefill_logits, st: GenStats) -> int:
        """First token from the prompt's last position — same decision tree
        as the sequential path."""
        layers = sorted(fetched)
        if self.mode == "standalone":
            l2 = layers[-1]
            if self.sampler == "greedy":
                return int(fetched[l2][0][0])
            return int(self._pick(np.asarray(fetched[l2][2]))[0])
        for l in layers:
            tok_l, conf_l, logits_l = fetched[l]
            if float(conf_l[0]) >= self.ccfg.theta:
                if self.sampler == "greedy":
                    return int(tok_l[0])
                return int(self._pick(np.asarray(logits_l))[0])
        # cloud already prefilled through the prompt: its last-position
        # logits ARE the cloud answer for the first token
        st.cloud_requests += 1
        return int(self._pick(prefill_logits)[0])

    def _finalize_latency(self, slot: _Slot) -> None:
        """Fold the stream's per-token emission timestamps into its stats
        at retirement: TTFT (first emission minus request arrival),
        inter-token gaps, and — when the request carries SLO targets —
        one met/total attainment sample.  Virtual-time quantities only,
        so fleet-bench gates built on them are deterministic."""
        st, req = slot.stats, slot.req
        ts = slot.emit_ts
        if not ts:
            return
        ttft = ts[0] - req.arrival_t
        st.ttft_s.append(ttft)
        gaps = [b - a for a, b in zip(ts, ts[1:])]
        st.token_lat_s.extend(gaps)
        if req.slo_ttft_s is not None or req.slo_tpot_s is not None:
            st.slo_total += 1
            met = True
            if req.slo_ttft_s is not None and ttft > req.slo_ttft_s:
                met = False
            if (req.slo_tpot_s is not None and gaps
                    and sum(gaps) / len(gaps) > req.slo_tpot_s):
                met = False
            st.slo_met += int(met)

    # -- slot retirement ----------------------------------------------------
    def _maybe_finish(self, slot: _Slot) -> bool:
        req = slot.req
        done = (len(slot.tokens) >= req.max_new
                or (req.eos_id is not None
                    and slot.tokens[-1] == req.eos_id))
        # speculative: the tail tokens are provisional until their cloud
        # replies reconcile (or miss their deadline) — a rewind may yet
        # resume decoding below max_new / replace the EOS.  A buffered
        # draft counts too: its flush (at-end rule in _draft_tick) must
        # run before the slot can retire.
        done = done and not slot.pending and not slot.draft
        if done:
            self._finalize_latency(slot)
            if self.mode == "collm":
                if self._batcher is not None:
                    # cancels queued requests, frees the cloud pool row
                    self._batcher.release(req.device_id)
                self.cm.end_of_sequence(req.device_id)
            slot.active = False
            if self.pool is not None:
                self._free_pages(slot)
        return done

    def _runnable(self, s: _Slot) -> bool:
        """A slot decodes this tick unless it is stalled on an in-flight
        cloud reply (non-speculative) or has provisionally reached its end
        and awaits validation (speculative).  Mid-prefill slots never
        decode — ``_prefill_tick`` advances them instead."""
        if not s.active:
            return False
        if s.prefill_prompt is not None:
            return False
        if s.pending and not self._spec:
            return False
        if len(s.tokens) >= s.req.max_new:
            return False
        if (s.req.eos_id is not None and s.tokens
                and s.tokens[-1] == s.req.eos_id):
            return False
        return True

    def _free_pages(self, slot: _Slot) -> None:
        """Bulk-free a retired slot's pages and invalidate them on device
        (pos = -1) so reallocation can never leak its K/V.  Pages the
        radix prefix cache (or another slot) still references are only
        unreferenced, stay resident, and are NOT invalidated."""
        freed = self.pool.free_slot(slot.index)
        self._tbl_device = None
        self._reset_freed(freed)

    # -- preemption ---------------------------------------------------------
    # Admission is optimistic, so a decode-time alloc can find the free
    # list empty.  The scheduler then checkpoints a victim stream (tokens,
    # events, stats, pending ContentManager uploads, the cloud-consumed
    # upload packets, the CloudBatcher row) and frees its pages; the
    # stream resumes later by re-prefill of its token prefix ("recompute")
    # or a host round-trip of its pages ("swap").  The resume point is
    # always ``len(prompt) + len(tokens) - 1``: the last emitted token is
    # re-fed, so an interrupted in-flight edge pass is simply re-run and
    # re-dispatched — greedy decode makes the re-run bit-deterministic,
    # which is why preemption is invisible in output space.

    def _preempt_victim(self, s: _Slot) -> None:
        """Pick and preempt one victim stream to free pages for ``s``.
        Shared (refcounted) pages don't come back on free, so victims are
        ranked by *reclaimable* pages; mid-prefill slots are excluded —
        their admission allocated everything upfront, and a checkpoint
        with zero emitted tokens has no resume point."""
        if self.preemption == "off":
            raise RuntimeError(
                f"slot {s.index}: out of pages mid-decode with "
                f"preemption off — the conservative admission "
                f"check should make this impossible") from None
        cands = [VictimCandidate(v.index, v.admit_seq,
                                 self.pool.owned_pages(v.index),
                                 self.pool.shared_pages(v.index))
                 for v in self.slots
                 if v.active and v is not s and v.prefill_prompt is None]
        try:
            victim = select_victim(cands, self.preempt_policy)
        except OutOfPages:
            raise RuntimeError(
                f"slot {s.index}: out of pages and no preemptible "
                f"victim (pool of {self.pool.num_pages} pages too "
                f"small for one stream?)") from None
        self._preempt(self.slots[victim])

    def _ensure_page(self, s: _Slot, lp: int) -> None:
        """Alloc-on-write with reclaim + preemption: evict unreferenced
        prefix-cache pages first, then keep freeing victims until the
        page for ``s``'s next write exists."""
        while True:
            try:
                self._alloc_page(s.index, lp)
                return
            except OutOfPages:
                self.oops += 1
                self._preempt_victim(s)

    def _cow_write(self, s: _Slot, lp: int) -> None:
        """Copy-on-write: ``s`` is about to write into a page another
        stream (or the radix cache) still references.  Allocate a private
        copy, device-copy the page contents across every cache tree this
        engine holds (K, V, pos, int8 scales — ``COPY_PAGES`` walks the
        whole tree), and repoint the block table; co-holders keep reading
        the original."""
        while True:
            try:
                src, dst = self.pool.cow_page(s.index, lp)
                break
            except OutOfPages:
                self.oops += 1
                freed = self.pool.evict_prefix(1)
                if freed:
                    self._reset_freed(freed)
                    continue
                self._preempt_victim(s)
        self._tbl_device = None
        jsrc, jdst = jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)
        for name in ("main_caches", "edge_caches", "cloud_caches"):
            c = getattr(self, name, None)
            if c is not None:
                setattr(self, name, self._copy_pages(c, jsrc, jdst))
        s.stats.cow_copies += 1

    def _preempt(self, s: _Slot) -> None:
        """Checkpoint one active stream and free its slot + pages.

        In-flight cloud replies are abandoned — the ``seq`` bump makes
        them late-drop — and queued CloudBatcher requests are cancelled
        before any KV is invalidated (cancel-before-invalidate), exactly
        the speculative-rewind lifecycle."""
        req, st = s.req, s.stats
        if (s.pending or s.draft) and self._spec:
            # provisional tokens past the earliest unvalidated position
            # would never be reconciled: rewind the checkpoint to the
            # validated prefix (re-decode re-speculates them identically).
            # Buffered draft tokens are always newer than any dispatched
            # group, but cover the case where only a draft is outstanding.
            cand = [p.tok_index for p in s.pending.values()]
            if s.draft:
                cand.append(s.draft[0].tok_index)
            cut = min(cand)
            for kind in reversed(s.events[cut:]):
                self._unwind_event(s, kind)
            del s.tokens[cut:]
            del s.emit_ts[cut:]
            del s.events[cut:]
        # abandoned in-flight waits are virtual time this stream really
        # spent: bill their stall/overlap here, because their replies will
        # late-drop and poll-time billing never sees a dropped request
        for pend in s.pending.values():
            if not self._spec:
                st.stall_s += self.vnow - pend.stall_from
            st.overlap_s += self._hidden_s(pend)
        s.pending = {}
        # dropped draft packets sit at/after the resume point — re-decode
        # re-creates (and re-uploads) them, so they are NOT checkpointed
        s.draft = []
        resume_pos = len(req.prompt) + len(s.tokens) - 1
        use_swap = self.preemption == "swap"
        if (use_swap and self._adaptive is not None
                and self._adaptive.cfg.adapt_resume_mode
                and self._resume_cost is not None):
            # per-victim mode choice: short contexts re-prefill cheaper
            # than their KV round-trips the host; long contexts flip
            use_swap = self._resume_cost.prefer_swap(
                resume_pos, int(resume_pos * self._kv_token_bytes()))
        # cloud KV at/after the resume point is re-created by re-decode;
        # everything before it replays from the consumed-upload log
        ck_pkts = [e for e in s.cloud_pkts if e[0] < resume_pos]
        uploads = []
        if self.mode == "collm":
            uploads = [u for u in self.cm.take_all_uploads(req.device_id)
                       if u[0] < resume_pos]
        batcher_swap = None
        if self._batcher is not None:
            if use_swap:
                batcher_swap = self._batcher.swap_out(req.device_id)
            else:
                self._batcher.release(req.device_id)
        swap_key, swap_pages = None, 0
        if self.pool is not None:
            if use_swap:
                swap_key, swap_pages = self._swap_out_slot(s)
            self._free_pages(s)
        self._preempted.append(_Checkpoint(
            req=req, stats=st, tokens=list(s.tokens), events=list(s.events),
            emit_ts=list(s.emit_ts),
            cloud_pkts=ck_pkts, uploads=uploads, standalone=s.standalone,
            miss_streak=s.miss_streak, swap_key=swap_key,
            swap_pages=swap_pages, batcher_swap=batcher_swap))
        st.preemptions += 1
        self.preemptions += 1
        s.seq += 1               # outstanding replies must never land here
        s.active = False
        s.req = None
        s.stats = None
        s.tokens = []
        s.emit_ts = []
        s.events = []
        s.cloud_pkts = []

    def _kv_token_bytes(self) -> float:
        """Modeled device bytes of KV/state per resident token (paged
        layout: total pooled cache bytes over total pooled capacity) —
        the quantity the swap cost model prices per victim."""
        if self._kv_tok_bytes is None:
            cap = self.pool.num_pages * self.pool.page_size
            self._kv_tok_bytes = self.kv_cache_bytes() / max(1, cap)
        return self._kv_tok_bytes

    def _swap_out_slot(self, s: _Slot) -> tuple:
        """Copy the slot's physical pages (every cache tree this engine
        holds) to the host-side SwapPool; returns (key, n_pages)."""
        key = self._swap_key
        self._swap_key += 1
        logical, trees = np.zeros((0,), np.int32), {}
        for name in ("main_caches", "edge_caches", "cloud_caches"):
            c = getattr(self, name, None)
            if c is None:
                continue
            logical, t = gather_slot_pages(self.pool, s.index, c)
            if t is not None:
                trees[name] = t
        self.swap.put(key, {"logical": logical, "trees": trees or None})
        return key, len(logical)

    def _resume_preempted(self) -> bool:
        """FIFO-resume checkpointed streams into free slots while their
        pages (and, in collm mode, a cloud row) are available."""
        resumed = False
        while self._preempted:
            slot = next((s for s in self.slots
                         if not s.active and s.req is None), None)
            if slot is None or not self._resumable(self._preempted[0]):
                break
            self._resume(self._preempted.popleft(), slot)
            resumed = True
        return resumed

    def _resumable(self, ck: _Checkpoint) -> bool:
        req = ck.req
        p_len = len(req.prompt)
        if self._batcher is not None \
                and not self._batcher.can_admit(p_len + req.max_new):
            return False
        if self.pool is None:
            return True
        need = (ck.swap_pages if ck.swap_key is not None
                else pages_needed(p_len + len(ck.tokens) - 1,
                                  self.pool.page_size))
        return self._fits_now(need)

    def _resume_pad(self, length: int) -> int:
        """Prefill bucket for a resume prefix: the usual power-of-two
        bucket, clamped to the single-row cache capacity (a long prefix's
        bucket may overshoot a dense ``max_seq`` that is not a power of
        two; the prefix itself always fits)."""
        if not self._pad_ok:
            return length
        return min(_bucket(length), self._row_seq)

    def _resume(self, ck: _Checkpoint, slot: _Slot) -> None:
        req = ck.req
        prompt = np.asarray(req.prompt, np.int32)
        p_len = len(prompt)
        resume_pos = p_len + len(ck.tokens) - 1
        if self._resume_cost is not None:
            # bill the chosen resume mode's modeled cost into this
            # engine's virtual clock — static and adaptive configurations
            # pay the same physics, they just choose differently
            if ck.swap_key is not None:
                kv_bytes = int(ck.swap_pages * self.pool.page_size
                               * self._kv_token_bytes())
                self.vnow += self._resume_cost.swap_s(kv_bytes)
            else:
                self.vnow += self._resume_cost.recompute_s(resume_pos)
        if self.mode == "collm":
            self.cm.restore_uploads(req.device_id, ck.uploads)
        if ck.swap_key is not None:
            self._swap_in_slot(slot, self.swap.take(ck.swap_key))
            if self._batcher is not None:
                self._batcher.swap_in(req.device_id, ck.batcher_swap)
        else:
            self._reprefill(slot, ck, prompt, resume_pos)
        slot.req, slot.stats = req, ck.stats
        slot.tokens = list(ck.tokens)
        slot.emit_ts = list(ck.emit_ts)
        slot.events = list(ck.events)
        slot.last_token = ck.tokens[-1]
        slot.pos = resume_pos
        slot.active = True
        slot.seq += 1
        slot.pending = {}
        slot.draft = []
        slot.miss_streak = ck.miss_streak
        slot.standalone = ck.standalone
        slot.cloud_pkts = list(ck.cloud_pkts)
        slot.admit_seq = self._next_admit_seq()
        self._maybe_finish(slot)

    def _swap_in_slot(self, slot: _Slot, snap: dict) -> None:
        """Write a swap snapshot into freshly allocated physical pages and
        re-bind the slot's block table (pages are row-agnostic)."""
        if snap["trees"] is None or not len(snap["logical"]):
            return
        short = len(snap["logical"]) - self.pool.free_pages
        if short > 0:       # reclaim cached prefix pages for the rebind
            self._reset_freed(self.pool.evict_prefix(short))
        padded = rebind_slot_pages(self.pool, slot.index, snap["logical"])
        self._tbl_device = None
        for name, data in snap["trees"].items():
            setattr(self, name,
                    self._write_pages(getattr(self, name), padded, data))

    def _reprefill(self, slot: _Slot, ck: _Checkpoint, prompt: np.ndarray,
                   resume_pos: int) -> None:
        """Recompute-mode resume: one prefill over ``prompt + tokens[:-1]``
        rebuilds the edge (or full-model) KV, and the checkpointed
        consumed-upload log replays the cloud KV — gaps at early-exited
        positions included, exactly as the un-preempted run left them."""
        p_len = len(prompt)
        st = ck.stats
        pad = self._resume_pad(resume_pos)
        tokens = np.zeros((1, pad), np.int32)
        tokens[0, :p_len] = prompt
        tokens[0, p_len:resume_pos] = ck.tokens[:-1]
        pages = (self._admit_pages(slot, resume_pos, pad)
                 if self.pool is not None else None)
        if self.mode == "cloud":
            t0 = time.perf_counter()
            _, row = self._full_prefill(self.params, tokens, resume_pos,
                                        self._full_row0)
            self.main_caches = self._scatter_admit(self.main_caches, row,
                                                   slot, pages)
            st.cloud_time += time.perf_counter() - t0
            return
        t0 = time.perf_counter()
        _, h1_seq, row = self._edge_prefill(self.params, tokens, resume_pos,
                                            self._edge_row0)
        self.edge_caches = self._scatter_admit(self.edge_caches, row, slot,
                                               pages)
        st.edge_time += time.perf_counter() - t0
        if self.mode != "collm":
            return
        # cloud prompt prefill (same padded hidden slice as admission) +
        # replay of the consumed decode uploads; the re-prefill h1 is NOT
        # re-uploaded — the wire already carried it before preemption
        t0 = time.perf_counter()
        pad_p = (min(_bucket(p_len), self._row_seq) if self._pad_ok
                 else p_len)
        h1_p = h1_seq[:, :pad_p]
        if self._batcher is not None:
            self._batcher.admit(req_id := ck.req.device_id, h1_p, p_len,
                                p_len + ck.req.max_new)
            self._batcher.restore(req_id, ck.cloud_pkts)
        else:
            cpages = None
            if self.pool is not None:
                n_prompt = pages_needed(p_len, self.pool.page_size)
                cpages = np.full((pages_needed(pad_p, self.pool.page_size),),
                                 -1, np.int32)
                cpages[:n_prompt] = self.pool.block_table[slot.index,
                                                          :n_prompt]
            _, crow = self._cloud_prefill(self.params, h1_p, p_len,
                                          self._cloud_row0)
            self.cloud_caches = self._scatter_admit(self.cloud_caches, crow,
                                                    slot, cpages)
            self._replay_cloud(slot, ck.cloud_pkts)
        st.cloud_time += time.perf_counter() - t0

    def _replay_cloud(self, slot: _Slot, pkts: List[tuple]) -> None:
        """Own-cloud replay of the checkpointed consumed uploads (one
        masked ring drain over this slot's row)."""
        if not pkts:
            return
        ring, ring_pos, valid = build_upload_ring([(slot.index, pkts)],
                                                  self.B)
        _, self.cloud_caches = self._ring_cloud(
            self.params, ring, ring_pos, valid, self.cloud_caches,
            self._block_tbl())

    # -- one decode tick ----------------------------------------------------
    def tick(self) -> None:
        """One step of the two-stage pipeline: resolve due replies, run the
        edge pass for every runnable row (stalled rows flow through the
        batched graph as placeholders), dispatch this tick's below-θ cloud
        requests, resolve again (a ``SyncChannel`` reply arrives within
        the same tick).  When every active row is blocked on the channel,
        the virtual clock jumps to the next arrival/deadline instead of
        busy-waiting."""
        self._tick_no += 1
        if self._adaptive is not None:
            self._adaptive.on_tick(self._tick_no, self.pool,
                                   self.preemptions, self.oops)
        for idx in self._preempt_schedule.get(self._tick_no, ()):
            # forced-preemption test hook (mid-prefill slots are never
            # preemptible — they have no resume point yet)
            if (self.slots[idx].active
                    and self.slots[idx].prefill_prompt is None):
                self._preempt(self.slots[idx])
        self._resolve()
        # chunked prefill: every mid-prefill slot advances by ONE
        # page-sized chunk per tick, interleaved with the other slots'
        # decode below (sharers stalled on unfilled pages just wait)
        prefilling = [s for s in self.slots
                      if s.active and s.prefill_prompt is not None]
        for s in prefilling:
            self._prefill_tick(s)
        busy = {s.index for s in prefilling}
        runnable = [s for s in self.slots
                    if self._runnable(s) and s.index not in busy]
        if not runnable:
            # a prefill chunk IS progress — don't jump the virtual clock
            if any(s.active for s in self.slots) and not prefilling:
                self._advance_idle()
                self._resolve()
            return
        for s in runnable:
            if self.pool is not None and s.active:
                # alloc-on-write: this tick writes KV at s.pos; an empty
                # free list preempts a victim stream (never s itself).  A
                # mapped-but-shared page (radix prefix / cached terminal
                # tail) must be split before the write: copy-on-write.
                lp = s.pos // self.pool.page_size
                page = self.pool.block_table[s.index, lp]
                if page == -1:
                    self._ensure_page(s, lp)
                elif self.pool.is_shared(int(page)):
                    self._cow_write(s, lp)
        runnable = [s for s in runnable if s.active]   # minus fresh victims
        tokens = np.zeros((self.B, 1), np.int32)
        pos = np.zeros((self.B,), np.int32)
        for s in self.slots:
            if s.active:     # stalled rows: placeholder decode, outputs dropped
                tokens[s.index, 0] = s.last_token
                pos[s.index] = s.pos

        self.vnow += self.tick_time_s    # this tick's edge compute (virtual)
        if self.mode == "cloud":
            self._tick_cloud(runnable, tokens, pos)
        else:
            self._tick_edge(runnable, tokens, pos)

        for s in runnable:
            s.pos += 1
            self._maybe_finish(s)
        self._resolve()

    def _tick_cloud(self, runnable, tokens, pos) -> None:
        t0 = time.perf_counter()
        tok, logits, self.main_caches = self._full_step(
            self.params, jnp.asarray(tokens), self.main_caches,
            jnp.asarray(pos), self._block_tbl())
        if self.sampler == "greedy":
            next_tok = np.asarray(tok)
        else:
            next_tok = self._pick(np.asarray(logits))
        dt = (time.perf_counter() - t0) / len(runnable)
        for s in runnable:
            s.stats.cloud_time += dt
            self._emit(s, int(next_tok[s.index]), "full")

    def _tick_edge(self, runnable, tokens, pos) -> None:
        collm, ccfg = self.collm, self.ccfg
        t0 = time.perf_counter()
        jt, jp, tbl = jnp.asarray(tokens), jnp.asarray(pos), self._block_tbl()
        if self._mask_edge:
            run_mask = np.zeros((self.B,), bool)
            for s in runnable:
                run_mask[s.index] = True
            out = self._edge_masked(self.params, jt, self.edge_caches, jp,
                                    jnp.asarray(run_mask), tbl)
        else:
            out = self._edge_step(self.params, jt, self.edge_caches, jp, tbl)
        self.edge_caches = out.caches
        want_logits = self.sampler != "greedy"
        get = {
            "token": out.token, "exited": out.exited,
            "conf": {l: d.confidence for l, d in out.decisions.items()},
            "tok2": out.decisions[collm.l_ee2].token,
            "upload": out.upload,
        }
        if want_logits:
            if self.mode == "standalone":
                get["logits_l2"] = out.decisions[collm.l_ee2].logits
            else:
                # per-row logits of the chosen exit (sampling path); rows
                # that exit nowhere get the LAST exit's logits, which is
                # also what a standalone-fallback row samples from
                get["sel_logits"] = select_exit_logits(
                    out.decisions, ccfg.theta)[0]
        fetched = jax.device_get(get)
        edge_dt = (time.perf_counter() - t0) / len(runnable)
        exited = fetched["exited"]
        confs = fetched["conf"]

        for s in runnable:
            s.stats.edge_time += edge_dt
            s.stats.tokens += 1
            c1 = float(confs.get(collm.l_ee1, np.zeros(self.B))[s.index])
            c2 = float(confs.get(collm.l_ee2, np.zeros(self.B))[s.index])
            s.stats.confidences.append((c1, c2))

        if self.mode == "standalone":
            toks = (fetched["tok2"] if self.sampler == "greedy"
                    else self._pick(fetched["logits_l2"]))
            for s in runnable:
                c1 = s.stats.confidences[-1][0]
                if c1 >= ccfg.theta:
                    s.stats.exits_l1 += 1
                    self._emit(s, int(toks[s.index]), "l1")
                else:
                    s.stats.exits_l2 += 1
                    self._emit(s, int(toks[s.index]), "l2")
            return

        # parallel upload (always dispatched at l_ee1) — batched receive.
        # Standalone-fallback rows have given up on the cloud: no upload.
        up = fetched["upload"]
        uploaders = [s for s in runnable if not s.standalone]
        pkts = {s.index: StatePacket(
            hidden={k: v[s.index:s.index + 1] for k, v in up.items()},
            pos=s.pos) for s in uploaders}
        self.cm.upload_batch((s.req.device_id, s.pos, pkts[s.index])
                             for s in uploaders)
        for s in uploaders:
            nb = pkts[s.index].nbytes()
            s.stats.upload_bytes += nb
            self.channel.notify_upload(s.index, nb, self.vnow)

        exit_toks = (fetched["token"] if self.sampler == "greedy"
                     else self._pick(fetched["sel_logits"]))
        tok2 = fetched["tok2"]

        # the provisional token a deadline miss commits: the l_ee2 exit
        # head's answer under the configured sampler (sel_logits gives
        # below-θ rows the last exit's logits on the sampling path)
        prov_toks = tok2 if self.sampler == "greedy" else exit_toks
        needy = [s for s in uploaders if not bool(exited[s.index])]
        if self._spec:
            # multi-token drafting (spec_k=1 ≡ the classic speculative
            # path): below-θ rows buffer provisional tokens and ship them
            # in k-sized verification requests
            self._draft_tick(needy, uploaders, prov_toks)
        elif needy:
            self._dispatch_cloud(needy, pos, prov_toks)
        for s in runnable:
            if bool(exited[s.index]):
                if s.stats.confidences[-1][0] >= ccfg.theta:
                    s.stats.exits_l1 += 1
                    self._emit(s, int(exit_toks[s.index]), "l1")
                else:
                    s.stats.exits_l2 += 1
                    self._emit(s, int(exit_toks[s.index]), "l2")
            elif s.standalone:
                # latency fallback: the edge serves its below-θ tokens
                s.stats.exits_l2 += 1
                tok = (int(tok2[s.index]) if self.sampler == "greedy"
                       else int(exit_toks[s.index]))
                self._emit(s, tok, "l2")
            # else: needy — token arrives via the channel (_resolve)

    def _dispatch_cloud(self, needy: List[_Slot], pos: np.ndarray,
                        prov_toks: np.ndarray) -> None:
        """Stage 2: one masked cloud call computes every below-θ slot of
        the tick; per-row requests enter the channel and the engine keeps
        decoding while they are in flight.  The batched logits stay on
        device — materialization is deferred to the drain, so jax async
        dispatch overlaps the cloud compute with the next edge pass in
        wall-clock time while the channel prices the flight in virtual
        time.  With a shared ``CloudBatcher`` the masked call itself is
        deferred too: requests queue with the batcher so concurrent rows
        from OTHER engines join the same wave (one masked cloud step for
        N edge clients)."""
        ccfg = self.ccfg
        mask = np.zeros((self.B,), bool)
        for s in needy:
            mask[s.index] = True

        # the consumed-upload log backs the recompute resume's cloud
        # replay; swap resumes restore pages directly (CloudBatcher
        # flushes before its snapshot), so tracking there would only
        # hoard host memory
        track = self.preemption == "recompute"
        t0 = time.perf_counter()
        if self._batcher is not None:
            # shared cloud: queue per-row requests with the CloudBatcher —
            # it coalesces them with OTHER engines' concurrent requests
            # into one masked cloud step over the pooled cloud cache, and
            # the reply group's flush hook materializes it at the drain
            payloads = {}
            for s in needy:
                group, row, consumed = self._batcher.submit(
                    s.req.device_id, s.pos, backfill=ccfg.backfill)
                payloads[s.index] = (group, row)
                if track:
                    s.cloud_pkts.extend(consumed)
        elif ccfg.backfill:
            rings = self.cm.take_uploads_upto_batch(
                [(s.req.device_id, s.pos) for s in needy])
            if track:
                for s, pend in zip(needy, rings):
                    s.cloud_pkts.extend(pend)
            ring, ring_pos, valid = build_upload_ring(
                [(s.index, pend) for s, pend in zip(needy, rings)], self.B)
            logits, self.cloud_caches = self._ring_cloud(
                self.params, ring, ring_pos, valid, self.cloud_caches,
                self._block_tbl())
            group = {"logits": logits, "np": None}   # materialized at drain
            payloads = {s.index: (group, s.index) for s in needy}
        else:
            pkts = self.cm.take_upload_batch(
                [(s.req.device_id, s.pos) for s in needy])
            keys = pkts[0].hidden.keys()
            dense = {k: np.zeros((self.B,) + np.shape(pkts[0].hidden[k])[1:],
                                 np.asarray(pkts[0].hidden[k]).dtype)
                     for k in keys}
            for s, pkt in zip(needy, pkts):
                if track:
                    s.cloud_pkts.append((s.pos, pkt))
                for k in keys:
                    dense[k][s.index] = np.asarray(pkt.hidden[k])[0]
            logits, self.cloud_caches = self._cloud_masked(
                self.params, {k: jnp.asarray(v) for k, v in dense.items()},
                self.cloud_caches, jnp.asarray(pos), jnp.asarray(mask),
                self._block_tbl())
            group = {"logits": logits, "np": None}   # materialized at drain
            payloads = {s.index: (group, s.index) for s in needy}

        dt = (time.perf_counter() - t0) / len(needy)
        handles = []
        for s in needy:
            s.stats.cloud_time += dt
            h = self.channel.submit(
                slot=s.index, seq=s.seq, pos=s.pos,
                reply=payloads[s.index], now=self.vnow,
                nbytes_up=TOKEN_BYTES, nbytes_down=TOKEN_BYTES)
            s.pending[h] = _Pending(
                pos=s.pos, tok_index=len(s.tokens),
                provisional=int(prov_toks[s.index]), stall_from=self.vnow,
                deadline_t=self.vnow + self.channel.deadline_s,
                idle_at=self._idle_s)
            handles.append(h)
        if not self.overlap:
            # blocking baseline: the whole pool waits for this tick's
            # replies (still paying the channel's virtual latency) — the
            # jump is pure idle time, nothing decodes during it
            arr = [self.channel.arrival_of(h) for h in handles]
            target = max([self.vnow] + [a for a in arr if a is not None])
            self._idle_s += target - self.vnow
            self.vnow = target

    # -- multi-token drafting (speculative path) ----------------------------
    def _draft_tick(self, needy: List[_Slot], uploaders: List[_Slot],
                    prov_toks: np.ndarray) -> None:
        """Speculative drafting: every below-θ row commits its provisional
        l_ee2 token into the slot's draft buffer — popping the
        just-uploaded packet so the ContentManager window can never evict
        a position still awaiting verification — then full drafts, drafts
        whose row took a confident tick (drafts stay position-contiguous),
        and drafts whose row just reached its end flush as single
        verification requests (_flush_drafts)."""
        ccfg = self.ccfg
        needy_idx = set()
        for s in needy:
            needy_idx.add(s.index)
            dev = s.req.device_id
            # release mode keeps today's semantics (consuming pos releases
            # earlier confident-tick uploads); backfill must preserve them
            # for the flush-time drain
            pkt = (self.cm.take_upload_keep(dev, s.pos) if ccfg.backfill
                   else self.cm.take_upload(dev, s.pos))
            s.draft.append(_DraftTok(
                pos=s.pos, tok_index=len(s.tokens),
                provisional=int(prov_toks[s.index]), pkt=pkt))
            # latency hiding: commit the edge token provisionally and keep
            # decoding; the verification reply reconciles it (_resolve)
            self._emit(s, int(prov_toks[s.index]), "spec")
        flush = []
        for s in uploaders:
            if not s.draft:
                continue
            eos = s.req.eos_id
            at_end = (len(s.tokens) >= s.req.max_new
                      or (eos is not None and s.tokens[-1] == eos))
            if (len(s.draft) >= self._spec_k
                    or s.index not in needy_idx   # confident tick ends it
                    or at_end):                   # the row won't tick again
                flush.append(s)
        if flush:
            self._flush_drafts(flush)

    def _flush_drafts(self, rows: List[_Slot]) -> None:
        """Ship each row's buffered draft as ONE verification request: the
        k draft packets join the upload ring (backfill additionally drains
        the not-yet-consumed older uploads so the cloud KV stays exact)
        and one masked ring pass scores every draft position
        (``ring_cloud_steps_all``); the reply carries per-position logits
        for the accept-prefix reconcile.  An all-singles wave (spec_k=1,
        release mode) takes the dense masked step — bit-identical to the
        classic speculative path."""
        ccfg = self.ccfg
        track = self.preemption == "recompute"
        t0 = time.perf_counter()
        ring_maps: Dict[int, Dict[int, int]] = {}
        if self._batcher is not None:
            payloads = {}
            for s in rows:
                group, row, consumed = self._batcher.submit_draft(
                    s.req.device_id, [(d.pos, d.pkt) for d in s.draft],
                    backfill=ccfg.backfill)
                payloads[s.index] = (group, row)
                ring_maps[s.index] = {p: i for i, (p, _)
                                      in enumerate(consumed)}
                if track:
                    s.cloud_pkts.extend(consumed)
        else:
            entries = []
            for s in rows:
                pkt_list = [(d.pos, d.pkt) for d in s.draft]
                if ccfg.backfill:
                    older = self.cm.take_uploads_upto(
                        s.req.device_id, s.draft[-1].pos)
                    # a confident tick flushes, so drafts are contiguous:
                    # every not-yet-consumed older upload precedes them
                    pkt_list = older + pkt_list
                if track:
                    s.cloud_pkts.extend(pkt_list)
                entries.append((s.index, pkt_list))
                ring_maps[s.index] = {p: i for i, (p, _)
                                      in enumerate(pkt_list)}
            depth = max(len(pl) for _, pl in entries)
            if depth == 1 and not ccfg.backfill:
                # all-singles wave: dense masked step (same code path the
                # classic speculative dispatch takes)
                mask = np.zeros((self.B,), bool)
                posv = np.zeros((self.B,), np.int32)
                pkts0 = [pl[0][1] for _, pl in entries]
                keys = pkts0[0].hidden.keys()
                dense = {k: np.zeros(
                    (self.B,) + np.shape(pkts0[0].hidden[k])[1:],
                    np.asarray(pkts0[0].hidden[k]).dtype) for k in keys}
                for s, pkt in zip(rows, pkts0):
                    mask[s.index] = True
                    posv[s.index] = s.draft[0].pos
                    for k in keys:
                        dense[k][s.index] = np.asarray(pkt.hidden[k])[0]
                logits, self.cloud_caches = self._cloud_masked(
                    self.params,
                    {k: jnp.asarray(v) for k, v in dense.items()},
                    self.cloud_caches, jnp.asarray(posv),
                    jnp.asarray(mask), self._block_tbl())
                group = {"logits": logits, "all": None,
                         "np": None, "np_all": None}
            else:
                ring, ring_pos, valid = build_upload_ring(entries, self.B)
                logits, all_logits, self.cloud_caches = \
                    self._ring_cloud_all(self.params, ring, ring_pos, valid,
                                         self.cloud_caches,
                                         self._block_tbl())
                group = {"logits": logits, "all": all_logits,
                         "np": None, "np_all": None}
            payloads = {s.index: (group, s.index) for s in rows}

        dt = (time.perf_counter() - t0) / len(rows)
        handles = []
        for s in rows:
            s.stats.cloud_time += dt
            kk = len(s.draft)
            rm = ring_maps[s.index]
            for d in s.draft:
                d.ring_idx = rm[d.pos]
            # wire: the k hidden rows were billed by their per-tick
            # notify_upload calls (parallel upload); the request carries
            # the k provisional ids up and k verified ids down
            h = self.channel.submit(
                slot=s.index, seq=s.seq, pos=s.draft[-1].pos,
                reply=payloads[s.index], now=self.vnow,
                nbytes_up=draft_request_bytes(kk),
                nbytes_down=TOKEN_BYTES * kk)
            s.pending[h] = _Pending(
                pos=s.draft[-1].pos, tok_index=s.draft[0].tok_index,
                provisional=s.draft[0].provisional,
                stall_from=self.vnow,
                deadline_t=self.vnow + self.channel.deadline_s,
                idle_at=self._idle_s, draft=s.draft)
            s.stats.draft_tokens += kk
            s.draft = []
            handles.append(h)
        if not self.overlap:
            # blocking baseline: the whole pool waits for this flush's
            # replies (still paying the channel's virtual latency)
            arr = [self.channel.arrival_of(h) for h in handles]
            target = max([self.vnow] + [a for a in arr if a is not None])
            self._idle_s += target - self.vnow
            self.vnow = target

    def _draft_tokens(self, rep) -> np.ndarray:
        """Materialize a verification reply's per-position greedy tokens
        — shape (depth,) for this row; the accept-prefix reconcile indexes
        it with each draft entry's ``ring_idx``."""
        group, row = rep.reply
        if group.get("np_all") is None:
            if group["logits"] is None and group.get("all") is None:
                # lazy CloudBatcher wave: first materialization computes it
                group["flush"]()
            if group.get("all") is not None:
                group["np_all"] = np.argmax(np.asarray(group["all"]),
                                            axis=-1)        # (depth, B)
            else:
                # dense all-singles wave: depth-1 view of the final logits
                group["np_all"] = np.argmax(
                    np.asarray(group["logits"]), axis=-1)[None, :]
        return group["np_all"][:, row]

    # -- reply drain --------------------------------------------------------
    def _reply_token(self, rep) -> int:
        """Materialize a reply group's logits (once per dispatched batch)
        and return this row's token."""
        group, row = rep.reply
        if group["np"] is None:
            if group["logits"] is None:
                # CloudBatcher reply: the batched cloud step is lazy so
                # that concurrent engines' requests land in one wave —
                # first materialization computes it
                group["flush"]()
            logits = np.asarray(group["logits"])
            if self.sampler == "greedy":
                group["np"] = np.argmax(logits, axis=-1)
            else:
                group["np"] = np.asarray(self._pick(logits))
        return int(group["np"][row])

    def _hidden_s(self, pend: _Pending) -> float:
        """Virtual time of this request's wait that was hidden behind the
        pool's continued decoding: the stalled window minus whatever part
        of it the whole engine spent idle (``_advance_idle`` jumps and the
        blocking drain).  This is the number that separates the overlapped
        pipeline from the blocking one — at 1 slot, or with
        ``overlap=False``, every wait is idle and it stays 0."""
        stall = self.vnow - pend.stall_from
        idle = self._idle_s - pend.idle_at
        return max(0.0, stall - idle)

    def _deadline_miss(self, s: _Slot, pend: _Pending) -> None:
        """Latency-aware early exit: the reply is overdue (or arrived past
        its deadline) — the row's edge l_ee2 token wins."""
        s.stats.deadline_misses += 1
        s.miss_streak += 1
        if self._spec:
            if pend.draft is not None:
                # the whole edge draft becomes final: every position the
                # reply would have reconciled commits as an l2 exit
                for d in pend.draft:
                    s.events[d.tok_index] = "l2"
                    s.stats.exits_l2 += 1
            else:
                # the provisional token becomes final
                s.events[pend.tok_index] = "l2"
                s.stats.exits_l2 += 1
        else:
            s.stats.stall_s += self.vnow - pend.stall_from
            s.stats.overlap_s += self._hidden_s(pend)
            s.stats.exits_l2 += 1
            self._emit(s, pend.provisional, "l2")
        if (self.fallback_after
                and s.miss_streak >= self.fallback_after
                and not s.standalone):
            s.standalone = True
            s.stats.fallbacks += 1
            # a buffered draft can never flush once the row goes
            # standalone (it stops uploading): its provisional tokens
            # become final l2 exits, never billed as draft_tokens
            for d in s.draft:
                s.events[d.tok_index] = "l2"
                s.stats.exits_l2 += 1
            s.draft = []

    def _resolve(self) -> None:
        """Drain arrived replies, then expire deadlines, at the current
        virtual time."""
        for rep in self.channel.poll(self.vnow):
            s = self.slots[rep.slot] if rep.slot < self.B else None
            if (s is None or not s.active or s.seq != rep.seq
                    or rep.handle not in s.pending):
                # the slot retired, was refilled, or rewound past this
                # position: a late reply must never land on its successor
                self.late_drops += 1
                continue
            pend = s.pending.pop(rep.handle)
            if rep.arrival_t > pend.deadline_t:
                # arrival and deadline crossed within one clock advance:
                # the deadline fired first — the reply is late even though
                # we only see both now
                self._deadline_miss(s, pend)
                self.late_drops += 1
                self._maybe_finish(s)
                continue
            if self._spec:
                s.stats.overlap_s += self._hidden_s(pend)
                s.miss_streak = 0
                toks = self._draft_tokens(rep)
                accepted = 0
                for d in pend.draft:
                    cloud_tok = int(toks[d.ring_idx])
                    if cloud_tok == s.tokens[d.tok_index]:
                        # validated: the provisional token IS the cloud
                        # token
                        s.events[d.tok_index] = "cloud"
                        s.stats.cloud_requests += 1
                        s.stats.accepted_tokens += 1
                        accepted += 1
                    else:
                        # first disagreement: correct it and discard the
                        # rejected suffix (later positions' scores were
                        # conditioned on a wrong token)
                        self._rewind(s, d, cloud_tok)
                        break
                s.stats.accept_lens.append(accepted)
            else:
                tok = self._reply_token(rep)
                s.stats.cloud_requests += 1
                s.stats.stall_s += self.vnow - pend.stall_from
                s.stats.overlap_s += self._hidden_s(pend)
                s.miss_streak = 0
                self._emit(s, tok, "cloud")
            self._maybe_finish(s)
        # latency-aware early exit: overdue replies commit the edge token
        for s in self.slots:
            if not s.active or not s.pending:
                continue
            for h, pend in list(s.pending.items()):
                if pend.deadline_t > self.vnow:
                    continue
                del s.pending[h]
                self._deadline_miss(s, pend)
                self._maybe_finish(s)

    def _advance_idle(self) -> None:
        """Every active row is blocked on the channel: jump the virtual
        clock to the next reply arrival or deadline (never busy-wait)."""
        cands = []
        nxt = self.channel.next_arrival()
        if nxt is not None:
            cands.append(nxt)
        for s in self.slots:
            if s.active:
                cands.extend(p.deadline_t for p in s.pending.values())
        if self._arrival_hint is not None and self._arrival_hint > self.vnow:
            # open-loop replay: a queued request's future arrival is also
            # a wake-up point — a free slot may admit it before any reply
            # lands (jumping past it would inflate its queueing delay)
            cands.append(self._arrival_hint)
        cands = [t for t in cands if t != math.inf]
        if not cands:
            raise RuntimeError(
                "scheduler wedged: every row is blocked on the channel but "
                "it has nothing in flight and no finite deadline")
        target = max(self.vnow, min(cands))
        self._idle_s += target - self.vnow     # nothing decodes while idle
        self.vnow = target

    def _unwind_event(self, s: _Slot, kind: str) -> None:
        """Undo one discarded token's contribution to the per-stream
        counters (speculative rewind).  ``deadline_misses`` is an event
        count, not a token property — it stays."""
        st = s.stats
        st.tokens -= 1
        if st.confidences:
            st.confidences.pop()
        if kind == "l1":
            st.exits_l1 -= 1
        elif kind == "l2":
            st.exits_l2 -= 1
        elif kind == "cloud":
            st.cloud_requests -= 1

    def _rewind(self, s: _Slot, pend: _Pending, tok: int) -> None:
        """Speculative reconcile: the cloud disagreed with the provisional
        token at ``pend.tok_index`` — replace it, discard everything the
        row decoded after it, and invalidate the discarded cloud KV (a
        position the re-decoded stream never cloud-serves again must read
        a release-semantics gap, not stale K/V; edge KV needs no repair
        because decode overwrites a slot before reading it)."""
        i = pend.tok_index
        for kind in reversed(s.events[i + 1:]):
            self._unwind_event(s, kind)
        del s.tokens[i + 1:]
        del s.emit_ts[i + 1:]
        del s.events[i + 1:]
        s.tokens[i] = tok
        s.emit_ts[i] = self.vnow   # the corrected token streams out NOW
        s.events[i] = "cloud"
        s.stats.cloud_requests += 1
        s.stats.spec_rewinds += 1
        s.last_token = tok
        s.pos = pend.pos + 1
        for h, p2 in list(s.pending.items()):
            if p2.pos > pend.pos:      # requests of discarded positions
                del s.pending[h]       # (their replies will late-drop)
        # buffered draft tokens of discarded positions are gone too (a
        # buffered draft is always newer than any dispatched group)
        s.draft = [d for d in s.draft if d.pos <= pend.pos]
        # the invalidated cloud KV must not resurface through a later
        # preemption replay either
        s.cloud_pkts = [e for e in s.cloud_pkts if e[0] <= pend.pos]
        if self._batcher is not None:
            # drop still-queued requests of the discarded positions FIRST
            # (a later flush would re-write the KV we are invalidating)
            self._batcher.cancel(s.req.device_id, pend.pos + 1)
            self._batcher.invalidate(s.req.device_id, pend.pos + 1)
        else:
            cut = np.full((self.B,), np.iinfo(np.int32).max, np.int32)
            cut[s.index] = pend.pos + 1
            self.cloud_caches = self._invalidate_rows(
                self.cloud_caches, jnp.asarray(cut), self._block_tbl())

    def _emit(self, slot: _Slot, tok: int, event: str) -> None:
        slot.tokens.append(tok)
        slot.emit_ts.append(self.vnow)
        slot.events.append(event)
        slot.last_token = tok
        if self.mode == "cloud":
            slot.stats.tokens += 1

    # -- driver -------------------------------------------------------------
    def _collect(self, results, stats) -> None:
        """Retire finished slots (frees them for the next admission)."""
        for s in self.slots:
            if s.req is not None and not s.active:
                results[s.req.index] = s.tokens
                stats[s.req.index] = s.stats
                s.req = None

    def run(self, requests: Sequence[Request]):
        """Drain a request list through the slot pool; returns
        (token lists, per-request GenStats) in submission order."""
        for i, r in enumerate(requests):
            r.index = i
            # arrival stamps are relative to the trace start: rebase them
            # onto this engine's (possibly reused) virtual clock
            r.arrival_t += self.vnow
        # open-loop replay admits in arrival order; the sort is stable, so
        # the closed-loop default (every arrival_t == 0) keeps submission
        # order exactly as before
        queue = collections.deque(
            sorted(requests, key=lambda r: (r.arrival_t, r.index)))
        results: List[Optional[List[int]]] = [None] * len(requests)
        stats: List[Optional[GenStats]] = [None] * len(requests)
        v0 = self.vnow
        self.late_drops = 0
        self._tick_no = 0        # forced-preemption schedules are per-run
        # a reused channel must not leak the previous run's link/service
        # virtual times (or stale in-flight replies) into this run's trace
        self.channel.reset()
        while queue or self._preempted or any(s.active for s in self.slots):
            self._arrival_hint = queue[0].arrival_t if queue else None
            admitted = self._admit(queue)
            self._collect(results, stats)     # finished at admission
            if any(s.active for s in self.slots):
                self.tick()
                self._collect(results, stats)
            elif (queue or self._preempted) and not admitted:
                if queue and queue[0].arrival_t > self.vnow:
                    # open-loop gap: nothing running and the next request
                    # hasn't arrived — jump the clock there (pure idle)
                    self._idle_s += queue[0].arrival_t - self.vnow
                    self.vnow = queue[0].arrival_t
                    continue
                # nothing active, nothing admitted/resumed, yet work
                # remains: no tick can ever free pages, so fail loudly
                # instead of spinning (conservative admission makes this
                # impossible, and an idle pool resumes ignore the
                # watermark).  (An admission that finished instantly —
                # first token hits eos — sets ``admitted`` and refills.)
                raise RuntimeError(
                    f"scheduler wedged: {len(queue)} queued, "
                    f"{len(self._preempted)} preempted, 0 active, "
                    f"pool {self.pool and self.pool.free_pages} pages free")
        # replies still in flight belong to retired slots — discard them
        # unbilled (they were never delivered) so a reused channel can
        # never leak them into a later run
        self.late_drops += self.channel.drop_in_flight()
        self._arrival_hint = None
        self.last_virtual_time = self.vnow - v0
        return results, stats


def run_multi(scheds: Sequence[BatchScheduler],
              request_lists: Sequence[Sequence[Request]]):
    """Drive several ``BatchScheduler``s (edge engines) in lockstep rounds
    against one shared cloud (paper §5: N edge clients, one server).

    Each engine keeps its own virtual clock, channel and edge caches; the
    cloud side is shared — a ``CloudServicePoint`` (timing) common to the
    engines' channels and, in cloud-batch mode, a ``CloudBatcher``
    (compute) that coalesces the round's concurrent requests into one
    masked cloud step.  Returns (per-engine token lists, per-engine
    stats, virtual makespan across engines).

    An engine handed an empty request list stays idle: its clock never
    advances, so it contributes ``0`` to the makespan ``max`` and cannot
    skew it (``workload.split_clients`` caps the fan-out but callers may
    still round-robin fewer requests than engines)."""
    queues = []
    for reqs, s in zip(request_lists, scheds):
        for i, r in enumerate(reqs):
            r.index = i
            r.arrival_t += s.vnow     # rebase trace time onto engine clock
        queues.append(collections.deque(
            sorted(reqs, key=lambda r: (r.arrival_t, r.index))))
    results = [[None] * len(rs) for rs in request_lists]
    stats = [[None] * len(rs) for rs in request_lists]
    v0 = [s.vnow for s in scheds]
    services = {}
    for s in scheds:
        s.late_drops = 0
        s._tick_no = 0
        s.channel.reset()
        svc = getattr(s.channel, "service", None)
        if svc is not None:
            services[id(svc)] = svc
    for svc in services.values():
        svc.reset()      # shared points are reset once per run, not per channel

    def busy(i: int) -> bool:
        return (bool(queues[i]) or bool(scheds[i]._preempted)
                or any(sl.active for sl in scheds[i].slots))

    while any(busy(i) for i in range(len(scheds))):
        progressed = False
        for i, s in enumerate(scheds):
            if not busy(i):
                continue
            s._arrival_hint = (queues[i][0].arrival_t if queues[i]
                               else None)
            progressed |= s._admit(queues[i])
            s._collect(results[i], stats[i])
            if any(sl.active for sl in s.slots):
                s.tick()
                s._collect(results[i], stats[i])
                progressed = True
            elif queues[i] and queues[i][0].arrival_t > s.vnow:
                # open-loop gap: this engine is empty until its next
                # arrival — jumping its private clock there IS progress
                s._idle_s += queues[i][0].arrival_t - s.vnow
                s.vnow = queues[i][0].arrival_t
                progressed = True
        if not progressed:
            raise RuntimeError(
                "multi-engine scheduler wedged: requests queued but no "
                "engine can admit or tick (shared cloud slots/pages "
                "exhausted with nothing running?)")
    for s, v in zip(scheds, v0):
        s.late_drops += s.channel.drop_in_flight()
        s._arrival_hint = None
        s.last_virtual_time = s.vnow - v
    makespan = max(s.last_virtual_time for s in scheds)
    return results, stats, makespan


class ServingSystem:
    """End-to-end multi-client co-inference."""

    def __init__(self, model: Model, params: Pytree,
                 ccfg: CollmConfig = CollmConfig()):
        self.model = model
        self.ccfg = ccfg
        self.collm = CoLLM(model, ccfg)
        # with ccfg.cloud_mesh set, commit the params to the cloud mesh
        # once — every scheduler / CloudBatcher below shares the placed
        # tree (identity without a mesh, the single-device default)
        self.params = mesh_context(self.collm).shard_params(params)
        self.cloud = CloudServer(self.collm, self.params)
        self._schedulers: Dict[tuple, BatchScheduler] = {}

    # ------------------------------------------------------------------
    def generate(self, prompts: Sequence[np.ndarray], max_new: int,
                 mode: str = "collm", max_seq: Optional[int] = None,
                 *, num_slots: Optional[int] = None,
                 sampler: str = "greedy", temperature: float = 1.0,
                 top_k: int = 0, eos_id: Optional[int] = None,
                 seed: int = 0, max_ctx: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 channel: Optional[CloudChannel] = None,
                 tick_time_s: float = 0.0, overlap: bool = True,
                 fallback_after: int = 0, watermark: int = 0,
                 preempt_schedule: Optional[Sequence] = None,
                 arrivals: Optional[Sequence[float]] = None,
                 slo_ttft_s: Optional[float] = None,
                 slo_tpot_s: Optional[float] = None,
                 adaptive: Optional[AdaptiveConfig] = None,
                 resume_cost: Optional[ResumeCostModel] = None
                 ) -> Dict[str, Any]:
        """mode: collm | standalone | cloud.  One client per prompt, decoded
        by the continuous-batching ``BatchScheduler`` (num_slots streams in
        flight; defaults to min(len(prompts), 8)).  The KV layout follows
        ``CollmConfig.kv_layout``; ``max_ctx``/``num_pages`` size the paged
        pool (defaults: max_ctx = max_seq, num_pages = dense-equivalent).

        ``channel`` selects the cloud transport (default: blocking-
        equivalent ``SyncChannel``); ``tick_time_s`` is the virtual edge
        compute per decode tick, ``overlap=False`` degrades the dispatch
        to a blocking drain, and ``fallback_after`` N consecutive deadline
        misses flips a stream to standalone mode.  The result dict gains
        ``virtual_time`` (this run's virtual makespan), ``late_drops``,
        and ``channel_stats``.

        Under ``CollmConfig.preemption != "off"`` the paged pool admits
        optimistically and preempts victims when pages run dry;
        ``watermark`` holds that many free pages back from admission as
        decode headroom, and ``preempt_schedule`` ([(tick, slot), ...])
        force-preempts specific slots at specific ticks (test hook —
        preemption is token-invisible either way).

        Open-loop replay (docs/fleet_sim.md): ``arrivals`` stamps one
        virtual arrival time per prompt (admission waits for it);
        ``slo_ttft_s`` / ``slo_tpot_s`` arm per-request SLO targets the
        stats fold into ``slo_attainment``; ``adaptive`` turns on the
        engine-side control loops and ``resume_cost`` prices preemption
        resumes into the virtual clock (both arms of a static-vs-adaptive
        comparison should share one ``ResumeCostModel``)."""
        if arrivals is not None and len(arrivals) != len(prompts):
            raise ValueError(f"need one arrival time per prompt "
                             f"({len(arrivals)} != {len(prompts)})")
        slots = num_slots or max(1, min(len(prompts), 8))
        longest = max(len(p) for p in prompts)
        max_seq = max_seq or (longest + max_new + 8)
        max_seq = max(max_seq, _bucket(longest))
        sched_tuple = (tuple((int(t), int(i)) for t, i in preempt_schedule)
                       if preempt_schedule else None)
        key = (mode, slots, max_seq, sampler, temperature, top_k, seed,
               max_ctx, num_pages,
               id(channel) if channel is not None else None,
               tick_time_s, overlap, fallback_after, watermark, sched_tuple,
               dataclasses.astuple(adaptive) if adaptive is not None
               else None,
               dataclasses.astuple(resume_cost) if resume_cost is not None
               else None)
        sched = self._schedulers.get(key)
        if sched is None:
            # bounded cache: each scheduler owns pooled device caches
            # (slots x max_seq x layers), so evict oldest beyond a few
            while len(self._schedulers) >= 4:
                self._schedulers.pop(next(iter(self._schedulers)))
            sched = BatchScheduler(
                self.collm, self.params, self.cloud.cm, slots, max_seq,
                mode=mode, sampler=sampler, temperature=temperature,
                top_k=top_k, seed=seed, max_ctx=max_ctx, num_pages=num_pages,
                channel=channel, tick_time_s=tick_time_s, overlap=overlap,
                fallback_after=fallback_after, watermark=watermark,
                preempt_schedule=sched_tuple, adaptive=adaptive,
                resume_cost=resume_cost)
            self._schedulers[key] = sched
        reqs = [Request(device_id=f"edge-{i}", prompt=np.asarray(p),
                        max_new=max_new, eos_id=eos_id,
                        arrival_t=(float(arrivals[i])
                                   if arrivals is not None else 0.0),
                        slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s)
                for i, p in enumerate(prompts)]
        results, stats = sched.run(reqs)
        return {"tokens": results, "stats": _aggregate(stats),
                "per_client": stats, "cm_stats": self.cloud.cm.stats(),
                "num_slots": slots,
                "virtual_time": sched.last_virtual_time,
                "late_drops": sched.late_drops,
                "channel_stats": sched.channel.stats.as_row(),
                "preemptions": sched.preemptions, "oops": sched.oops,
                "adaptive": (sched._adaptive.as_row()
                             if sched._adaptive is not None else None),
                "pool_stats": (dataclasses.asdict(sched.pool.stats)
                               if sched.pool is not None else None)}

    # ------------------------------------------------------------------
    def generate_multi(self, prompts: Sequence[np.ndarray], max_new: int,
                       *, n_engines: Optional[int] = None,
                       mode: str = "collm", max_seq: Optional[int] = None,
                       eos_id: Optional[int] = None,
                       cloud_batch: bool = True,
                       max_batch: Optional[int] = None,
                       channels: Optional[Sequence[CloudChannel]] = None,
                       preempt_schedules: Optional[Sequence] = None,
                       tick_time_s: float = 0.0, overlap: bool = True,
                       fallback_after: int = 0,
                       arrivals: Optional[Sequence[float]] = None,
                       slo_ttft_s: Optional[float] = None,
                       slo_tpot_s: Optional[float] = None) -> Dict[str, Any]:
        """Multi-client mode (paper §5): each edge client is its own
        single-slot ``BatchScheduler`` with its own channel and virtual
        clock; all of them share ONE cloud.

        With ``cloud_batch`` (default) a shared ``CloudBatcher`` serves
        every client out of a pooled batch-major cloud cache, coalescing
        concurrent below-θ requests from different engines into one
        masked cloud step; with ``cloud_batch=False`` each engine computes
        its own cloud calls (the per-request FIFO cloud the batcher is
        benchmarked against — same tokens, different virtual makespan).

        ``channels`` optionally provides one ``CloudChannel`` per engine —
        e.g. ``AsyncSimChannel``s sharing a ``CloudServicePoint`` so their
        requests contend in (FIFO) or coalesce at (batched) the same
        virtual cloud queue.  Defaults to a ``SyncChannel`` each, in which
        case the streams are token-identical to independent
        ``generate()`` runs.  Returns the usual result dict plus
        ``n_engines`` and, in cloud-batch mode, the batcher's stats row.

        ``arrivals`` / ``slo_ttft_s`` / ``slo_tpot_s`` mirror
        ``generate()``: open-loop fleet replay stamps one virtual arrival
        per prompt and each engine admits its requests in arrival order
        (docs/fleet_sim.md)."""
        n = n_engines or len(prompts)
        if channels is not None and len(channels) != n:
            raise ValueError(f"need one channel per engine "
                             f"({len(channels)} != {n})")
        if arrivals is not None and len(arrivals) != len(prompts):
            raise ValueError(f"need one arrival time per prompt "
                             f"({len(arrivals)} != {len(prompts)})")
        longest = max(len(p) for p in prompts)
        max_seq = max_seq or (longest + max_new + 8)
        max_seq = max(max_seq, _bucket(longest))
        batcher = None
        if cloud_batch and mode == "collm":
            batcher = CloudBatcher(self.collm, self.params, self.cloud.cm,
                                   n, max_seq, max_batch=max_batch)
        scheds = [BatchScheduler(
            self.collm, self.params, self.cloud.cm, 1, max_seq, mode=mode,
            channel=(channels[i] if channels is not None else None),
            tick_time_s=tick_time_s, overlap=overlap,
            fallback_after=fallback_after, cloud_batcher=batcher,
            preempt_schedule=(preempt_schedules[i]
                              if preempt_schedules is not None else None))
            for i in range(n)]
        per_engine = [[] for _ in range(n)]
        assign = [[] for _ in range(n)]
        for j, p in enumerate(prompts):
            per_engine[j % n].append(Request(
                device_id=f"edge-{j}", prompt=np.asarray(p),
                max_new=max_new, eos_id=eos_id,
                arrival_t=(float(arrivals[j])
                           if arrivals is not None else 0.0),
                slo_ttft_s=slo_ttft_s, slo_tpot_s=slo_tpot_s))
            assign[j % n].append(j)
        results, stats, makespan = run_multi(scheds, per_engine)
        tokens: List[Optional[List[int]]] = [None] * len(prompts)
        flat: List[Optional[GenStats]] = [None] * len(prompts)
        for e in range(n):
            for k, j in enumerate(assign[e]):
                tokens[j] = results[e][k]
                flat[j] = stats[e][k]
        ch_agg = ChannelStats()
        for s in scheds:
            for f in dataclasses.fields(ChannelStats):
                setattr(ch_agg, f.name, getattr(ch_agg, f.name)
                        + getattr(s.channel.stats, f.name))
        out = {"tokens": tokens, "stats": _aggregate(flat),
               "per_client": flat, "cm_stats": self.cloud.cm.stats(),
               "n_engines": n, "virtual_time": makespan,
               "late_drops": sum(s.late_drops for s in scheds),
               "channel_stats": ch_agg.as_row()}
        if batcher is not None:
            # the batched wave compute runs in the batcher, not in any one
            # engine's dispatch: fold it into the aggregate so cloud_time
            # stays comparable with non-batched runs (it cannot be
            # attributed per client — per_client entries carry only each
            # stream's own admit/submit time)
            out["stats"].cloud_time += batcher.stats.cloud_time
            out["batcher"] = batcher.stats.as_row()
        return out

    # ------------------------------------------------------------------
    def generate_sequential(self, prompts: Sequence[np.ndarray], max_new: int,
                            mode: str = "collm",
                            max_seq: Optional[int] = None,
                            channel: Optional[CloudChannel] = None
                            ) -> Dict[str, Any]:
        """The seed's per-client loops (batch=1, one Python iteration per
        token) — reference implementation and throughput baseline.
        ``channel`` optionally shares one cloud channel across the clients
        (wire-accounting tests read its stats); default: a fresh blocking
        ``SyncChannel`` per client."""
        max_seq = max_seq or (max(len(p) for p in prompts) + max_new + 8)
        results, stats = [], []
        for i, prompt in enumerate(prompts):
            toks, st = self._generate_one(f"edge-{i}", np.asarray(prompt),
                                          max_new, mode, max_seq,
                                          channel=channel)
            results.append(toks)
            stats.append(st)
        return {"tokens": results, "stats": _aggregate(stats),
                "per_client": stats, "cm_stats": self.cloud.cm.stats()}

    # ------------------------------------------------------------------
    def _generate_one(self, device_id: str, prompt: np.ndarray, max_new: int,
                      mode: str, max_seq: int,
                      channel: Optional[CloudChannel] = None):
        model, collm, params = self.model, self.collm, self.params
        st = GenStats()
        if channel is None:
            channel = SyncChannel()  # the one cloud-request path (blocking)
        batch = {"tokens": jnp.asarray(prompt[None, :])}

        if mode == "cloud":
            caches = model.init_cache(1, max_seq)
            t0 = time.perf_counter()
            x, _, caches, _ = model.prefill(params, batch, caches)
            tok = jnp.argmax(model.logits(params, x[:, -1:])[:, 0], -1)
            toks = [int(tok[0])]
            pos = len(prompt)
            for _ in range(max_new - 1):
                tok, _, caches = collm.full_step(
                    params, tok[:, None].astype(jnp.int32), caches,
                    jnp.asarray(pos, jnp.int32))
                toks.append(int(tok[0]))
                pos += 1
            st.cloud_time += time.perf_counter() - t0
            st.tokens = len(toks)
            return toks, st

        client = EdgeClient(collm, params, device_id, 1, max_seq)
        t0 = time.perf_counter()
        decisions, h1_seq = client.prefill(batch)
        st.edge_time += time.perf_counter() - t0

        prefill_logits = None
        if mode == "collm":
            enc = None  # enc-dec handled by uploading enc_out once (DESIGN)
            t0 = time.perf_counter()
            prefill_logits = self.cloud.register(device_id, 1, max_seq,
                                                 h1_prompt=h1_seq, enc_out=enc)
            st.cloud_time += time.perf_counter() - t0
            # prompt upload crosses the wire in the configured format
            st.upload_bytes += hidden_wire_bytes(
                model.cfg.d_model, self.ccfg.wire_format,
                seq=h1_seq.shape[1])

        # first token from the prompt's last position
        from repro.core.exits import first_confident_exit
        tok_arr, exited, _ = first_confident_exit(decisions, collm.ccfg.theta)
        if mode == "standalone":
            tok = int(decisions[collm.l_ee2].token[0])
        elif bool(exited[0]) or mode != "collm":
            tok = int(tok_arr[0])
        else:
            # cloud already prefilled through the prompt: its last-position
            # logits ARE the cloud answer for the first token
            st.cloud_requests += 1
            tok = int(jnp.argmax(prefill_logits[0, 0]))
        toks = [tok]
        st.tokens += 1

        for _ in range(max_new - 1):
            t0 = time.perf_counter()
            out = client.step(jnp.asarray([[tok]], jnp.int32))
            st.edge_time += time.perf_counter() - t0
            st.tokens += 1
            confs = {l: float(d.confidence[0])
                     for l, d in out.decisions.items()}
            st.confidences.append((confs.get(collm.l_ee1, 0.0),
                                   confs.get(collm.l_ee2, 0.0)))

            if mode == "standalone":
                tok = int(out.decisions[collm.l_ee2].token[0])
                if confs.get(collm.l_ee1, 0.0) >= collm.ccfg.theta:
                    st.exits_l1 += 1
                else:
                    st.exits_l2 += 1
                toks.append(tok)
                continue

            # parallel upload (always dispatched at l_ee1).  The packet
            # crosses the wire NOW: bill it on the channel once, here —
            # a later request that consumes it (or a backfill ring of
            # them) is a token-sized control message only.
            pkt = StatePacket(hidden=out.upload,
                              pos=jnp.asarray(client.pos - 1))
            self.cloud.receive_upload(device_id, client.pos - 1, pkt)
            st.upload_bytes += pkt.nbytes()
            channel.notify_upload(0, pkt.nbytes(), 0.0)

            if bool(out.exited[0]):
                if confs.get(collm.l_ee1, 0.0) >= collm.ccfg.theta:
                    st.exits_l1 += 1
                else:
                    st.exits_l2 += 1
                tok = int(out.token[0])
            else:
                t0 = time.perf_counter()
                self.cloud.request(channel, device_id, client.pos - 1,
                                   backfill=self.ccfg.backfill)
                (rep,) = channel.poll()
                st.cloud_time += time.perf_counter() - t0
                st.cloud_requests += 1
                tok = int(jnp.argmax(rep.reply[0]))
            toks.append(tok)

        if mode == "collm":
            self.cloud.finish(device_id)
        return toks, st


def token_agreement(a: Sequence[int], b: Sequence[int]) -> float:
    """Longest-common-subsequence F1 — the ROUGE-L proxy used in
    EXPERIMENTS.md to compare strategies' generations."""
    a, b = list(a), list(b)
    if not a or not b:
        return 0.0
    m, n = len(a), len(b)
    dp = np.zeros((m + 1, n + 1), np.int32)
    for i in range(m):
        for j in range(n):
            dp[i + 1, j + 1] = (dp[i, j] + 1 if a[i] == b[j]
                                else max(dp[i, j + 1], dp[i + 1, j]))
    lcs = dp[m, n]
    prec, rec = lcs / m, lcs / n
    return 0.0 if lcs == 0 else 2 * prec * rec / (prec + rec)
