"""Adaptive serving controllers (docs/fleet_sim.md).

Three loops close over knobs that already exist elsewhere in the stack:

  * ``WindowController`` sizes ``CloudServicePoint.batch_window_s`` from
    the observed request arrival rate at the service queue.  A static
    window taxes every request with its full accumulation delay even
    when arrivals are sparse and nothing ever joins the batch; shrinking
    it to zero in the troughs and re-opening it to ~(max_batch-1) mean
    interarrival gaps in the bursts keeps coalescing where it pays and
    removes the tax where it doesn't.

  * ``ResumeCostModel`` prices the two ways a preempted stream can come
    back — re-prefill (fluid-ODE batch-time curve ``d0 + d1 * ctx``) vs
    host page swap (``2 * kv_bytes / host_bw``: out at preempt, in at
    resume) — so the engine can pick per victim instead of globally, and
    so BOTH static and adaptive arms of a comparison pay the same
    physics (the model is a cost *meter*; the adaptive win comes from
    choosing the cheaper mode, never from deleting the cost).

  * ``FluidCapacity`` is the vLLM fluid-ODE capacity curve (SNIPPETS.md
    snippet 1): ``m_total`` tokens of KV memory, ``b_tokens`` of batch
    budget per step, batch time ``d0 + d1 * min(n, b)``.  ``AdaptiveConfig``
    uses it as an admission gate — hold a stream at the door while its
    worst-case residency would push the pool into preemption thrash —
    and ``WatermarkController`` complements it reactively by raising the
    ``PagePool`` watermark (reserved headroom) while ``OutOfPages`` /
    preemption events are observed, decaying it in quiet windows (AIMD).

Everything here runs in virtual time and is deterministic: controllers
observe only virtual-clock quantities, so a fleet replay with fixed
seeds reproduces bit-identical decisions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


# ---------------------------------------------------------------------------
# Cloud batch-window controller (attaches to transport.CloudServicePoint)
# ---------------------------------------------------------------------------
class WindowController:
    """Size the cloud accumulation window from the observed arrival rate.

    ``observe(ready_t, svc)`` is called by ``CloudServicePoint.service``
    with each request's ready time and must return the window to use.
    It keeps an EWMA of interarrival gaps; once warmed up:

      * sparse arrivals (``rate * service_s < sat_threshold``): return 0
        — a window only delays the lone request in its batch;
      * dense arrivals: return ``(max_batch - 1) * mean_gap`` clamped to
        ``max_window_s`` — long enough that a full batch can actually
        accumulate, never longer.
    """

    def __init__(self, *, max_window_s: float = 0.008,
                 sat_threshold: float = 1.0, ewma: float = 0.25,
                 min_obs: int = 4):
        if max_window_s <= 0:
            raise ValueError("max_window_s must be > 0")
        if not 0.0 < ewma <= 1.0:
            raise ValueError("ewma must be in (0, 1]")
        self.max_window_s = float(max_window_s)
        self.sat_threshold = float(sat_threshold)
        self.ewma = float(ewma)
        self.min_obs = int(min_obs)
        self.adjustments = 0       # times the returned window changed
        self.reset()

    def reset(self) -> None:
        self._last_t: Optional[float] = None
        self._mean_gap: Optional[float] = None
        self._n = 0
        self._last_window: Optional[float] = None

    @property
    def mean_gap_s(self) -> Optional[float]:
        return self._mean_gap

    def observe(self, ready_t: float, svc) -> float:
        if self._last_t is None:
            self._last_t = ready_t
            return svc.batch_window_s
        # ready times from different uplinks can interleave slightly out
        # of order; a negative gap carries no rate information
        gap = max(0.0, ready_t - self._last_t)
        self._last_t = max(self._last_t, ready_t)
        self._mean_gap = (gap if self._mean_gap is None else
                          (1 - self.ewma) * self._mean_gap + self.ewma * gap)
        self._n += 1
        if self._n < self.min_obs or self._mean_gap <= 0.0:
            return svc.batch_window_s
        rate = 1.0 / self._mean_gap
        if rate * svc.service_s < self.sat_threshold:
            window = 0.0           # sparse: the window is pure latency tax
        else:
            window = min(self.max_window_s,
                         (svc.max_batch - 1) * self._mean_gap)
        if self._last_window is not None and window != self._last_window:
            self.adjustments += 1
        self._last_window = window
        return window


# ---------------------------------------------------------------------------
# Preemption resume pricing (shared physics for static AND adaptive arms)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ResumeCostModel:
    """Virtual-time price of bringing a preempted stream back.

    ``recompute_s`` follows the fluid-ODE batch-time curve (a re-prefill
    is one batch over ``ctx`` tokens); ``swap_s`` is the host round trip
    of the victim's KV bytes (page-out at preempt + page-in at resume).
    The engine bills the chosen mode's cost into its virtual clock at
    resume time; ``prefer_swap`` is the per-victim decision rule the
    adaptive controller applies with the *same* model."""
    d0_s: float = 0.004            # fixed batch overhead (re-prefill)
    d1_s: float = 2.0e-4           # per-context-token re-prefill time
    host_bw: float = 1.0e9         # host<->device bandwidth, bytes/s

    def recompute_s(self, ctx_tokens: int) -> float:
        return self.d0_s + self.d1_s * max(0, int(ctx_tokens))

    def swap_s(self, kv_bytes: int) -> float:
        return 2.0 * max(0, int(kv_bytes)) / self.host_bw

    def prefer_swap(self, ctx_tokens: int, kv_bytes: int) -> bool:
        """Short contexts re-prefill faster than their pages round-trip
        the host; long contexts flip — the crossover is exactly where
        the two curves meet."""
        return self.swap_s(kv_bytes) < self.recompute_s(ctx_tokens)


# ---------------------------------------------------------------------------
# Fluid-ODE capacity curve (SNIPPETS.md snippet 1: M_total / B / d0 / d1)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FluidCapacity:
    """The cheap-to-evaluate capacity model an admission controller can
    consult before accepting work: ``m_total`` tokens of KV memory,
    ``b_tokens`` of per-step batch budget, batch time ``d0 + d1 * n``."""
    m_total: int                   # KV memory capacity, in tokens
    b_tokens: int                  # batch token budget per step
    d0_s: float = 0.004
    d1_s: float = 2.0e-4

    def batch_time_s(self, n_tokens: int) -> float:
        return self.d0_s + self.d1_s * min(max(0, n_tokens), self.b_tokens)

    def throughput(self, n_tokens: int) -> float:
        """Steady-state tokens/s when ``n_tokens`` are resident."""
        n = min(max(0, n_tokens), self.b_tokens)
        return n / self.batch_time_s(n) if n else 0.0

    def can_admit(self, resident_tokens: int, active_streams: int,
                  new_tokens: int) -> bool:
        """Admission gate: the stream's worst-case residency must fit the
        memory curve AND the step must have batch budget for one more
        decoding stream — admitting past either point converts admission
        into guaranteed preemption churn."""
        if resident_tokens + new_tokens > self.m_total:
            return False
        return active_streams + 1 <= self.b_tokens


# ---------------------------------------------------------------------------
# PagePool watermark AIMD + per-victim mode choice + admission gate
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AdaptiveConfig:
    """Knobs for the engine-side adaptive loops (``BatchScheduler``
    consults an ``AdaptiveController`` built from this)."""
    interval_ticks: int = 8        # controller cadence, in scheduler ticks
    watermark_max_frac: float = 0.25   # AIMD ceiling as a pool fraction
    quiet_intervals: int = 4       # decay the watermark after this many
                                   # event-free intervals
    adapt_resume_mode: bool = True     # per-victim swap-vs-recompute
    capacity: Optional[FluidCapacity] = None   # None: derive from pool
    gate_admission: bool = True    # consult the fluid curve at admission


class AdaptiveController:
    """Engine-side adaptive loop: watermark AIMD + fluid admission gate.

    Stateless with respect to the engine except through public knobs
    (``pool.watermark``) and observed counters (``preemptions``,
    ``oops``); ``on_tick`` is called once per scheduler tick and is a
    no-op between intervals."""

    def __init__(self, cfg: AdaptiveConfig):
        self.cfg = cfg
        self.capacity: Optional[FluidCapacity] = cfg.capacity
        self.watermark_raises = 0
        self.watermark_decays = 0
        self.gate_holds = 0        # admissions delayed by the fluid gate
        self._last_tick = 0
        self._last_events = 0
        self._quiet = 0
        self._floor = 0
        self._ceiling = 0

    def attach(self, pool, resume_cost: Optional[ResumeCostModel]) -> None:
        """Derive unset pieces from the engine's actual pool geometry."""
        self._floor = pool.watermark
        self._ceiling = max(self._floor,
                            int(pool.num_pages * self.cfg.watermark_max_frac))
        if self.capacity is None:
            rc = resume_cost or ResumeCostModel()
            self.capacity = FluidCapacity(
                m_total=pool.num_pages * pool.page_size,
                b_tokens=max(1, pool.num_slots),
                d0_s=rc.d0_s, d1_s=rc.d1_s)

    def on_tick(self, tick_no: int, pool, preemptions: int,
                oops: int) -> None:
        """AIMD on the pool watermark: additive increase while the window
        saw preemption/OutOfPages pressure, multiplicative-ish decrease
        (one page per quiet streak) back toward the configured floor."""
        if tick_no - self._last_tick < self.cfg.interval_ticks:
            return
        self._last_tick = tick_no
        events = (preemptions + oops) - self._last_events
        self._last_events = preemptions + oops
        if events > 0:
            self._quiet = 0
            new = min(self._ceiling, pool.watermark + max(1, events))
            if new != pool.watermark:
                pool.watermark = new
                self.watermark_raises += 1
        else:
            self._quiet += 1
            if (self._quiet >= self.cfg.quiet_intervals
                    and pool.watermark > self._floor):
                pool.watermark -= 1
                self._quiet = 0
                self.watermark_decays += 1

    def admit_ok(self, resident_tokens: int, active_streams: int,
                 new_tokens: int) -> bool:
        if not self.cfg.gate_admission or self.capacity is None:
            return True
        ok = self.capacity.can_admit(resident_tokens, active_streams,
                                     new_tokens)
        if not ok:
            self.gate_holds += 1
        return ok

    def as_row(self) -> dict:
        return {"watermark_raises": self.watermark_raises,
                "watermark_decays": self.watermark_decays,
                "gate_holds": self.gate_holds}
