"""Cloud-side continuous batching across engines (paper §5, Fig 4).

The paper's central experiment is N edge clients sharing ONE cloud
server.  Up to PR 3 the cloud side still executed one cloud step per
client request — the shared-FIFO saturation knee existed only inside the
``netsim`` simulator.  The **CloudBatcher** makes it real: it is the cloud
service point's *compute* half.

  * every co-inference client stream owns one row of a pooled,
    batch-major cloud KV cache (the ``ContentManager`` maps
    ``device_id -> cloud slot``; under ``kv_layout="paged"`` the rows
    share a ``PagePool`` exactly like the edge engine's);
  * edge engines submit single-token cloud requests (the uploaded l_ee1
    packet is popped from the ContentManager at submit time, preserving
    the release/backfill semantics of the per-engine path);
  * pending requests from *any* engine are coalesced into waves — at most
    one request per cloud slot, up to ``max_batch`` rows — and each wave
    is ONE masked ``cloud_step_masked`` (or ``ring_cloud_steps`` in
    backfill mode) over the pooled cache;
  * each request's still-on-device logits fan back out through the
    requester's own ``CloudChannel``; arrival times are priced by the
    channels' shared ``transport.CloudServicePoint`` (the timing half),
    so per-client latencies stay correct.

Flushes are lazy: requests queue until an engine materializes a reply
(the reply payload carries a ``flush`` hook) or ``flush()`` is called.
Under the multi-engine driver this means one lockstep round of N engines
lands N clients' requests in one wave — one masked cloud step for N edge
clients.

This module must not import ``repro.serving.engine`` (the engine imports
it); the pooled-cache scatter helpers live here and the engine reuses
them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collm import CoLLM
from repro.core.content_manager import ContentManager
from repro.core.paging import PagePool, pages_needed
from repro.models.attention import paged_reset_pages, paged_scatter_prefill

Pytree = Any


# ---------------------------------------------------------------------------
# pooled-cache helpers (shared with the edge engine)
# ---------------------------------------------------------------------------
def _bucket(n: int, floor: int = 8) -> int:
    """Next power-of-two length bucket >= n (bounds prefill recompiles)."""
    b = floor
    while b < n:
        b *= 2
    return b


def _put_row(f: jax.Array, r: jax.Array, j) -> jax.Array:
    """Insert one cache row into a pooled leaf; the batch axis is located
    by shape mismatch (stacked segments carry batch at axis 1, shared
    segments at axis 0)."""
    if f.shape == r.shape:                          # pool of size 1
        return r.astype(f.dtype)
    axis = next(i for i, (a, b) in enumerate(zip(f.shape, r.shape))
                if a != b)
    return jax.lax.dynamic_update_slice_in_dim(f, r.astype(f.dtype), j, axis)


def _scatter_row(full: Pytree, row: Pytree, j) -> Pytree:
    """Insert a single-row cache pytree into a batched pool at row j."""
    return jax.tree.map(lambda f, r: _put_row(f, r, j), full, row)


def _scatter_row_paged(full: Pytree, row: Pytree, j,
                       pages: jax.Array) -> Pytree:
    """Paged admission scatter: self-attention K/V of the prefilled row is
    written into its allocated physical pages (``pages``: one id per
    logical prompt page, -1 entries redirect to the trash page); every
    other cache leaf (cross-attn, recurrent state) is a dense per-row
    scatter at row j exactly like the dense layout."""
    def go(f: Pytree, r: Pytree) -> Pytree:
        if isinstance(f, dict):
            if "kp" in f:
                if f["kp"].ndim == 5:       # stacked: (L, P, ps, KV, d)
                    return jax.vmap(paged_scatter_prefill,
                                    in_axes=(0, 0, None))(f, r, pages)
                return paged_scatter_prefill(f, r, pages)
            return {k: go(f[k], r[k]) for k in f}
        return _put_row(f, r, j)
    return {si: go(full[si], row[si]) for si in full}


def _reset_pages_tree(caches: Pytree, pages: jax.Array) -> Pytree:
    """Invalidate freed physical pages across every paged cache node, so a
    page returned to the free list never leaks a retired stream's K/V."""
    def go(c: Pytree) -> Pytree:
        if isinstance(c, dict):
            if "kp" in c:
                if c["kp"].ndim == 5:
                    return jax.vmap(paged_reset_pages,
                                    in_axes=(0, None))(c, pages)
                return paged_reset_pages(c, pages)
            return {k: go(v) for k, v in c.items()}
        return c
    return {si: go(c) for si, c in caches.items()}


# one jitted wrapper per process, shared by every scheduler and batcher —
# schedulers are spawned per client in multi-engine mode and must not each
# re-trace the scatter/invalidate graphs
SCATTER = jax.jit(_scatter_row)
SCATTER_PAGED = jax.jit(_scatter_row_paged)
RESET_PAGES = jax.jit(_reset_pages_tree)


def _jit(collm: CoLLM, name: str):
    """Per-CoLLM memoized ``jax.jit`` of a bound step method: every
    scheduler/batcher sharing one CoLLM (the multi-engine mode spawns one
    scheduler per client) reuses one traced wrapper instead of re-tracing
    per engine."""
    cache = getattr(collm, "_jit_cache", None)
    if cache is None:
        cache = collm._jit_cache = {}
    if name not in cache:
        cache[name] = jax.jit(getattr(collm, name))
    return cache[name]


# ---------------------------------------------------------------------------
# the batcher
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Entry:
    """One queued cloud request awaiting a batched step."""
    device_id: str
    slot: int                   # cloud pool row
    pos: int
    packets: list               # [(pos, StatePacket), ...]; len > 1 = backfill
    group: dict                 # reply payload shared with the channel


@dataclasses.dataclass
class BatcherStats:
    requests: int = 0
    steps: int = 0              # masked batched cloud calls executed
    rows: int = 0               # summed rows served by those calls
    cancelled: int = 0
    prefills: int = 0
    # host seconds spent in batched wave compute.  Prefill time is NOT
    # included: the admitting engine times admit() and charges it to the
    # admitting stream's GenStats, so summing the two never double-counts.
    cloud_time: float = 0.0

    @property
    def mean_batch(self) -> float:
        return self.rows / self.steps if self.steps else 0.0

    def as_row(self) -> Dict[str, float]:
        return {"requests": self.requests, "steps": self.steps,
                "mean_batch": round(self.mean_batch, 2),
                "cancelled": self.cancelled, "prefills": self.prefills,
                "cloud_time_s": round(self.cloud_time, 4)}


class CloudBatcher:
    """One cloud partition serving N client streams out of a pooled,
    batch-major KV cache — the compute half of the shared cloud service
    point (docs/async_transport.md §cloud service point)."""

    def __init__(self, collm: CoLLM, params: Pytree, cm: ContentManager,
                 num_slots: int, max_seq: int, *,
                 max_batch: Optional[int] = None,
                 max_ctx: Optional[int] = None,
                 num_pages: Optional[int] = None):
        self.collm = collm
        self.params = params
        self.cm = cm
        self.B = num_slots
        self.max_seq = max_seq
        self.max_batch = max_batch or num_slots
        cm.init_cloud_slots(num_slots)

        self.layout = collm.ccfg.kv_layout
        self.pool: Optional[PagePool] = None
        self._tbl_device: Optional[jax.Array] = None
        if self.layout == "paged":
            ps = collm.ccfg.page_size
            self.max_ctx = max_ctx or max_seq
            n_pages = num_pages or num_slots * pages_needed(self.max_ctx, ps)
            self.pool = PagePool(n_pages, ps, num_slots,
                                 pages_needed(self.max_ctx, ps))
            row_seq = _bucket(self.max_ctx)
            self.caches = collm.init_cloud_cache_paged(
                num_slots, self.pool.num_pages, ps)
        else:
            self.max_ctx = max_seq
            row_seq = max_seq
            self.caches = collm.init_cloud_cache(num_slots, max_seq)
        self._row_seq = row_seq
        self._row0 = collm.init_cloud_cache(1, row_seq)

        self._cloud_masked = _jit(collm, "cloud_step_masked")
        self._ring_cloud = _jit(collm, "ring_cloud_steps")
        self._cloud_prefill = _jit(collm, "cloud_prefill_padded")
        self._invalidate_rows = _jit(collm, "invalidate_rows_after")
        self._scatter = SCATTER
        self._scatter_paged = SCATTER_PAGED
        self._reset_pages = RESET_PAGES

        self._pending: List[_Entry] = []
        self.stats = BatcherStats()

    # -- capacity / lifecycle ----------------------------------------------
    def can_admit(self, budget_tokens: int) -> bool:
        """One more stream of ``prompt + max_new`` tokens, right now?"""
        if self.cm.cloud_slots_free() <= 0:
            return False
        if self.pool is not None:
            if pages_needed(budget_tokens, self.pool.page_size) \
                    > self.pool.num_pages:
                raise ValueError(
                    f"stream of {budget_tokens} tokens needs more pages "
                    f"than the cloud pool has ({self.pool.num_pages})")
            return self.pool.can_admit(budget_tokens)
        return True

    def admit(self, device_id: str, h1_seq: jax.Array, true_len: int,
              budget_tokens: int) -> jax.Array:
        """Prefill the cloud partition over the uploaded (padded) prompt
        hidden sequence into the client's pool row; returns the logits at
        the true last position (the cloud answer for the first token),
        still on device."""
        slot = self.cm.assign_cloud_slot(device_id)
        pages = None
        if self.pool is not None:
            self.pool.reserve(slot, budget_tokens)
            n_prompt = pages_needed(true_len, self.pool.page_size)
            for lp in range(n_prompt):
                self.pool.alloc(slot, lp)
            pad = h1_seq.shape[1]
            pages = np.full((pages_needed(pad, self.pool.page_size),),
                            -1, np.int32)
            pages[:n_prompt] = self.pool.block_table[slot, :n_prompt]
            self._tbl_device = None
        logits, row = self._cloud_prefill(self.params, h1_seq, true_len,
                                          self._row0)
        if pages is None:
            self.caches = self._scatter(self.caches, row, slot)
        else:
            self.caches = self._scatter_paged(self.caches, row, slot,
                                              jnp.asarray(pages))
        self.stats.prefills += 1
        return logits

    def release(self, device_id: str) -> None:
        """Stream finished: cancel its queued requests, free its pages
        (invalidated on device), return its pool row."""
        self.cancel(device_id, 0)
        slot = self.cm.release_cloud_slot(device_id)
        if slot is None or self.pool is None:
            return
        freed = self.pool.free_slot(slot)
        self._tbl_device = None
        if not freed:
            return
        ids = np.full((self.pool.max_logical,), -1, np.int32)
        ids[:len(freed)] = freed
        self.caches = self._reset_pages(self.caches, jnp.asarray(ids))

    # -- request path -------------------------------------------------------
    def submit(self, device_id: str, pos: int, *, backfill: bool = False):
        """Queue one single-token cloud request; returns the reply payload
        ``(group, row)`` the engine hands to its channel.  The uploaded
        packet(s) are popped from the ContentManager NOW (submit order =
        per-client pos order), so a later flush computes exactly what a
        per-engine call would have."""
        slot = self.cm.cloud_slot(device_id)
        if slot is None:
            raise KeyError(f"{device_id} has no cloud slot (admit first)")
        if backfill:
            packets = self.cm.take_uploads_upto(device_id, pos)
        else:
            packets = [(pos, self.cm.take_upload(device_id, pos))]
        if self.pool is not None:
            for p, _ in packets:
                lp = p // self.pool.page_size
                if self.pool.block_table[slot, lp] == -1:
                    self.pool.alloc(slot, lp)
                    self._tbl_device = None
        group = {"logits": None, "np": None, "flush": self.flush}
        self._pending.append(_Entry(device_id=device_id, slot=slot, pos=pos,
                                    packets=packets, group=group))
        self.stats.requests += 1
        return group, slot

    def cancel(self, device_id: str, min_pos: int) -> int:
        """Drop queued (not yet computed) requests of one client at
        positions >= ``min_pos`` — a speculative rewind discarded them, or
        the stream retired.  Their replies will late-drop in the engine;
        computing them after an ``invalidate`` would resurrect stale KV."""
        keep = [e for e in self._pending
                if e.device_id != device_id or e.pos < min_pos]
        dropped = len(self._pending) - len(keep)
        self._pending = keep
        self.stats.cancelled += dropped
        return dropped

    def invalidate(self, device_id: str, cut_pos: int) -> None:
        """Speculative rewind support: invalidate the client's cloud KV at
        positions >= ``cut_pos`` (see ``CoLLM.invalidate_rows_after``)."""
        slot = self.cm.cloud_slot(device_id)
        if slot is None:
            return
        cut = np.full((self.B,), np.iinfo(np.int32).max, np.int32)
        cut[slot] = cut_pos
        self.caches = self._invalidate_rows(self.caches, jnp.asarray(cut),
                                            self._block_tbl())

    def flush(self) -> None:
        """Drain the queue in waves: each wave serves at most one request
        per cloud slot (and at most ``max_batch`` rows) with ONE masked
        batched cloud step; every entry's reply group gets the wave's
        still-on-device logits."""
        while self._pending:
            wave, rest, seen = [], [], set()
            for e in self._pending:
                if e.slot in seen or len(wave) >= self.max_batch:
                    rest.append(e)
                else:
                    seen.add(e.slot)
                    wave.append(e)
            self._pending = rest
            self._compute(wave)

    # -- internals ----------------------------------------------------------
    def _block_tbl(self) -> Optional[jax.Array]:
        if self.pool is None:
            return None
        if self._tbl_device is None:
            self._tbl_device = jnp.asarray(self.pool.block_table)
        return self._tbl_device

    def _compute(self, wave: List[_Entry]) -> None:
        t0 = time.perf_counter()
        backfill = any(len(e.packets) > 1 for e in wave)
        mask = np.zeros((self.B,), bool)
        for e in wave:
            mask[e.slot] = True
        first = wave[0].packets[0][1]
        keys = first.hidden.keys()
        if backfill:
            depth = _bucket(max(len(e.packets) for e in wave), floor=1)
            ring = {k: np.zeros(
                (depth, self.B) + np.shape(first.hidden[k])[1:],
                np.asarray(first.hidden[k]).dtype) for k in keys}
            ring_pos = np.zeros((depth, self.B), np.int32)
            valid = np.zeros((depth, self.B), bool)
            for e in wave:
                for i, (p, pkt) in enumerate(e.packets):
                    for k in keys:
                        ring[k][i, e.slot] = np.asarray(pkt.hidden[k])[0]
                    ring_pos[i, e.slot] = p
                    valid[i, e.slot] = True
            logits, self.caches = self._ring_cloud(
                self.params, {k: jnp.asarray(v) for k, v in ring.items()},
                jnp.asarray(ring_pos), jnp.asarray(valid), self.caches,
                self._block_tbl())
        else:
            dense = {k: np.zeros((self.B,) + np.shape(first.hidden[k])[1:],
                                 np.asarray(first.hidden[k]).dtype)
                     for k in keys}
            pos = np.zeros((self.B,), np.int32)
            for e in wave:
                (p, pkt), = e.packets
                for k in keys:
                    dense[k][e.slot] = np.asarray(pkt.hidden[k])[0]
                pos[e.slot] = p
            logits, self.caches = self._cloud_masked(
                self.params, {k: jnp.asarray(v) for k, v in dense.items()},
                self.caches, jnp.asarray(pos), jnp.asarray(mask),
                self._block_tbl())
        for e in wave:
            e.group["logits"] = logits
        self.stats.steps += 1
        self.stats.rows += len(wave)
        self.stats.cloud_time += time.perf_counter() - t0

    def kv_cache_bytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(self.caches))
