"""Cloud-side continuous batching across engines (paper §5, Fig 4).

The paper's central experiment is N edge clients sharing ONE cloud
server.  Up to PR 3 the cloud side still executed one cloud step per
client request — the shared-FIFO saturation knee existed only inside the
``netsim`` simulator.  The **CloudBatcher** makes it real: it is the cloud
service point's *compute* half.

  * every co-inference client stream owns one row of a pooled,
    batch-major cloud KV cache (the ``ContentManager`` maps
    ``device_id -> cloud slot``; under ``kv_layout="paged"`` the rows
    share a ``PagePool`` exactly like the edge engine's);
  * edge engines submit single-token cloud requests (the uploaded l_ee1
    packet is popped from the ContentManager at submit time, preserving
    the release/backfill semantics of the per-engine path) or k-token
    draft verification requests (``submit_draft``; the draft packets were
    popped by the engine at draft time);
  * pending requests from *any* engine are coalesced into waves — at most
    one request per cloud slot, up to ``max_batch`` rows — and each wave
    is ONE masked ``cloud_step_masked`` (or ``ring_cloud_steps`` in
    backfill mode) over the pooled cache;
  * each request's still-on-device logits fan back out through the
    requester's own ``CloudChannel``; arrival times are priced by the
    channels' shared ``transport.CloudServicePoint`` (the timing half),
    so per-client latencies stay correct.

Flushes are lazy: requests queue until an engine materializes a reply
(the reply payload carries a ``flush`` hook) or ``flush()`` is called.
Under the multi-engine driver this means one lockstep round of N engines
lands N clients' requests in one wave — one masked cloud step for N edge
clients.

This module must not import ``repro.serving.engine`` (the engine imports
it); the pooled-cache scatter helpers live here and the engine reuses
them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collm import CoLLM
from repro.core.content_manager import ContentManager
from repro.core.paging import OutOfPages, PagePool, pages_needed
from repro.models.attention import paged_reset_pages, paged_scatter_prefill
from repro.serving.mesh_exec import jit_step, mesh_context

Pytree = Any


def _pad_pages(phys: np.ndarray) -> np.ndarray:
    """Pad a physical-page id list to its power-of-two bucket by repeating
    the last id (duplicate scatter writes of identical data are no-ops),
    bounding the compile count of the swap gather/write graphs."""
    n = len(phys)
    padded = np.empty((_bucket(n, floor=1),), np.int32)
    padded[:n] = phys
    padded[n:] = phys[n - 1]
    return padded


# ---------------------------------------------------------------------------
# pooled-cache helpers (shared with the edge engine)
# ---------------------------------------------------------------------------
def _bucket(n: int, floor: int = 8) -> int:
    """Next power-of-two length bucket >= n (bounds prefill recompiles)."""
    b = floor
    while b < n:
        b *= 2
    return b


def _put_row(f: jax.Array, r: jax.Array, j) -> jax.Array:
    """Insert one cache row into a pooled leaf; the batch axis is located
    by shape mismatch (stacked segments carry batch at axis 1, shared
    segments at axis 0)."""
    if f.shape == r.shape:                          # pool of size 1
        return r.astype(f.dtype)
    axis = next(i for i, (a, b) in enumerate(zip(f.shape, r.shape))
                if a != b)
    return jax.lax.dynamic_update_slice_in_dim(f, r.astype(f.dtype), j, axis)


def _scatter_row(full: Pytree, row: Pytree, j) -> Pytree:
    """Insert a single-row cache pytree into a batched pool at row j."""
    return jax.tree.map(lambda f, r: _put_row(f, r, j), full, row)


def _scatter_row_paged(full: Pytree, row: Pytree, j,
                       pages: jax.Array) -> Pytree:
    """Paged admission scatter: self-attention K/V of the prefilled row is
    written into its allocated physical pages (``pages``: one id per
    logical prompt page, -1 entries redirect to the trash page); every
    other cache leaf (cross-attn, recurrent state) is a dense per-row
    scatter at row j exactly like the dense layout."""
    def go(f: Pytree, r: Pytree) -> Pytree:
        if isinstance(f, dict):
            if "kp" in f:
                if f["kp"].ndim == 5:       # stacked: (L, P, ps, KV, d)
                    return jax.vmap(paged_scatter_prefill,
                                    in_axes=(0, 0, None))(f, r, pages)
                return paged_scatter_prefill(f, r, pages)
            return {k: go(f[k], r[k]) for k in f}
        return _put_row(f, r, j)
    return {si: go(full[si], row[si]) for si in full}


def _reset_pages_tree(caches: Pytree, pages: jax.Array) -> Pytree:
    """Invalidate freed physical pages across every paged cache node, so a
    page returned to the free list never leaks a retired stream's K/V."""
    def go(c: Pytree) -> Pytree:
        if isinstance(c, dict):
            if "kp" in c:
                if c["kp"].ndim == 5:
                    return jax.vmap(paged_reset_pages,
                                    in_axes=(0, None))(c, pages)
                return paged_reset_pages(c, pages)
            return {k: go(v) for k, v in c.items()}
        return c
    return {si: go(c) for si, c in caches.items()}


def build_upload_ring(entries, batch: int):
    """Assemble the dense upload ring for ``ring_cloud_steps`` from
    per-row packet lists.

    ``entries``: [(row, [(pos, StatePacket), ...]), ...] — one entry per
    pool row, packets in consumption order.  Returns ``(ring, ring_pos,
    valid)`` device arrays with the ring depth bucketed to a power of two
    (bounds the scan compile count).  Shared by the engine's backfill
    dispatch, the preemption replay paths, and the CloudBatcher's wave
    compute, so the ring layout can never drift between them."""
    depth = _bucket(max((len(p) for _, p in entries), default=1), floor=1)
    first = next(p for _, pkts in entries for _, p in pkts)
    keys = first.hidden.keys()
    ring = {k: np.zeros((depth, batch) + np.shape(first.hidden[k])[1:],
                        np.asarray(first.hidden[k]).dtype) for k in keys}
    ring_pos = np.zeros((depth, batch), np.int32)
    valid = np.zeros((depth, batch), bool)
    for row, pkts in entries:
        for i, (p, pkt) in enumerate(pkts):
            for k in keys:
                ring[k][i, row] = np.asarray(pkt.hidden[k])[0]
            ring_pos[i, row] = p
            valid[i, row] = True
    return ({k: jnp.asarray(v) for k, v in ring.items()},
            jnp.asarray(ring_pos), jnp.asarray(valid))


def _page_axis(node: Pytree) -> int:
    """Batch/page axis of a paged node's leaves: stacked segments carry a
    leading layer axis (kp: (L, P, ps, KV, d)), shared ones don't."""
    return 1 if node["kp"].ndim == 5 else 0


def _gather_pages_tree(caches: Pytree, phys: jax.Array) -> Pytree:
    """Swap-out: slice the given physical pages out of every paged cache
    node (``{si: {kp, vp, pos}}`` page-axis slices, same tree shape)."""
    def go(c: Pytree) -> Pytree:
        if isinstance(c, dict):
            if "kp" in c:
                ax = _page_axis(c)
                return {k: jnp.take(v, phys, axis=ax) for k, v in c.items()}
            return {k: go(v) for k, v in c.items()}
        return None
    return {si: go(c) for si, c in caches.items()}


def _copy_pages_tree(caches: Pytree, src, dst) -> Pytree:
    """Copy-on-write device half: duplicate physical page ``src`` into
    ``dst`` across every paged cache node.  Generic over the node's
    leaves, so int8 nodes' quantized K/V *and* their scale rows ride
    along; ``src``/``dst`` trace as scalars (one compile for all ids)."""
    def go(c: Pytree) -> Pytree:
        if isinstance(c, dict):
            if "kp" in c:
                if _page_axis(c) == 1:
                    return {k: v.at[:, dst].set(v[:, src])
                            for k, v in c.items()}
                return {k: v.at[dst].set(v[src]) for k, v in c.items()}
            return {k: go(v) for k, v in c.items()}
        return c
    return {si: go(c) for si, c in caches.items()}


def _write_pages_tree(caches: Pytree, phys: jax.Array,
                      data: Pytree) -> Pytree:
    """Swap-in: write snapshotted page contents into (freshly allocated)
    physical pages.  Duplicate ids in ``phys`` carry identical data
    (``_pad_pages``), so overlapping scatters are benign."""
    def go(c: Pytree, d: Pytree) -> Pytree:
        if isinstance(c, dict):
            if "kp" in c:
                if _page_axis(c) == 1:
                    return {k: c[k].at[:, phys].set(
                        d[k].astype(c[k].dtype)) for k in c}
                return {k: c[k].at[phys].set(d[k].astype(c[k].dtype))
                        for k in c}
            return {k: go(c[k], d[k]) for k in c}
        return c
    return {si: go(c, data[si]) for si, c in caches.items()}


def gather_slot_pages(pool: PagePool, slot: int, caches: Pytree):
    """Swap-out core: slice one slot's physical pages out of a paged
    cache tree.  Returns ``(logical, host_tree)`` — the slot's logical
    page indices and the device-fetched page contents (None when the slot
    owns nothing)."""
    tbl_row = pool.block_table[slot]
    logical = np.nonzero(tbl_row >= 0)[0].astype(np.int32)
    if not len(logical):
        return logical, None
    padded = jnp.asarray(_pad_pages(tbl_row[logical].astype(np.int32)))
    return logical, jax.device_get(GATHER_PAGES(caches, padded))


def rebind_slot_pages(pool: PagePool, slot: int,
                      logical: np.ndarray) -> jax.Array:
    """Swap-in core: re-allocate a snapshot's logical pages for ``slot``
    (pages are row-agnostic — the block table re-binds them to whatever
    physical ids are free) and return the padded id vector to
    ``WRITE_PAGES`` the snapshot into."""
    for lp in logical:
        pool.alloc(slot, int(lp))
    phys = pool.block_table[slot][logical].astype(np.int32)
    return jnp.asarray(_pad_pages(phys))


def all_paged(caches: Pytree) -> bool:
    """True when every cache leaf lives under a paged ("kp") node — the
    precondition for swap preemption (a dense leaf — recurrent state,
    cross-attention — would be silently lost by a page-only snapshot)."""
    def go(c: Pytree) -> bool:
        if isinstance(c, dict):
            if "kp" in c:
                return True
            return bool(c) and all(go(v) for v in c.values())
        return False
    return all(go(c) for c in caches.values())


# one jitted wrapper per process, shared by every scheduler and batcher —
# schedulers are spawned per client in multi-engine mode and must not each
# re-trace the scatter/invalidate graphs
SCATTER = jax.jit(_scatter_row)
SCATTER_PAGED = jax.jit(_scatter_row_paged)
RESET_PAGES = jax.jit(_reset_pages_tree)
GATHER_PAGES = jax.jit(_gather_pages_tree)
WRITE_PAGES = jax.jit(_write_pages_tree)
COPY_PAGES = jax.jit(_copy_pages_tree)


# per-CoLLM memoized jit of bound step methods now lives in the
# MeshContext (serving/mesh_exec.py): same one-trace-per-CoLLM guarantee,
# but cloud steps are traced under the sharding policy when
# CollmConfig.cloud_mesh is set
_jit = jit_step


# ---------------------------------------------------------------------------
# the batcher
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Entry:
    """One queued cloud request awaiting a batched step."""
    device_id: str
    slot: int                   # cloud pool row
    pos: int
    packets: list               # [(pos, StatePacket), ...]; len > 1 means
                                # backfill ring and/or k-token draft
    group: dict                 # reply payload shared with the channel


@dataclasses.dataclass
class BatcherStats:
    requests: int = 0
    steps: int = 0              # masked batched cloud calls executed
    rows: int = 0               # summed rows served by those calls
    max_rows: int = 0           # peak rows in any single wave (occupancy)
    cancelled: int = 0
    prefills: int = 0
    prefill_chunks: int = 0     # chunked-admission cloud prefill calls
    prefix_hit_tokens: int = 0  # prompt tokens served from shared pages
    restores: int = 0           # preempted-stream cloud-KV replays
    swaps: int = 0              # cloud rows swapped out to host
    # host seconds spent in batched wave compute.  Prefill time is NOT
    # included: the admitting engine times admit() and charges it to the
    # admitting stream's GenStats, so summing the two never double-counts.
    cloud_time: float = 0.0

    @property
    def mean_batch(self) -> float:
        return self.rows / self.steps if self.steps else 0.0

    def as_row(self) -> Dict[str, float]:
        return {"requests": self.requests, "steps": self.steps,
                "mean_batch": round(self.mean_batch, 2),
                "max_batch": self.max_rows,
                "cancelled": self.cancelled, "prefills": self.prefills,
                "prefill_chunks": self.prefill_chunks,
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "restores": self.restores, "swaps": self.swaps,
                "cloud_time_s": round(self.cloud_time, 4)}


class CloudBatcher:
    """One cloud partition serving N client streams out of a pooled,
    batch-major KV cache — the compute half of the shared cloud service
    point (docs/async_transport.md §cloud service point)."""

    def __init__(self, collm: CoLLM, params: Pytree, cm: ContentManager,
                 num_slots: int, max_seq: int, *,
                 max_batch: Optional[int] = None,
                 max_ctx: Optional[int] = None,
                 num_pages: Optional[int] = None):
        self.collm = collm
        # mesh-aware placement (docs/sharding.md): with cloud_mesh set the
        # params and the pooled batch-major cloud KV get committed to the
        # cloud mesh via role-based NamedShardings, and the jitted cloud
        # steps below trace under the sharding policy.  Without a mesh
        # both calls are identity.
        self._mesh = mesh_context(collm)
        self.params = self._mesh.shard_params(params)
        self.cm = cm
        self.B = num_slots
        self.max_seq = max_seq
        self.max_batch = max_batch or num_slots
        cm.init_cloud_slots(num_slots)

        self.layout = collm.ccfg.kv_layout
        self.pool: Optional[PagePool] = None
        self._tbl_device: Optional[jax.Array] = None
        if self.layout == "paged":
            ps = collm.ccfg.page_size
            self.max_ctx = max_ctx or max_seq
            n_pages = num_pages or num_slots * pages_needed(self.max_ctx, ps)
            self.pool = PagePool(n_pages, ps, num_slots,
                                 pages_needed(self.max_ctx, ps),
                                 prefix_cache=collm.ccfg.prefix_share)
            row_seq = _bucket(self.max_ctx)
            self.caches = collm.init_cloud_cache_paged(
                num_slots, self.pool.num_pages, ps)
        else:
            self.max_ctx = max_seq
            row_seq = max_seq
            self.caches = collm.init_cloud_cache(num_slots, max_seq)
        self.caches = self._mesh.shard_caches(self.caches, batch=num_slots)
        self._row_seq = row_seq
        self._row0 = collm.init_cloud_cache(1, row_seq)

        self._cloud_masked = _jit(collm, "cloud_step_masked")
        self._ring_cloud = _jit(collm, "ring_cloud_steps")
        self._ring_cloud_all = _jit(collm, "ring_cloud_steps_all")
        self._cloud_prefill = _jit(collm, "cloud_prefill_padded")
        self._cloud_chunk = _jit(collm, "cloud_prefill_chunk")
        self._invalidate_rows = _jit(collm, "invalidate_rows_after")
        self._scatter = SCATTER
        self._scatter_paged = SCATTER_PAGED
        self._reset_pages = RESET_PAGES

        self._pending: List[_Entry] = []
        self._budget: Dict[str, int] = {}   # device_id -> prompt+max_new
        self.stats = BatcherStats()

    # -- capacity / lifecycle ----------------------------------------------
    def _outstanding_pages(self) -> int:
        """Worst-case pages still owed to admitted streams.  The pool no
        longer keeps a reservation ledger; the batcher stays conservative
        (its rows are not preemptible) by re-deriving the same number from
        each active client's token budget minus what it already owns."""
        out = 0
        for dev, budget in self._budget.items():
            slot = self.cm.cloud_slot(dev)
            if slot is None:
                continue
            out += max(0, pages_needed(budget, self.pool.page_size)
                       - self.pool.owned_pages(slot))
        return out

    def can_admit(self, budget_tokens: int, hit_pages: int = 0) -> bool:
        """One more stream of ``prompt + max_new`` tokens, right now?
        ``hit_pages`` discounts prompt pages a prospective prefix-cache hit
        would map instead of allocating (see ``PagePool.can_admit``), and
        pages held only by the prefix cache count as available — they come
        back on demand through ``evict_prefix``."""
        if self.cm.cloud_slots_free() <= 0:
            return False
        if self.pool is not None:
            need = pages_needed(budget_tokens, self.pool.page_size) \
                - hit_pages
            if need > self.pool.num_pages:
                raise ValueError(
                    f"stream of {budget_tokens} tokens needs more pages "
                    f"than the cloud pool has ({self.pool.num_pages})")
            avail = (self.pool.free_pages + self.pool.reclaimable_pages
                     - self._outstanding_pages())
            return need <= avail
        return True

    def _alloc(self, slot: int, lp: int) -> None:
        """Pool alloc that reclaims prefix-cache pages under pressure: a
        failed alloc first evicts LRU trie entries (their device ``pos``
        markers are invalidated here, so the recycled page cannot leak
        stale K/V) and retries before letting ``OutOfPages`` escape."""
        try:
            self.pool.alloc(slot, lp)
        except OutOfPages:
            freed = self.pool.evict_prefix(1)
            if not freed:
                raise
            ids = np.full((self.pool.max_logical,), -1, np.int32)
            ids[:len(freed)] = freed
            self.caches = self._reset_pages(self.caches, jnp.asarray(ids))
            self.pool.alloc(slot, lp)
        self._tbl_device = None

    def admit(self, device_id: str, h1_seq: jax.Array, true_len: int,
              budget_tokens: int) -> jax.Array:
        """Prefill the cloud partition over the uploaded (padded) prompt
        hidden sequence into the client's pool row; returns the logits at
        the true last position (the cloud answer for the first token),
        still on device."""
        slot = self.cm.assign_cloud_slot(device_id)
        self._budget[device_id] = budget_tokens
        pages = None
        if self.pool is not None:
            n_prompt = pages_needed(true_len, self.pool.page_size)
            for lp in range(n_prompt):
                self._alloc(slot, lp)
            pad = h1_seq.shape[1]
            pages = np.full((pages_needed(pad, self.pool.page_size),),
                            -1, np.int32)
            pages[:n_prompt] = self.pool.block_table[slot, :n_prompt]
            self._tbl_device = None
        logits, row = self._cloud_prefill(self.params, h1_seq, true_len,
                                          self._row0)
        if pages is None:
            self.caches = self._scatter(self.caches, row, slot)
        else:
            self.caches = self._scatter_paged(self.caches, row, slot,
                                              jnp.asarray(pages))
        self.stats.prefills += 1
        return logits

    # -- chunked admission (prefix sharing) --------------------------------
    def prefix_hit(self, tokens) -> int:
        """Full-page prefix hit the batcher's OWN pool could serve for
        this prompt (0 without prefix sharing).  The engine takes the min
        of the edge-side and cloud-side hits, so upload skipping and
        cloud-page sharing stay aligned — a chunk is only skipped when
        BOTH service points already hold it."""
        if self.pool is None or not self.pool.prefix_cache:
            return 0
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        return len(self.pool.match_prefix(toks).pages)

    def admit_begin(self, device_id: str, tokens, true_len: int,
                    budget_tokens: int, hit_pages: int = 0) -> List[int]:
        """Chunked admission, bookkeeping half: assign the cloud row, map
        ``hit_pages`` shared prefix pages out of the batcher's own trie,
        allocate the remaining prompt pages upfront (chunk compute never
        allocates mid-flight), and register the prompt's full chunks for
        future sharers.  Returns the shared page ids — the engine must see
        ``pages_filled`` on them before uploading chunks that attend past
        them (their owning stream may still be mid-prefill)."""
        slot = self.cm.assign_cloud_slot(device_id)
        self._budget[device_id] = budget_tokens
        if self.pool is None:
            return []
        ps = self.pool.page_size
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)[:true_len]]
        shared: List[int] = []
        if hit_pages:
            hit = self.pool.match_prefix(toks)
            shared = list(hit.pages[:hit_pages])
            for lp, page in enumerate(shared):
                self.pool.share_page(slot, lp, page)
            self.pool.stats.prefix_hit_tokens += len(shared) * ps
            self.stats.prefix_hit_tokens += len(shared) * ps
        for lp in range(len(shared), pages_needed(true_len, ps)):
            self._alloc(slot, lp)
        if self.pool.prefix_cache:
            self.pool.insert_prefix(slot, toks)
        self._tbl_device = None
        self.stats.prefills += 1
        return shared

    def admit_chunk(self, device_id: str, h1: jax.Array, pos0: int,
                    chunk_len: int) -> jax.Array:
        """Chunked admission, compute half: cloud-prefill ONE uploaded
        hidden chunk (h1: (1, C, d), right-padded to the page size) into
        the stream's pages.  Returns the logits at the chunk's true last
        position — only the final chunk's matter; earlier chunks run for
        the KV side effect.  ``pos0``/``chunk_len`` trace as scalars, so
        every chunk of every stream shares one compile."""
        slot = self.cm.cloud_slot(device_id)
        if slot is None:
            raise KeyError(f"{device_id} has no cloud slot "
                           "(admit_begin first)")
        row_tbl = jnp.asarray(self.pool.block_table[slot:slot + 1])
        logits, self.caches = self._cloud_chunk(
            self.params, h1, jnp.int32(pos0), jnp.int32(chunk_len),
            self.caches, row_tbl)
        self.stats.prefill_chunks += 1
        ps = self.pool.page_size
        if chunk_len == ps:
            self.pool.mark_filled(
                int(self.pool.block_table[slot, pos0 // ps]))
        return logits

    def pages_filled(self, pages) -> bool:
        """True once every given shared page's owning stream has computed
        its chunk (engine-side stall check for concurrent sharers)."""
        return self.pool is None or self.pool.pages_filled(pages)

    def release(self, device_id: str) -> None:
        """Stream finished (or was preempted): cancel its queued requests,
        free its pages (invalidated on device), return its pool row."""
        self.cancel(device_id, 0)
        self._budget.pop(device_id, None)
        slot = self.cm.release_cloud_slot(device_id)
        if slot is None or self.pool is None:
            return
        freed = self.pool.free_slot(slot)
        self._tbl_device = None
        if not freed:
            return
        ids = np.full((self.pool.max_logical,), -1, np.int32)
        ids[:len(freed)] = freed
        self.caches = self._reset_pages(self.caches, jnp.asarray(ids))

    # -- request path -------------------------------------------------------
    def submit(self, device_id: str, pos: int, *, backfill: bool = False):
        """Queue one single-token cloud request; returns ``(group, row,
        packets)`` — the engine hands ``(group, row)`` to its channel as
        the reply payload and may retain ``packets`` (the consumed
        uploads) for a preemption checkpoint.  The uploaded packet(s) are
        popped from the ContentManager NOW (submit order = per-client pos
        order), so a later flush computes exactly what a per-engine call
        would have."""
        slot = self.cm.cloud_slot(device_id)
        if slot is None:
            raise KeyError(f"{device_id} has no cloud slot (admit first)")
        if backfill:
            packets = self.cm.take_uploads_upto(device_id, pos)
        else:
            packets = [(pos, self.cm.take_upload(device_id, pos))]
        if self.pool is not None:
            for p, _ in packets:
                lp = p // self.pool.page_size
                if self.pool.block_table[slot, lp] == -1:
                    self._alloc(slot, lp)
        group = {"logits": None, "np": None, "flush": self.flush}
        self._pending.append(_Entry(device_id=device_id, slot=slot, pos=pos,
                                    packets=packets, group=group))
        self.stats.requests += 1
        return group, slot, packets

    def submit_draft(self, device_id: str, draft, *, backfill: bool = False):
        """Queue one k-token draft verification request (the engine's
        ``_flush_drafts``).  ``draft``: [(pos, StatePacket), ...] — the
        draft positions' packets in order, popped by the engine at draft
        time (the upload window must never evict a position awaiting
        verification).  Backfill additionally drains the client's
        not-yet-consumed older uploads here, so the merged ring rebuilds
        the exact cloud KV.  Returns ``(group, row, packets)`` like
        ``submit``; ``packets`` is the merged consumption-order list the
        engine indexes the reply's per-position logits with (and may
        retain for a preemption checkpoint).  The reply group carries
        ``all`` / ``np_all``: EVERY ring entry's logits, not just the
        last-valid row."""
        slot = self.cm.cloud_slot(device_id)
        if slot is None:
            raise KeyError(f"{device_id} has no cloud slot (admit first)")
        packets = list(draft)
        if backfill:
            older = self.cm.take_uploads_upto(device_id, packets[-1][0])
            # older positions all precede the draft (the engine flushes on
            # a confident tick, so drafts stay position-contiguous)
            packets = older + packets
        if self.pool is not None:
            for p, _ in packets:
                lp = p // self.pool.page_size
                if self.pool.block_table[slot, lp] == -1:
                    self._alloc(slot, lp)
        group = {"logits": None, "all": None, "np": None, "np_all": None,
                 "flush": self.flush}
        self._pending.append(_Entry(device_id=device_id, slot=slot,
                                    pos=packets[-1][0], packets=packets,
                                    group=group))
        self.stats.requests += 1
        return group, slot, packets

    def cancel(self, device_id: str, min_pos: int) -> int:
        """Drop queued (not yet computed) requests of one client at
        positions >= ``min_pos`` — a speculative rewind discarded them, or
        the stream retired.  Their replies will late-drop in the engine;
        computing them after an ``invalidate`` would resurrect stale KV."""
        keep = [e for e in self._pending
                if e.device_id != device_id or e.pos < min_pos]
        dropped = len(self._pending) - len(keep)
        self._pending = keep
        self.stats.cancelled += dropped
        return dropped

    def invalidate(self, device_id: str, cut_pos: int) -> None:
        """Speculative rewind support: invalidate the client's cloud KV at
        positions >= ``cut_pos`` (see ``CoLLM.invalidate_rows_after``)."""
        slot = self.cm.cloud_slot(device_id)
        if slot is None:
            return
        cut = np.full((self.B,), np.iinfo(np.int32).max, np.int32)
        cut[slot] = cut_pos
        self.caches = self._invalidate_rows(self.caches, jnp.asarray(cut),
                                            self._block_tbl())

    # -- preemption lifecycle ----------------------------------------------
    def restore(self, device_id: str, packets) -> None:
        """Resume (recompute mode): replay a checkpointed stream's
        consumed cloud uploads — positions strictly below the resume
        point — through the cloud partition, rebuilding its pooled-row KV
        to the exact pre-preemption state (release-semantics gaps
        included).  The caller re-``admit``s the prompt prefill first;
        positions at/after the resume point are NOT replayed — re-decode
        re-submits them through the normal request path."""
        slot = self.cm.cloud_slot(device_id)
        if slot is None:
            raise KeyError(f"{device_id} has no cloud slot (admit first)")
        if not packets:
            return
        if self.pool is not None:
            for p, _ in packets:
                lp = p // self.pool.page_size
                if self.pool.block_table[slot, lp] == -1:
                    self._alloc(slot, lp)
        t0 = time.perf_counter()
        ring, ring_pos, valid = build_upload_ring([(slot, packets)], self.B)
        _, self.caches = self._ring_cloud(
            self.params, ring, ring_pos, valid, self.caches,
            self._block_tbl())
        self.stats.restores += 1
        self.stats.cloud_time += time.perf_counter() - t0

    def swap_out(self, device_id: str):
        """Preempt (swap mode): snapshot the stream's cloud-KV pages to
        host memory, then release its row/pages/budget.  Returns the
        snapshot for ``swap_in`` (None when the stream owned nothing).

        Flushes the request queue first: a queued-but-uncomputed entry
        (lazy flush) has consumed its uploads without writing their KV
        yet — snapshotting before the wave runs would freeze the gap and
        ``release``'s cancel would drop the only copy of the packets
        (backfill rings cover positions re-decode never re-uploads).  The
        un-preempted run computes those entries at the next
        materialization anyway, so flushing early changes wave grouping,
        never values."""
        slot = self.cm.cloud_slot(device_id)
        if slot is None or self.pool is None:
            self.release(device_id)
            return None
        if self._pending:
            self.flush()
        logical, pages = gather_slot_pages(self.pool, slot, self.caches)
        if pages is not None:
            self.stats.swaps += 1
        snap = {"logical": logical, "pages": pages,
                "budget": self._budget.get(device_id)}
        self.release(device_id)
        return snap

    def swap_in(self, device_id: str, snap) -> None:
        """Resume (swap mode): re-acquire a cloud row (possibly a
        different one — pages are row-agnostic, the block table re-binds
        them) and write the snapshot back into freshly allocated pages."""
        self.cm.assign_cloud_slot(device_id)
        if snap is None:
            return
        if snap["budget"] is not None:
            self._budget[device_id] = snap["budget"]
        if snap["pages"] is None:
            return
        slot = self.cm.cloud_slot(device_id)
        padded = rebind_slot_pages(self.pool, slot, snap["logical"])
        self.caches = WRITE_PAGES(self.caches, padded, snap["pages"])
        self._tbl_device = None

    def flush(self) -> None:
        """Drain the queue in waves: each wave serves at most one request
        per cloud slot (and at most ``max_batch`` rows) with ONE masked
        batched cloud step; every entry's reply group gets the wave's
        still-on-device logits."""
        while self._pending:
            wave, rest, seen = [], [], set()
            for e in self._pending:
                if e.slot in seen or len(wave) >= self.max_batch:
                    rest.append(e)
                else:
                    seen.add(e.slot)
                    wave.append(e)
            self._pending = rest
            self._compute(wave)

    # -- internals ----------------------------------------------------------
    def _block_tbl(self) -> Optional[jax.Array]:
        if self.pool is None:
            return None
        if self._tbl_device is None:
            self._tbl_device = jnp.asarray(self.pool.block_table)
        return self._tbl_device

    def _compute(self, wave: List[_Entry]) -> None:
        t0 = time.perf_counter()
        # any multi-packet entry (backfill ring OR k-token draft) needs the
        # ring pass; an all-singles wave takes the dense masked step
        ring_mode = any(len(e.packets) > 1 for e in wave)
        mask = np.zeros((self.B,), bool)
        for e in wave:
            mask[e.slot] = True
        first = wave[0].packets[0][1]
        keys = first.hidden.keys()
        if ring_mode:
            ring, ring_pos, valid = build_upload_ring(
                [(e.slot, e.packets) for e in wave], self.B)
            logits, all_logits, self.caches = self._ring_cloud_all(
                self.params, ring, ring_pos, valid, self.caches,
                self._block_tbl())
            for e in wave:
                # draft replies reconcile per position; single-token
                # groups ignore the extra key
                e.group["all"] = all_logits
        else:
            dense = {k: np.zeros((self.B,) + np.shape(first.hidden[k])[1:],
                                 np.asarray(first.hidden[k]).dtype)
                     for k in keys}
            pos = np.zeros((self.B,), np.int32)
            for e in wave:
                (p, pkt), = e.packets
                for k in keys:
                    dense[k][e.slot] = np.asarray(pkt.hidden[k])[0]
                pos[e.slot] = p
            logits, self.caches = self._cloud_masked(
                self.params, {k: jnp.asarray(v) for k, v in dense.items()},
                self.caches, jnp.asarray(pos), jnp.asarray(mask),
                self._block_tbl())
        for e in wave:
            e.group["logits"] = logits
        self.stats.steps += 1
        self.stats.rows += len(wave)
        self.stats.max_rows = max(self.stats.max_rows, len(wave))
        self.stats.cloud_time += time.perf_counter() - t0

    def kv_cache_bytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree.leaves(self.caches))
