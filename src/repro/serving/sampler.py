"""Token samplers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(rng: jax.Array, logits: jax.Array,
                       temperature: float = 1.0, top_k: int = 0) -> jax.Array:
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k:
        vals, _ = jax.lax.top_k(lf, top_k)
        cutoff = vals[..., -1:]
        lf = jnp.where(lf < cutoff, -jnp.inf, lf)
    return jax.random.categorical(rng, lf).astype(jnp.int32)
