"""Token samplers.

``greedy`` / ``temperature_sample`` are the primitives; ``sample`` is the
dispatch the batch scheduler wires into its jitted step (one call samples
every slot of the batch at once)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(rng: jax.Array, logits: jax.Array,
                       temperature: float = 1.0, top_k: int = 0) -> jax.Array:
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k:
        vals, _ = jax.lax.top_k(lf, top_k)
        cutoff = vals[..., -1:]
        lf = jnp.where(lf < cutoff, -jnp.inf, lf)
    return jax.random.categorical(rng, lf).astype(jnp.int32)


def sample(logits: jax.Array, *, method: str = "greedy",
           rng: Optional[jax.Array] = None, temperature: float = 1.0,
           top_k: int = 0) -> jax.Array:
    """Batched sampling dispatch: logits (B, V) -> tokens (B,)."""
    if method == "greedy":
        return greedy(logits)
    if method == "temperature":
        if rng is None:
            raise ValueError("temperature sampling requires an rng key")
        return temperature_sample(rng, logits, temperature, top_k)
    raise ValueError(f"unknown sampler {method!r}")
