"""Multi-exit training loss (EE-LLM style).

total = CE(final) + sum_i w_i * CE(exit_i) + moe aux.  Exit weights follow
EE-LLM's constant weighting (all exits weighted equally at ``exit_weight``).

``fused_unembed_ce`` is the production path: it streams the unembedding
over sequence chunks under ``jax.checkpoint`` so the (B,S,V) logits — f32,
three read-out heads, forward AND backward — are never materialized
(measured ~12 GB/device at command-r train_4k; EXPERIMENTS.md §Perf
iteration 3)."""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array) -> jax.Array:
    """logits: (B,S,V); labels: (B,S) int; mask: (B,S) float."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def fused_unembed_ce(hidden: jax.Array, norm_scale: jax.Array,
                     weight: jax.Array, labels: jax.Array, mask: jax.Array,
                     *, eps: float = 1e-5, chunk: int = 512) -> jax.Array:
    """CE of ``rms_norm(hidden) @ weight.T`` without full logits.

    hidden: (B,S,d); weight: (V,d); labels/mask: (B,S).  Scans seq chunks;
    each chunk's logits are recomputed in the backward pass."""
    from repro.models.common import rms_norm
    b, s, d = hidden.shape
    chunk = math.gcd(s, chunk)
    n = s // chunk

    hc = jnp.moveaxis(hidden.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, n, chunk), 1, 0)

    @jax.checkpoint
    def body(acc, xs):
        h, lab, m = xs
        hn = rms_norm(h, norm_scale, eps)
        logits = jnp.einsum("bcd,vd->bcv", hn,
                            weight.astype(hn.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - ll) * m), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc, mc))
    return total / jnp.maximum(mask.sum(), 1.0)


def multi_exit_loss_fused(model, params, hiddens: Dict[str, Any],
                          labels: jax.Array, mask: jax.Array, *,
                          exit_weight: float = 0.3) -> Dict[str, jax.Array]:
    """Fused-CE variant of ``multi_exit_loss`` working on hidden states.

    ``hiddens``: {"final": (B,S,d), "exits": {layer: (B,S,d)},
    "aux_loss": scalar, "prefix_len": int}."""
    cfg = model.cfg
    w = model.unembed_weight(params)
    prefix = hiddens.get("prefix_len", 0) or 0

    def trim(x):
        return x[:, prefix:] if prefix else x

    if cfg.norm_type == "layernorm":
        # layernorm read-out models (whisper) use the plain path for the
        # final head; exits are rms read-outs everywhere.
        final_logits = model.logits(params, trim(hiddens["final"]))
        main = cross_entropy(final_logits, labels, mask)
    else:
        main = fused_unembed_ce(trim(hiddens["final"]), params["final_norm"],
                                w, labels, mask, eps=cfg.norm_eps)
    total = main
    exit_losses = {}
    for l, h in sorted(hiddens["exits"].items()):
        el = fused_unembed_ce(trim(h), params["exit_norms"][str(l)], w,
                              labels, mask, eps=cfg.norm_eps)
        exit_losses[l] = el
        total = total + exit_weight * el
    total = total + hiddens.get("aux_loss", 0.0)
    return {"loss": total, "main_loss": main,
            "aux_loss": hiddens.get("aux_loss", jnp.zeros(())),
            **{f"exit{l}_loss": v for l, v in exit_losses.items()}}


def multi_exit_loss(outputs: Dict[str, Any], labels: jax.Array,
                    mask: jax.Array, *, exit_weight: float = 0.3
                    ) -> Dict[str, jax.Array]:
    """``outputs`` is Model.forward_train output.  For VLM models the logits
    cover [vision prefix + text]; labels align with the text tail."""
    logits = outputs["logits"]
    prefix = outputs.get("prefix_len", 0) or 0
    if prefix:
        logits = logits[:, prefix:]
    main = cross_entropy(logits, labels, mask)
    exit_losses = {}
    total = main
    for l, xl in sorted(outputs["exit_logits"].items()):
        if prefix:
            xl = xl[:, prefix:]
        el = cross_entropy(xl, labels, mask)
        exit_losses[l] = el
        total = total + exit_weight * el
    total = total + outputs.get("aux_loss", 0.0)
    return {"loss": total, "main_loss": main,
            "aux_loss": outputs.get("aux_loss", jnp.zeros(())),
            **{f"exit{l}_loss": v for l, v in exit_losses.items()}}
