"""Minimal dependency-free checkpointing: pytree <-> npz with a structure
manifest (no orbax on the box)."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(path: str, params: Pytree, extra: dict = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = jax.tree.flatten(params)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(path + ".npz", **arrays)
    manifest = {"n_leaves": len(leaves), "treedef": str(treedef),
                "extra": extra or {}}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, template: Pytree) -> Tuple[Pytree, dict]:
    """Template supplies the pytree structure (e.g. model.init output or
    param_specs)."""
    data = np.load(path + ".npz")
    leaves, treedef = jax.tree.flatten(template)
    if len(leaves) != len(data.files):
        raise ValueError(f"checkpoint has {len(data.files)} leaves, "
                         f"template has {len(leaves)}")
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    with open(path + ".json") as f:
        manifest = json.load(f)
    return jax.tree.unflatten(treedef, new_leaves), manifest.get("extra", {})
