"""jit-able train step: multi-exit LM loss + AdamW."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.training.loss import multi_exit_loss, multi_exit_loss_fused
from repro.training.optim import AdamWConfig, AdamWState, adamw_update

Pytree = Any


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    exit_weight: float = 0.3, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    batch: {"tokens": (B,S), "labels": (B,S), "mask": (B,S)} plus modality
    extras ("frames" / "patches").  Uses the fused chunked unembed+CE
    (never materializes (B,S,V) logits).  ``microbatches>1`` runs gradient
    accumulation over batch slices — activation memory scales 1/M at the
    cost of M sequential passes."""

    def loss_fn(params, batch):
        hiddens = model.forward_train_hiddens(params, batch)
        losses = multi_exit_loss_fused(model, params, hiddens,
                                       batch["labels"], batch["mask"],
                                       exit_weight=exit_weight)
        return losses["loss"], losses

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params: Pytree, opt_state: AdamWState,
                   batch: Dict[str, jax.Array]
                   ) -> Tuple[Pytree, AdamWState, Dict[str, jax.Array]]:
        if microbatches <= 1:
            (_, losses), grads = grads_of(params, batch)
        else:
            b = batch["tokens"].shape[0]
            assert b % microbatches == 0, (b, microbatches)
            mb = {k: v.reshape(microbatches, b // microbatches, *v.shape[1:])
                  for k, v in batch.items()}

            def body(carry, mbatch):
                acc, loss_acc = carry
                (_, losses), g = grads_of(params, mbatch)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / microbatches,
                    acc, g)
                return (acc, loss_acc + losses["loss"] / microbatches), losses

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), all_losses = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mb)
            losses = jax.tree.map(lambda x: x.mean(), all_losses)
            losses["loss"] = loss
        params, opt_state, opt_info = adamw_update(opt_cfg, grads, opt_state,
                                                   params)
        metrics = {**losses, **opt_info}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model, exit_weight: float = 0.3):
    def eval_step(params, batch):
        out = model.forward_train(params, batch)
        return multi_exit_loss(out, batch["labels"], batch["mask"],
                               exit_weight=exit_weight)
    return eval_step
