"""AdamW with decoupled weight decay, global-norm clipping and a
linear-warmup cosine schedule.  Self-contained (no optax on the box)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree


def init_adamw(params: Pytree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, grads: Pytree, state: AdamWState,
                 params: Pytree) -> Tuple[Pytree, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:   # decay matrices only, not norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), {
        "grad_norm": gnorm, "lr": lr}
