"""ee-llm-7b — the paper's own model (EE-LLM 7B, architecturally
LLaMA2-7B with early exits at layers 8 and 16 of 32).
[CE-CoLLM §5; EE-LLM arXiv:2312.04916; llama2 arXiv:2307.09288]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="ee-llm-7b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    exit_layers=(8, 16),           # l_ee1=8, l_ee2=16 (edge partition = 1..16)
    source="CE-CoLLM (Jin & Wu 2024) / EE-LLM 7B",
).validate()
