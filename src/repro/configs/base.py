"""Model / run configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The
config is a plain frozen dataclass so it can be hashed into jit static
arguments and printed into EXPERIMENTS.md verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by models/registry.py
# ---------------------------------------------------------------------------
DENSE = "dense"          # attention + MLP decoder block
MOE = "moe"              # attention + routed-expert block
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block (sequential)
MAMBA2 = "mamba2"        # SSD block
SHARED_ATTN = "shared_attn"  # Zamba2 shared transformer block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Parameters shared by mLSTM / Mamba2 style blocks."""
    state_size: int = 64          # N (mamba2 state dim per head)
    conv_width: int = 4           # depthwise conv width (mamba2)
    expand: int = 2               # inner expansion factor
    chunk_size: int = 256         # chunked-scan block length
    num_ssm_heads: int = 0        # 0 -> derived from d_inner/headdim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 -> full attention
    local_global_pattern: int = 0  # k -> k local layers per 1 global layer
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    use_rope: bool = True          # False -> absolute (sinusoidal) positions
    norm_type: str = "rms"         # "rms" | "layernorm"
    mlp_kind: str = "gated_silu"   # "gated_silu" | "gelu"
    # --- mixture of experts -------------------------------------------------
    moe: Optional[MoEConfig] = None
    # --- ssm / hybrid -------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    hybrid_attn_period: int = 0    # zamba2: shared attn block every k blocks
    # --- enc-dec (audio) ----------------------------------------------------
    encoder_layers: int = 0        # 0 -> decoder-only
    encoder_seq: int = 0           # fixed encoder sequence (e.g. 1500 frames)
    # --- vlm ----------------------------------------------------------------
    vision_tokens: int = 0         # prefix patch-embedding count (stub frontend)
    # --- early exit (the paper's technique) ---------------------------------
    exit_layers: Tuple[int, ...] = ()   # 1-based layer indices with exit heads
    # --- citation -----------------------------------------------------------
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_recurrent(self) -> bool:
        """True when decode state is O(1) (no growing KV for ssm blocks)."""
        return self.arch_type == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode: ssm, hybrid, or sliding-window dense."""
        return self.arch_type in ("ssm", "hybrid") or (
            self.sliding_window > 0 and self.local_global_pattern > 0
        )

    @property
    def has_decode(self) -> bool:
        """All assigned archs have a decoder (whisper is enc-dec)."""
        return True

    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind sequence for the *decoder* stack."""
        if self.arch_type == "moe":
            return (MOE,) * self.n_layers
        if self.arch_type == "ssm":
            # xLSTM: sLSTM block at every 7th position per arXiv:2405.04517
            # ([1:7] sLSTM:mLSTM ratio for the 350M-class model family);
            # remaining blocks mLSTM.
            kinds = []
            for i in range(self.n_layers):
                kinds.append(SLSTM if (i % 7 == 3) else MLSTM)
            return tuple(kinds)
        if self.arch_type == "hybrid":
            # Zamba2: mamba2 backbone, shared attention block applied every
            # `hybrid_attn_period` layers.
            period = self.hybrid_attn_period or 6
            kinds = []
            for i in range(self.n_layers):
                kinds.append(SHARED_ATTN if (i % period == period - 1) else MAMBA2)
            return tuple(kinds)
        return (DENSE,) * self.n_layers

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-decoder-layer sliding window (0 = full attention)."""
        if self.sliding_window and self.local_global_pattern:
            period = self.local_global_pattern + 1
            return tuple(self.sliding_window if (i % period) < self.local_global_pattern
                         else 0 for i in range(self.n_layers))
        if self.sliding_window:
            return (self.sliding_window,) * self.n_layers
        return (0,) * self.n_layers

    def validate(self) -> "ModelConfig":
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name
        if self.exit_layers:
            assert all(1 <= l <= self.n_layers for l in self.exit_layers)
            assert tuple(sorted(self.exit_layers)) == self.exit_layers
        if self.arch_type == "moe":
            assert self.moe is not None
        return self


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            n_heads: int = 4, vocab: int = 512) -> ModelConfig:
    """A smoke-test-sized variant of the same family (assignment rule:
    ≤2 layers, d_model ≤ 512, ≤4 experts)."""
    kv = min(cfg.n_kv_heads, n_heads)
    while n_heads % kv:
        kv -= 1
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, num_experts=4,
                                  top_k=min(2, cfg.moe.top_k),
                                  expert_d_ff=max(64, d_model // 4))
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, state_size=16, chunk_size=32)
    exits = (1,) if n_layers >= 2 else ()
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=d_model // n_heads,
        d_ff=0 if cfg.d_ff == 0 else d_model * 2,
        vocab_size=vocab,
        moe=moe,
        ssm=ssm,
        encoder_layers=min(cfg.encoder_layers, n_layers),
        encoder_seq=min(cfg.encoder_seq, 64),
        vision_tokens=min(cfg.vision_tokens, 16),
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        hybrid_attn_period=2 if cfg.hybrid_attn_period else 0,
        exit_layers=exits,
    ).validate()
