"""Assigned input shapes and the (arch x shape) run matrix rules."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg, shape: InputShape) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic decode archs."""
    if shape.name == "long_500k":
        if cfg.supports_long_decode:
            return True, ""
        return False, (
            f"{cfg.name} is a pure full-attention decoder; long_500k requires "
            "sub-quadratic attention (skip documented in DESIGN.md)")
    return True, ""
