"""Architecture registry: ``--arch <id>`` resolution.

Maps the assigned public-pool ids (with dots/dashes) onto config modules.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import (
    granite_moe_3b_a800m,
    qwen1_5_110b,
    xlstm_350m,
    olmoe_1b_7b,
    gemma3_12b,
    paligemma_3b,
    command_r_35b,
    zamba2_1_2b,
    whisper_medium,
    stablelm_12b,
    ee_llm_7b,
)
from repro.configs.base import ModelConfig, reduced

_MODULES = (
    granite_moe_3b_a800m,
    qwen1_5_110b,
    xlstm_350m,
    olmoe_1b_7b,
    gemma3_12b,
    paligemma_3b,
    command_r_35b,
    zamba2_1_2b,
    whisper_medium,
    stablelm_12b,
    ee_llm_7b,
)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# The 10 assigned architectures (paper's own model excluded from the matrix).
ASSIGNED = tuple(n for n in ARCHS if n != "ee-llm-7b")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_smoke_config(arch: str) -> ModelConfig:
    return reduced(get_config(arch))
