"""granite-moe-3b-a800m  [moe]
32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base family]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                      # per-expert ffn width
    vocab_size=49155,
    qkv_bias=False,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, top_k=8, expert_d_ff=512),
    exit_layers=(8, 16),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
).validate()
