"""olmoe-1b-7b  [moe]
16L d_model=2048 16H (GQA kv=16) per-expert d_ff=1024 vocab=50304,
MoE 64 experts top-8.  [arXiv:2409.02060]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, expert_d_ff=1024),
    exit_layers=(4, 8),
    source="arXiv:2409.02060",
).validate()
