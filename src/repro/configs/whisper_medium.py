"""whisper-medium  [audio]
24L (enc) + 24L (dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865 —
enc-dec; mel-spectrogram + conv frontend is a STUB per assignment
(input_specs provides 1500 precomputed frame embeddings).
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,                   # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    qkv_bias=True,
    use_rope=False,                # sinusoidal absolute positions
    norm_type="layernorm",
    mlp_kind="gelu",
    encoder_layers=24,
    encoder_seq=1500,              # 30 s audio -> 1500 frames after conv stub
    exit_layers=(6, 12),
    source="arXiv:2212.04356",
).validate()
