"""xlstm-350m  [ssm]
24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304 — sLSTM + mLSTM blocks.
[arXiv:2405.04517]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,                         # xLSTM blocks carry their own projections
    vocab_size=50304,
    tie_embeddings=True,
    ssm=SSMConfig(state_size=0, conv_width=4, expand=2, chunk_size=256,
                  num_ssm_heads=4),
    exit_layers=(6, 12),
    source="arXiv:2405.04517",
).validate()
