"""paligemma-3b  [vlm]
18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216 — SigLIP vision
frontend (STUB per assignment: input_specs provides patch embeddings) +
gemma decoder.  [arXiv:2407.07726]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    tie_embeddings=True,
    vision_tokens=256,             # SigLIP 224px/14 -> 256 patch embeddings
    exit_layers=(5, 9),
    source="arXiv:2407.07726",
).validate()
