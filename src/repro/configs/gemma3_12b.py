"""gemma3-12b  [dense]
48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 — 5:1 local:global
sliding-window attention (window 1024), 128k context.  [hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1000000.0,
    sliding_window=1024,
    local_global_pattern=5,        # 5 local layers then 1 global layer
    tie_embeddings=True,
    exit_layers=(12, 24),
    source="hf:google/gemma-3-1b-pt",
).validate()
