"""zamba2-1.2b  [hybrid]
38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64 —
Mamba2 backbone + shared attention blocks (one shared transformer block
applied periodically).  [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,                     # shared attn block ffn width
    vocab_size=32000,
    ssm=SSMConfig(state_size=64, conv_width=4, expand=2, chunk_size=256),
    hybrid_attn_period=6,
    exit_layers=(10, 19),
    source="arXiv:2411.15242",
).validate()
