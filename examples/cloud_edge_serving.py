"""End-to-end driver (the paper's kind: serving): multi-client CE-CoLLM
serving with batched requests, measured exit traces, and a virtual-time
deployment projection through the network simulator.

    PYTHONPATH=src python examples/cloud_edge_serving.py [--clients 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.collm import CollmConfig
from repro.core.netsim import (ComputeParams, ModelSplit, NetworkParams,
                               simulate)
from repro.core.workload import split_clients, traces_from_confidences
from repro.serving.engine import ServingSystem, token_agreement

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import tiny_trained_model  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--theta", type=float, default=0.8)
    args = ap.parse_args()

    print("training the tiny EE model...")
    tt = tiny_trained_model(steps=150)
    model, params, data = tt["model"], tt["params"], tt["data"]
    prompts = [data.sample_tokens(12) for _ in range(args.clients)]

    # ---- real serving: N edge clients against one cloud server ----------
    system = ServingSystem(model, params, CollmConfig(theta=args.theta))
    t0 = time.time()
    r = system.generate(prompts, args.max_new, mode="collm")
    st = r["stats"]
    print(f"\nserved {args.clients} clients x {args.max_new} tokens "
          f"in {time.time()-t0:.1f}s wall")
    print(f"request-cloud rate: {st.request_rate:.1%}  "
          f"uploads: {st.upload_bytes/1e3:.1f} KB")
    print("content manager:", r["cm_stats"])

    base = ServingSystem(model, params, CollmConfig(theta=1.0)).generate(
        prompts, args.max_new, mode="cloud")
    ags = [token_agreement(a, b) for a, b in zip(r["tokens"], base["tokens"])]
    print(f"agreement vs cloud-only: {[round(a,3) for a in ags]}")

    # ---- deployment projection: measured traces -> A100-class virtual time
    per_client = [[] for _ in range(args.clients)]
    for i, c in enumerate(st.confidences):
        per_client[i % args.clients].append(c)
    cases = traces_from_confidences([12] * args.clients,
                                    [c for c in per_client if c])
    cfg = model.cfg
    comp = ComputeParams(edge_layer_time=1.28e-3, cloud_layer_time=1.28e-3,
                         exit_head_time=1e-3)
    split = ModelSplit(n_layers=cfg.n_layers, l_ee1=cfg.exit_layers[0],
                       l_ee2=cfg.exit_layers[-1], d_model=cfg.d_model)
    print("\nvirtual-time projection (per strategy):")
    for strat in ("cloud_llm", "ce_collm", "standalone"):
        res = simulate(strat, split_clients(cases, args.clients),
                       NetworkParams(), comp, split, theta=args.theta)
        print(f"  {strat:10s} total={res.total_time:7.2f}s "
              f"edge={res.edge_time:6.2f}s cloud={res.cloud_time:6.2f}s "
              f"comm={res.comm_time:6.2f}s")


if __name__ == "__main__":
    main()
