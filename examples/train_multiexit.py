"""Train a multi-exit model of any assigned architecture family.

Default trains a reduced variant for a few hundred steps on CPU; pass a
bigger --d-model/--layers (or drop --smoke on a TPU mesh) to scale up.

    PYTHONPATH=src python examples/train_multiexit.py --arch olmoe-1b-7b \
        --steps 120
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main  # noqa: E402


if __name__ == "__main__":
    if "--smoke" not in sys.argv:
        sys.argv.append("--smoke")
    if "--arch" not in " ".join(sys.argv):
        sys.argv += ["--arch", "olmoe-1b-7b"]
    if "--steps" not in " ".join(sys.argv):
        sys.argv += ["--steps", "120"]
    train_main()
