"""Quickstart: train a tiny early-exit LLM and serve it with CE-CoLLM
cloud-edge co-inference — the whole paper in ~60 s on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.collm import CollmConfig
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models.registry import build_model
from repro.serving.engine import ServingSystem, token_agreement
from repro.training.optim import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step


def main():
    # 1. an EE-LLM-style model: exits at layers 1 and 2 of 4
    cfg = ModelConfig(name="quickstart-ee", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=256, tie_embeddings=True,
                      exit_layers=(1, 2)).validate()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 2. multi-exit training (EE-LLM loss: final CE + weighted exit CEs)
    data = SyntheticCorpus(DataConfig(vocab_size=256, seq_len=64,
                                      batch_size=8, kind="markov"))
    step = jax.jit(make_train_step(model, AdamWConfig(
        lr=1e-3, warmup_steps=10, total_steps=300)))
    opt = init_adamw(params)
    print("training 150 steps...")
    for i, b in enumerate(data.batches(150)):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, mets = step(params, opt, batch)
        if i % 50 == 0:
            print(f"  step {i}: loss={float(mets['loss']):.3f} "
                  f"exit1={float(mets['exit1_loss']):.3f} "
                  f"exit2={float(mets['exit2_loss']):.3f}")

    # 3. serve: cloud baseline vs CE-CoLLM at several thresholds
    prompts = [data.sample_tokens(12) for _ in range(3)]
    base = ServingSystem(model, params, CollmConfig(theta=1.0)).generate(
        prompts, 24, mode="cloud")
    print("\n  theta | request-rate | exits@l1/l2 | agreement-vs-cloud")
    for theta in (0.5, 0.8, 0.9, 1.0):
        s = ServingSystem(model, params, CollmConfig(theta=theta))
        r = s.generate(prompts, 24, mode="collm")
        st = r["stats"]
        ag = sum(token_agreement(a, b) for a, b in
                 zip(r["tokens"], base["tokens"])) / len(prompts)
        print(f"  {theta:5.2f} | {st.request_rate:11.1%} | "
              f"{st.exits_l1:4d}/{st.exits_l2:<4d} | {ag:.3f}")

    # 4. edge standalone mode (paper's low-latency mode)
    sa = ServingSystem(model, params, CollmConfig(theta=0.8))
    r = sa.generate(prompts, 24, mode="standalone")
    print(f"\nstandalone: 0 cloud requests, {r['stats'].tokens} tokens "
          f"generated entirely at the edge")


if __name__ == "__main__":
    main()
