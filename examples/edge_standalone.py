"""Edge standalone (low-latency) mode: the edge partition alone, last exit
as output head — plus a per-exit confidence profile (paper Table 1 style).

    PYTHONPATH=src python examples/edge_standalone.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core.collm import CoLLM, CollmConfig

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import tiny_trained_model  # noqa: E402


def main():
    tt = tiny_trained_model(steps=150)
    model, params, data = tt["model"], tt["params"], tt["data"]
    co = CoLLM(model, CollmConfig(theta=0.8))
    prompt = jnp.asarray(data.sample_tokens(12)[None, :])

    caches = co.init_edge_cache(1, 64)
    decisions, _, caches = co.edge_prefill(params, {"tokens": prompt}, caches)

    # paper Table 1: per-exit token + confidence for each generated position
    print(" id | exit1 token (conf)      | exit2 token (conf)")
    tok = decisions[co.l_ee2].token
    t0 = time.time()
    for t in range(16):
        x, exit_h, caches = model.decode_step(
            params, tok[:, None], caches, jnp.asarray(12 + t, jnp.int32),
            co.edge_segs)
        from repro.core.exits import evaluate_exit
        ds = {l: evaluate_exit(model.exit_logits(params, l, h))
              for l, h in exit_h.items()}
        d1, d2 = ds[co.l_ee1], ds[co.l_ee2]
        mark1 = "*" if float(d1.confidence[0]) >= 0.8 else " "
        mark2 = "*" if float(d2.confidence[0]) >= 0.8 else " "
        print(f" {t:2d} | {int(d1.token[0]):6d} ({float(d1.confidence[0]):.3f}){mark1} "
              f"       | {int(d2.token[0]):6d} ({float(d2.confidence[0]):.3f}){mark2}")
        tok = d2.token   # standalone: last exit is the output
    dt = (time.time() - t0) / 16
    print(f"\nedge-standalone latency: {dt*1e3:.1f} ms/token on CPU "
          f"({model.cfg.n_layers} -> {co.l_ee2} layers, no network)")


if __name__ == "__main__":
    main()
