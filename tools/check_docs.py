#!/usr/bin/env python
"""Docs gate for CI (stdlib only, no network).

1. Markdown link check: every relative link in README.md and docs/*.md
   must point at a file (or file#anchor) that exists in the repo.
   External (http/https/mailto) links are not fetched.
2. Sync gate: the tier-1 verify command declared in ROADMAP.md must appear
   verbatim in README.md, so the front door can never drift from the
   command CI actually runs.

Exit code 0 = docs are green; non-zero prints every violation.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — excluding images' leading "!" is unnecessary: image
# targets must exist too.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_TIER1 = re.compile(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`")


def doc_files() -> list[Path]:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def check_links() -> list[str]:
    errors = []
    for md in doc_files():
        for target in _LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not (md.parent / path).exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> "
                              f"{target}")
    return errors


def check_tier1_sync() -> list[str]:
    roadmap = (ROOT / "ROADMAP.md").read_text()
    m = _TIER1.search(roadmap)
    if not m:
        return ["ROADMAP.md: no '**Tier-1 verify:** `...`' line found"]
    cmd = m.group(1)
    readme = (ROOT / "README.md").read_text()
    if cmd not in readme:
        return [f"README.md: tier-1 command out of sync with ROADMAP.md "
                f"(expected to contain: {cmd})"]
    return []


def main() -> int:
    errors = check_links() + check_tier1_sync()
    for e in errors:
        print(f"FAIL {e}")
    if errors:
        return 1
    print(f"docs ok: {len(doc_files())} files link-checked, "
          f"tier-1 command in sync")
    return 0


if __name__ == "__main__":
    sys.exit(main())
