"""Paper Table 2: cost/performance across deployment strategies.

100 cases per dataset, paper-calibrated confidence traces, single client.
Prints our simulated numbers next to the paper's reported values."""
from __future__ import annotations

from repro.core.netsim import simulate
from repro.core.workload import ALPACA, XSUM, paper_calibrated_cases, \
    split_clients

from benchmarks.common import PAPER_COMP, PAPER_NET, PAPER_SPLIT

PAPER_TOTALS = {
    ("alpaca", "cloud_llm", None): 370.2,
    ("alpaca", "naive", None): 3371.8,
    ("alpaca", "standalone", None): 201.6,
    ("alpaca", "ce_collm", 0.8): 319.1,
    ("alpaca", "ce_collm", 0.9): 345.4,
    ("alpaca", "ce_collm", 1.0): 481.3,
    ("xsum", "cloud_llm", None): 392.5,
    ("xsum", "naive", None): 19108.7,
    ("xsum", "standalone", None): 221.4,
    ("xsum", "ce_collm", 0.8): 376.0,
    ("xsum", "ce_collm", 0.9): 402.4,
    ("xsum", "ce_collm", 1.0): 611.9,
}
PAPER_RR = {("alpaca", 0.8): 49.58, ("alpaca", 0.9): 58.00,
            ("xsum", 0.8): 27.73, ("xsum", 0.9): 36.13}


def run(csv=True):
    rows = []
    for prof in (ALPACA, XSUM):
        cases = paper_calibrated_cases(prof, 100, seed=1)
        clients = split_clients(cases, 1)
        plan = [("cloud_llm", None, True), ("naive", None, False),
                ("standalone", None, True), ("ce_collm", 0.8, True),
                ("ce_collm", 0.9, True), ("ce_collm", 1.0, True)]
        for strat, theta, hp in plan:
            kw = {"theta": theta} if theta is not None else {}
            r = simulate(strat, clients, PAPER_NET, PAPER_COMP, PAPER_SPLIT,
                         half_precision=hp, **kw)
            paper = PAPER_TOTALS.get((prof.name, strat, theta))
            row = {
                "table": "table2", "dataset": prof.name,
                "strategy": strat + (f"@{theta}" if theta else ""),
                **r.as_row(),
                "paper_total_s": paper,
                "rel_err_pct": (round(100 * (r.total_time - paper) / paper, 1)
                                if paper else None),
            }
            if strat == "ce_collm" and (prof.name, theta) in PAPER_RR:
                row["paper_request_rate_pct"] = PAPER_RR[(prof.name, theta)]
            rows.append(row)
    if csv:
        for row in rows:
            print("table2," + row["dataset"] + "," + row["strategy"] + ","
                  + str(row["total_s"]) + "," + str(row["paper_total_s"])
                  + "," + str(row["rel_err_pct"]))
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1))
