"""Paper Table 4: component ablations at theta=0.8 (fp16 wire, early exit,
content manager + parallel upload)."""
from __future__ import annotations

from repro.core.netsim import simulate
from repro.core.workload import ALPACA, XSUM, paper_calibrated_cases, \
    split_clients

from benchmarks.common import PAPER_COMP, PAPER_NET, PAPER_SPLIT

PAPER_REL = {   # paper's "Relative Total Cost (%)"
    ("alpaca", "full"): 100.0, ("alpaca", "no_fp16"): 105.69,
    ("alpaca", "no_ee"): 151.24, ("alpaca", "no_cm"): 441.28,
    ("xsum", "full"): 100.0, ("xsum", "no_fp16"): 114.51,
    ("xsum", "no_ee"): 165.96, ("xsum", "no_cm"): 1335.14,
}


def run(csv=True):
    rows = []
    for prof in (ALPACA, XSUM):
        cases = paper_calibrated_cases(prof, 100, seed=1)
        clients = split_clients(cases, 1)
        variants = [
            ("full", dict()),
            ("no_fp16", dict(half_precision=False)),
            ("no_ee", dict(early_exit=False)),
            ("no_cm", dict(content_manager=False)),
        ]
        base_total = None
        for name, kw in variants:
            r = simulate("ce_collm", clients, PAPER_NET, PAPER_COMP,
                         PAPER_SPLIT, theta=0.8, **kw)
            if base_total is None:
                base_total = r.total_time
            rel = 100 * r.total_time / base_total
            rows.append({"table": "table4", "dataset": prof.name,
                         "variant": name, **r.as_row(),
                         "relative_pct": round(rel, 2),
                         "paper_relative_pct": PAPER_REL[(prof.name, name)]})
    if csv:
        for row in rows:
            print(f"table4,{row['dataset']},{row['variant']},"
                  f"{row['relative_pct']},{row['paper_relative_pct']}")
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1))
