"""Serving throughput: continuous-batching scheduler vs. the seed's
sequential per-client loop, dense vs. block-paged KV layouts, and the
async cloud channel vs. the blocking dispatch.

Measures aggregate decode tokens/s on the tiny trained EE model for slot
counts 1/4/8/16 against the sequential baseline (same request set), in
co-inference mode at θ=0.8.  The acceptance bar for the batching PR is
>= 3x aggregate tokens/s at 8 slots.  ``--kv-layout paged`` (or ``both``)
additionally reports tokens/s, pooled-KV bytes, achieved decode KV HBM
bytes/token and the achieved-vs-roofline HBM fraction per layout at 8/16
slots (see docs/kv_paging.md); ``--kv-dtype int8`` adds the quantized
page pool, and with ``--check`` asserts the int8 pool cuts decode KV HBM
bytes/token >= 1.8x vs float32 at 8 slots, that paged float32 stays
token-identical to dense, and that the int8 exit-rate drift is bounded.
Every sweep row is also written to ``--json`` (BENCH_throughput.json).

``--channel sim`` runs the async-transport comparison instead
(docs/async_transport.md): the same WiFi-class ``AsyncSimChannel`` priced
in virtual time, dispatched blocking vs. overlapped at 8 slots, plus a
deadline-miss trace (replies slower than the deadline -> edge-committed
tokens instead of stalls).  With ``--check`` it asserts the overlapped
virtual makespan beats the blocking one and that the deadline trace
still completes every stream.

``--spec-k K`` runs the drafting sweep instead: the classic 1-token
speculative path vs. K-token edge drafts, both overlapped at 8 slots on a
high-RTT WAN-class channel with a per-request cloud service point.  Each
verification request pays RTT and serializes through the service point,
so shipping k provisional tokens per request cuts the wire/service tax
~k-fold while the accept-prefix/rewind reconcile keeps streams greedy
token-identical to the blocking run.  With ``--check`` it asserts the
K-token sweep beats spec_k=1 on virtual makespan and that the acceptance
rate is measured; per-k rows (incl. ``accept_rate``) land in ``--json``.

``--prefix-share`` runs the radix prefix-sharing sweep instead
(docs/kv_paging.md §Prefix sharing): N streams whose prompts share a
common system prefix, admitted via chunked prefill on the paged pool
with and without ``prefix_share``, on float32 and int8 pools.  Sharing
maps refcounted prefix pages into every stream's block table (skipping
their prefill chunks and hidden-state uploads) and copy-on-writes the
partial tail page on first divergence; exact-duplicate prompts hit a
cached terminal.  With ``--check`` it asserts fewer prefill chunks,
fewer page allocations, fewer uploaded bytes, >=1 CoW copy and
token-identical streams for both dtypes, plus an all-terminal second
wave of re-sent prompts.

``--cloud-batch`` runs the multi-client sweep instead: ``--clients N``
edge engines (one slot + one WiFi link each) share one cloud, and the
shared ``CloudBatcher`` (one masked cloud step per wave of concurrent
requests, priced by a batching ``CloudServicePoint``) is compared against
the per-request FIFO cloud.  With ``--check`` it asserts the batched
cloud virtual makespan beats FIFO at N>=4 and that both variants emit
token-identical streams to N independent sync runs.

    PYTHONPATH=src:. python benchmarks/throughput_bench.py [--check]
    PYTHONPATH=src:. python benchmarks/throughput_bench.py --kv-layout both
    PYTHONPATH=src:. python benchmarks/throughput_bench.py --channel sim --check
    PYTHONPATH=src:. python benchmarks/throughput_bench.py --clients 4 --cloud-batch --check
    PYTHONPATH=src:. python benchmarks/throughput_bench.py --spec-k 4 --check
    PYTHONPATH=src:. python benchmarks/throughput_bench.py --prefix-share --check
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core.collm import CollmConfig
from repro.core.netsim import NetworkParams
from repro.core.transport import (AsyncSimChannel, CloudServicePoint,
                                  ScriptedChannel)
from repro.roofline.analyze import (decode_kv_bytes_per_token,
                                    hbm_roofline_fraction)
from repro.serving.engine import ServingSystem

from benchmarks.common import PAPER_NET, tiny_trained_model

SLOT_COUNTS = (1, 4, 8, 16)


def _requests(data, n_clients: int, prompt_len: int = 12):
    return [data.sample_tokens(prompt_len) for _ in range(n_clients)]


def _tokens_per_s(fn, total_tokens: int, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return total_tokens / best


def run(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
        theta: float = 0.8, repeats: int = 1, check: bool = False,
        rows: list = None) -> dict:
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new
    mean_ctx = float(np.mean([len(p) for p in prompts])) + max_new / 2.0
    ccfg = CollmConfig(theta=theta)

    # both engines are warmed with the SAME shapes they are measured at
    # (same max_new -> same max_seq -> same compiled graphs) and timed with
    # the same repeat count.  Note the sequential path re-traces its edge
    # step per client by construction (fresh EdgeClient jit wrapper), which
    # no warmup can amortize — that cost is intrinsic to the seed loop.
    seq_sys = ServingSystem(model, params, ccfg)
    seq_sys.generate_sequential(prompts[:2], max_new)       # warm compile
    seq_tps = _tokens_per_s(
        lambda: seq_sys.generate_sequential(prompts, max_new, mode="collm"),
        total, repeats)

    out = {"sequential": seq_tps}
    print("engine,slots,clients,max_new,tokens_per_s,speedup_vs_sequential")
    print(f"sequential,1,{n_clients},{max_new},{seq_tps:.1f},1.00")
    for slots in SLOT_COUNTS:
        sys_b = ServingSystem(model, params, ccfg)
        sys_b.generate(prompts[:slots], max_new, num_slots=slots)  # warm
        tps = _tokens_per_s(
            lambda: sys_b.generate(prompts, max_new, mode="collm",
                                   num_slots=slots), total, repeats)
        out[slots] = tps
        if rows is not None:
            sched = max(sys_b._schedulers.values(),
                        key=lambda s: s.kv_cache_bytes())
            bpt = _kv_bytes_per_token(sched, mean_ctx)
            rows.append({"layout": "dense", "kv_dtype": "float32",
                         "slots": slots, "clients": n_clients,
                         "max_new": max_new, "tokens_per_s": tps,
                         "kv_bytes": sched.kv_cache_bytes(),
                         "kv_bytes_per_token": bpt,
                         "hbm_roofline_frac":
                             hbm_roofline_fraction(bpt, tps)})
        print(f"batched,{slots},{n_clients},{max_new},{tps:.1f},"
              f"{tps / seq_tps:.2f}")

    if check:
        speedup = out[8] / seq_tps
        assert speedup >= 3.0, (
            f"continuous batching at 8 slots is only {speedup:.2f}x the "
            f"sequential loop (acceptance bar: 3x)")
        print(f"# check passed: {speedup:.2f}x >= 3x at 8 slots")
    return out


PAGED_SLOT_COUNTS = (8, 16)
# |exit_rate(int8) - exit_rate(float32)| accuracy gate for the paged sweep:
# int8 KV perturbs logits near θ, so a few borderline tokens may flip which
# tier emits them — the gate bounds that drift (docs/kv_paging.md
# §Quantized pages), it does not demand bit-identical streams.
INT8_EXIT_DRIFT = 0.15
# int8 pages must cut the decode KV sweep by at least this factor; the
# analytic ratio for this model is ~3.4x (int8 data + fp32 per-row scales
# vs fp32 data), so 1.8x has headroom without being vacuous
INT8_BYTES_RATIO = 1.8


def _kv_bytes_per_token(sched, mean_ctx: float) -> int:
    """Achieved decode-step KV HBM bytes/token for one scheduler: paged
    layouts read the mapped pages of the mean-context slot (+ write one
    row); dense rings sweep the full per-slot ring regardless of context
    (the masked attention reads every slot)."""
    trees = [c for n in ("main_caches", "edge_caches", "cloud_caches")
             if (c := getattr(sched, n, None)) is not None]
    if sched.layout == "paged":
        return sum(decode_kv_bytes_per_token(t, int(mean_ctx),
                                             sched.pool.page_size)
                   for t in trees)
    total = sum(l.size * l.dtype.itemsize
                for t in trees for l in jax.tree.leaves(t))
    return total // sched.B


def _exit_rate(r: dict, total: int) -> float:
    st = r["stats"]
    return (st.exits_l1 + st.exits_l2) / total


def run_paged(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
              theta: float = 0.8, repeats: int = 1,
              kv_dtype: str = "float32", check: bool = False,
              rows: list = None) -> dict:
    """Dense vs. block-paged KV at 8/16 slots: aggregate decode tokens/s,
    pooled-KV device bytes, achieved decode KV HBM bytes/token, and the
    achieved-vs-roofline HBM fraction per (layout, kv_dtype).

    ``--kv-dtype int8`` (or ``both``) adds the int8 paged pool next to the
    float32 one.  With ``--check``:

      * paged float32 streams must be greedy token-identical to dense;
      * int8 paged KV must cut decode HBM bytes/token by >=
        ``INT8_BYTES_RATIO`` vs float32 paged at 8 slots;
      * the int8 exit-rate drift vs float32 stays within
        ``INT8_EXIT_DRIFT`` (bounded accuracy gate, not bit-identity)."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new
    mean_ctx = float(np.mean([len(p) for p in prompts])) + max_new / 2.0
    variants = [("dense", "float32"), ("paged", "float32")]
    if kv_dtype in ("int8", "both"):
        variants.append(("paged", "int8"))
    out: dict = {}
    print("layout,kv_dtype,slots,clients,max_new,tokens_per_s,kv_bytes,"
          "kv_bytes_per_token,hbm_roofline_frac,exit_rate")
    for layout, dtype in variants:
        ccfg = CollmConfig(theta=theta, kv_layout=layout,
                           kv_dtype=dtype if layout == "paged" else "float32")
        for slots in PAGED_SLOT_COUNTS:
            sys_b = ServingSystem(model, params, ccfg)
            sys_b.generate(prompts[:slots], max_new, num_slots=slots)  # warm
            res = {}
            def go():
                res["r"] = sys_b.generate(prompts, max_new, mode="collm",
                                          num_slots=slots)
            tps = _tokens_per_s(go, total, repeats)
            r = res["r"]
            sched = max(sys_b._schedulers.values(),
                        key=lambda s: s.kv_cache_bytes())
            kv_bytes = sched.kv_cache_bytes()
            bpt = _kv_bytes_per_token(sched, mean_ctx)
            frac = hbm_roofline_fraction(bpt, tps)
            row = {"layout": layout, "kv_dtype": dtype, "slots": slots,
                   "clients": n_clients, "max_new": max_new,
                   "tokens_per_s": tps, "kv_bytes": kv_bytes,
                   "kv_bytes_per_token": bpt, "hbm_roofline_frac": frac,
                   "exit_rate": _exit_rate(r, total)}
            out[(layout, dtype, slots)] = dict(row, tokens=r["tokens"])
            if rows is not None:
                rows.append(row)
            print(f"{layout},{dtype},{slots},{n_clients},{max_new},"
                  f"{tps:.1f},{kv_bytes},{bpt},{frac:.3e},"
                  f"{row['exit_rate']:.3f}")

    if check:
        for slots in PAGED_SLOT_COUNTS:
            d, p = out[("dense", "float32", slots)], \
                out[("paged", "float32", slots)]
            assert p["tokens"] == d["tokens"], (
                f"paged float32 streams must be greedy token-identical to "
                f"dense at {slots} slots")
        if ("paged", "int8", 8) in out:
            f32, i8 = out[("paged", "float32", 8)], out[("paged", "int8", 8)]
            ratio = f32["kv_bytes_per_token"] / i8["kv_bytes_per_token"]
            assert ratio >= INT8_BYTES_RATIO, (
                f"int8 paged KV cuts decode HBM bytes/token only "
                f"{ratio:.2f}x vs float32 at 8 slots "
                f"(gate: {INT8_BYTES_RATIO}x)")
            for slots in PAGED_SLOT_COUNTS:
                drift = abs(out[("paged", "int8", slots)]["exit_rate"]
                            - out[("paged", "float32", slots)]["exit_rate"])
                assert drift <= INT8_EXIT_DRIFT, (
                    f"int8 exit-rate drift {drift:.3f} at {slots} slots "
                    f"exceeds the {INT8_EXIT_DRIFT} accuracy gate")
            print(f"# check passed: paged f32 token-identical to dense; "
                  f"int8 bytes/token ratio {ratio:.2f}x >= "
                  f"{INT8_BYTES_RATIO}x; exit-rate drift within "
                  f"{INT8_EXIT_DRIFT}")
        else:
            print("# check passed: paged f32 token-identical to dense")
    return out


ASYNC_SLOTS = 8
# virtual edge compute per decode tick: A100-class edge partition on the
# tiny split (the absolute value only scales the virtual axis; the
# overlap-vs-blocking *ratio* is what the bench measures)
TICK_TIME_S = 0.01


def run_channel(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
                theta: float = 0.8, check: bool = False) -> dict:
    """Async cloud channel vs. blocking dispatch under identical WiFi-class
    ``NetworkParams``, at 8 slots, in virtual time; plus a deadline-miss
    trace (reply latency >> deadline) showing the latency-aware early exit
    committing edge tokens instead of stalling."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new
    ccfg = CollmConfig(theta=theta)
    out: dict = {}

    print("channel,dispatch,slots,virtual_s,virtual_ms_per_tok,wall_s,"
          "cloud_requests,deadline_misses,stall_s,overlap_s")
    for overlap in (False, True):
        ch = AsyncSimChannel(PAPER_NET, service_s=0.004)
        sysb = ServingSystem(model, params, ccfg)
        sysb.generate(prompts[:ASYNC_SLOTS], max_new,
                      num_slots=ASYNC_SLOTS, channel=ch,
                      tick_time_s=TICK_TIME_S, overlap=overlap)  # warm
        t0 = time.perf_counter()
        r = sysb.generate(prompts, max_new, mode="collm",
                          num_slots=ASYNC_SLOTS, channel=ch,
                          tick_time_s=TICK_TIME_S, overlap=overlap)
        wall = time.perf_counter() - t0
        st = r["stats"]
        name = "overlapped" if overlap else "blocking"
        out[name] = {"virtual_s": r["virtual_time"], "wall_s": wall,
                     "stats": st}
        print(f"wifi-sim,{name},{ASYNC_SLOTS},{r['virtual_time']:.3f},"
              f"{1e3 * r['virtual_time'] / total:.2f},{wall:.2f},"
              f"{st.cloud_requests},{st.deadline_misses},"
              f"{st.stall_s:.2f},{st.overlap_s:.2f}")

    # deadline-miss trace: every reply arrives long after its deadline
    ch = ScriptedChannel([0.5], deadline_s=0.02)
    sysd = ServingSystem(model, params, ccfg)
    r = sysd.generate(prompts, max_new, mode="collm", num_slots=ASYNC_SLOTS,
                      channel=ch, tick_time_s=TICK_TIME_S, fallback_after=4)
    st = r["stats"]
    complete = all(len(t) == max_new for t in r["tokens"])
    out["deadline"] = {"virtual_s": r["virtual_time"], "stats": st,
                       "complete": complete}
    print(f"deadline-trace,overlapped,{ASYNC_SLOTS},{r['virtual_time']:.3f},"
          f"{1e3 * r['virtual_time'] / total:.2f},-,{st.cloud_requests},"
          f"{st.deadline_misses},{st.stall_s:.2f},{st.overlap_s:.2f}")
    print(f"# deadline trace: {st.deadline_misses} misses -> edge-committed "
          f"tokens, {st.fallbacks} standalone fallbacks, all streams "
          f"complete: {complete}")

    if check:
        v_block = out["blocking"]["virtual_s"]
        v_over = out["overlapped"]["virtual_s"]
        assert v_over < v_block, (
            f"overlapped dispatch ({v_over:.3f}s virtual) should beat the "
            f"blocking path ({v_block:.3f}s virtual) at {ASYNC_SLOTS} slots")
        assert complete and st.deadline_misses > 0, (
            "deadline-miss trace must complete every stream via "
            "edge-committed tokens")
        print(f"# check passed: overlapped {v_over:.3f}s < blocking "
              f"{v_block:.3f}s virtual; deadline trace completed with "
              f"{st.deadline_misses} misses")
    return out


OVERSUB_SLOTS = 4
OVERSUB_FRAC = 0.6       # page budget as a fraction of worst-case demand


def run_oversubscribe(csv: bool = False, *, n_clients: int = 8,
                      max_new: int = 24, theta: float = 0.8,
                      check: bool = False) -> dict:
    """Optimistic admission + preemption vs. worst-case (admission-blocked)
    paging at a page budget of ~60% of the concurrent worst-case demand
    (docs/kv_paging.md §Preemption).

    ``blocked`` keeps ``preemption="off"``: admission reserves the worst
    case, so the shrunken pool caps concurrency below the slot count and
    the queue drains in waves.  ``recompute``/``swap`` admit every slot on
    its prompt pages and preempt victims when the free list runs dry.  All
    three emit token-identical streams (asserted against an unconstrained
    paged run); the virtual makespan (``tick_time_s`` per decode tick,
    zero-latency cloud) isolates the concurrency win.  ``--check`` asserts
    >= 1 real preemption and a preemptive makespan below the blocked one."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    ccfg = lambda **kw: CollmConfig(theta=theta, kv_layout="paged", **kw)
    ps = ccfg().page_size
    worst = max((len(p) + max_new - 1) // ps + 1 for p in prompts)
    demand = OVERSUB_SLOTS * worst
    budget = max(worst, int(OVERSUB_FRAC * demand))

    ref_sys = ServingSystem(model, params, ccfg())
    ref = ref_sys.generate(prompts, max_new, mode="collm",
                           num_slots=OVERSUB_SLOTS)["tokens"]

    out: dict = {}
    print(f"# page budget {budget}/{demand} pages "
          f"({100 * budget / demand:.0f}% of worst-case demand)")
    print("paging,slots,pages,virtual_s,preemptions,swapped_kb,"
          "tokens_equal")
    for variant in ("blocked", "recompute", "swap"):
        pre = "off" if variant == "blocked" else variant
        sysv = ServingSystem(model, params, ccfg(preemption=pre))
        r = sysv.generate(prompts, max_new, mode="collm",
                          num_slots=OVERSUB_SLOTS, num_pages=budget,
                          tick_time_s=TICK_TIME_S)
        sched = next(iter(sysv._schedulers.values()))
        equal = r["tokens"] == ref
        # NB ``sched.swap`` has __len__ (empty after a clean drain): test
        # for None, not truthiness
        sw_kb = (sched.swap.stats.bytes_out / 1e3
                 if sched.swap is not None else 0.0)
        out[variant] = {"virtual_s": r["virtual_time"],
                        "preemptions": sched.preemptions,
                        "tokens_equal": equal}
        print(f"{variant},{OVERSUB_SLOTS},{budget},{r['virtual_time']:.3f},"
              f"{sched.preemptions},{sw_kb:.1f},{equal}")

    if check:
        assert all(v["tokens_equal"] for v in out.values()), \
            "oversubscribed streams must be token-identical to the " \
            "unconstrained paged run"
        assert out["blocked"]["preemptions"] == 0
        for variant in ("recompute", "swap"):
            assert out[variant]["preemptions"] >= 1, \
                f"{variant}: the {budget}-page budget should force at " \
                f"least one preemption"
            assert out[variant]["virtual_s"] < out["blocked"]["virtual_s"], (
                f"{variant} ({out[variant]['virtual_s']:.3f}s virtual) "
                f"should beat admission-blocked paging "
                f"({out['blocked']['virtual_s']:.3f}s virtual)")
        print(f"# check passed: recompute {out['recompute']['virtual_s']:.3f}s"
              f" / swap {out['swap']['virtual_s']:.3f}s < blocked "
              f"{out['blocked']['virtual_s']:.3f}s virtual; streams "
              f"identical")
    return out


PREFIX_SLOTS = 4
PREFIX_PAGE_SIZE = 8     # small pages -> several shared chunks per prompt


def _prefix_requests(data, n_clients: int, page_size: int):
    """N prompts sharing a common system prefix (2 full pages + a partial
    tail page), each with a distinct continuation, plus two exact
    duplicates of earlier prompts (whole-prompt terminal hits)."""
    system = np.asarray(data.sample_tokens(2 * page_size + 3))
    prompts = []
    for i in range(max(1, n_clients - 2)):
        suffix = np.asarray(data.sample_tokens(4 + i % 6))
        prompts.append(np.concatenate([system, suffix]).astype(np.int32))
    while len(prompts) < n_clients:
        prompts.append(prompts[len(prompts) % 2].copy())
    return prompts


def run_prefix_share(csv: bool = False, *, n_clients: int = 8,
                     max_new: int = 16, theta: float = 0.8,
                     check: bool = False, rows: list = None) -> dict:
    """Radix prefix sharing + copy-on-write vs. plain chunked prefill
    (docs/kv_paging.md §Prefix sharing): N streams whose prompts open with
    a common system prefix, admitted through the chunked-prefill path on
    the paged pool, with and without ``prefix_share``.  Sharing maps the
    prefix pages into every stream's block table (refcounted), skips their
    prefill chunks AND their hidden-state uploads, and copy-on-writes the
    partial tail page when each stream's first divergent token lands.
    Exact-duplicate prompts hit a cached terminal (zero prefill compute,
    memoized first token).  Both variants must emit token-identical
    streams.  ``--check`` asserts, for float32 and int8 paged pools:
    fewer prefill chunks, fewer page allocations, fewer uploaded bytes,
    >0 prefix-hit tokens, >=1 CoW copy, token-identical output — plus an
    all-terminal second wave (re-sent prompts, zero prefill chunks)."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    ps = PREFIX_PAGE_SIZE
    prompts = _prefix_requests(data, n_clients, ps)
    max_len = max(len(p) for p in prompts)
    max_seq = -(-(max_len + max_new) // ps) * ps + ps
    gkw = dict(num_slots=PREFIX_SLOTS, max_seq=max_seq, max_ctx=max_seq,
               num_pages=PREFIX_SLOTS * (max_seq // ps) * 2)

    out: dict = {}
    print("kv_dtype,variant,prefill_chunks,prefix_hit_tokens,cow_copies,"
          "page_allocs,upload_kb,tokens_equal")
    for kv_dtype in ("float32", "int8"):
        ccfg = lambda **kw: CollmConfig(theta=theta, kv_layout="paged",
                                        page_size=ps, kv_dtype=kv_dtype,
                                        chunked_prefill=True, **kw)
        r_un = ServingSystem(model, params, ccfg()).generate(
            prompts, max_new, mode="collm", **gkw)
        sys_sh = ServingSystem(model, params, ccfg(prefix_share=True))
        r_sh = sys_sh.generate(prompts, max_new, mode="collm", **gkw)
        # second wave on the warm system: every re-sent prompt should hit
        # a cached terminal (zero prefill compute, memoized first token)
        r_w2 = sys_sh.generate(prompts[:2], max_new, mode="collm", **gkw)
        out[kv_dtype] = {}
        for variant, r in (("unshared", r_un), ("shared", r_sh)):
            st = r["stats"]
            equal = r["tokens"] == r_un["tokens"]
            row = {"mode": "prefix_share", "kv_dtype": kv_dtype,
                   "variant": variant, "clients": n_clients,
                   "slots": PREFIX_SLOTS, "max_new": max_new,
                   "prefill_chunks": st.prefill_chunks,
                   "prefix_hit_tokens": st.prefix_hit_tokens,
                   "cow_copies": st.cow_copies,
                   "page_allocs": r["pool_stats"]["allocs"],
                   "upload_bytes": st.upload_bytes,
                   "tokens_equal": equal}
            out[kv_dtype][variant] = row
            if rows is not None:
                rows.append(row)
            print(f"{kv_dtype},{variant},{st.prefill_chunks},"
                  f"{st.prefix_hit_tokens},{st.cow_copies},"
                  f"{row['page_allocs']},{st.upload_bytes / 1e3:.1f},"
                  f"{equal}")
        out[kv_dtype]["wave2"] = {
            "prefill_chunks": r_w2["stats"].prefill_chunks,
            "tokens_equal": r_w2["tokens"] == r_un["tokens"][:2]}

    if check:
        for kv_dtype, o in out.items():
            un, sh, w2 = o["unshared"], o["shared"], o["wave2"]
            assert sh["tokens_equal"], \
                f"{kv_dtype}: shared streams diverge from unshared"
            assert sh["prefill_chunks"] < un["prefill_chunks"], (
                f"{kv_dtype}: sharing should skip prefix prefill chunks "
                f"({sh['prefill_chunks']} vs {un['prefill_chunks']})")
            assert sh["prefix_hit_tokens"] > 0, \
                f"{kv_dtype}: no prefix hits recorded"
            assert sh["cow_copies"] >= 1, (
                f"{kv_dtype}: the partial tail page must be "
                f"copy-on-written at least once")
            assert sh["upload_bytes"] < un["upload_bytes"], (
                f"{kv_dtype}: deduped uploads should cut wire bytes "
                f"({sh['upload_bytes']} vs {un['upload_bytes']})")
            assert sh["page_allocs"] < un["page_allocs"], (
                f"{kv_dtype}: shared pages should cut fresh allocations "
                f"({sh['page_allocs']} vs {un['page_allocs']})")
            assert w2["tokens_equal"] and w2["prefill_chunks"] == 0, (
                f"{kv_dtype}: wave-2 identical prompts should be "
                f"all-terminal (got {w2['prefill_chunks']} chunks)")
        f32 = out["float32"]
        print(f"# check passed: {f32['shared']['prefill_chunks']} vs "
              f"{f32['unshared']['prefill_chunks']} prefill chunks, "
              f"{f32['shared']['page_allocs']} vs "
              f"{f32['unshared']['page_allocs']} page allocs, "
              f"{f32['shared']['upload_bytes']} vs "
              f"{f32['unshared']['upload_bytes']} upload bytes "
              f"(float32; int8 likewise); streams identical, wave 2 "
              f"all-terminal")
    return out


# high-RTT WAN-class link for the drafting sweep: the per-request RTT tax
# and the per-request cloud service cost are what k-token drafts amortize
# (k tokens per verification request instead of one request per token)
SPEC_NET = NetworkParams(up_bw=3.8e6, down_bw=8e6, rtt=0.08)
SPEC_SERVICE_S = 0.006


def run_spec(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
             theta: float = 0.8, spec_k: int = 4, check: bool = False,
             rows: list = None) -> dict:
    """Multi-token edge drafting vs. the classic 1-token speculative path
    (docs/async_transport.md §Speculative): both overlapped at 8 slots on
    the same high-RTT WAN-class channel with a per-request cloud service
    point.  spec_k=k ships up to k provisional tokens per verification
    request, so a below-θ burst costs ~1/k as many requests — each of
    which pays RTT and serializes through the service point.  Streams stay
    greedy token-identical to the blocking non-speculative run (infinite
    deadline).  With ``--check`` asserts spec_k=k beats spec_k=1 on
    virtual makespan at 8 slots and that the acceptance rate is reported."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new

    # blocking non-speculative reference: drafting must be invisible in
    # output space, whatever k
    ref = ServingSystem(model, params, CollmConfig(theta=theta)).generate(
        prompts, max_new, mode="collm", num_slots=ASYNC_SLOTS)["tokens"]

    ks = sorted({1, spec_k})
    out: dict = {}
    print("spec_k,slots,virtual_s,virtual_ms_per_tok,requests,draft_tokens,"
          "accepted,accept_rate,mean_accept_len,rewinds,tokens_equal")
    for k in ks:
        ccfg = CollmConfig(theta=theta, speculative=True, spec_k=k)
        sysk = ServingSystem(model, params, ccfg)
        sysk.generate(prompts[:ASYNC_SLOTS], max_new, num_slots=ASYNC_SLOTS,
                      channel=AsyncSimChannel(SPEC_NET,
                                              service_s=SPEC_SERVICE_S),
                      tick_time_s=TICK_TIME_S)               # warm compile
        ch = AsyncSimChannel(SPEC_NET, service_s=SPEC_SERVICE_S)
        r = sysk.generate(prompts, max_new, mode="collm",
                          num_slots=ASYNC_SLOTS, channel=ch,
                          tick_time_s=TICK_TIME_S)
        st = r["stats"]
        accept_rate = (st.accepted_tokens / st.draft_tokens
                       if st.draft_tokens else 0.0)
        mean_len = (float(np.mean(st.accept_lens))
                    if st.accept_lens else 0.0)
        equal = r["tokens"] == ref
        row = {"spec_k": k, "slots": ASYNC_SLOTS, "clients": n_clients,
               "max_new": max_new, "virtual_s": r["virtual_time"],
               "requests": r["channel_stats"]["requests"],
               "draft_tokens": st.draft_tokens,
               "accepted_tokens": st.accepted_tokens,
               "accept_rate": accept_rate, "mean_accept_len": mean_len,
               "spec_rewinds": st.spec_rewinds, "tokens_equal": equal}
        out[k] = row
        if rows is not None:
            rows.append(row)
        print(f"{k},{ASYNC_SLOTS},{r['virtual_time']:.3f},"
              f"{1e3 * r['virtual_time'] / total:.2f},{row['requests']},"
              f"{st.draft_tokens},{st.accepted_tokens},{accept_rate:.2%},"
              f"{mean_len:.2f},{st.spec_rewinds},{equal}")

    if check:
        assert spec_k > 1, "--check needs --spec-k > 1 (nothing to compare)"
        v1, vk = out[1]["virtual_s"], out[spec_k]["virtual_s"]
        assert vk < v1, (
            f"spec_k={spec_k} drafting ({vk:.3f}s virtual) should beat the "
            f"1-token speculative path ({v1:.3f}s virtual) at "
            f"{ASYNC_SLOTS} slots on the high-RTT link")
        assert out[spec_k]["requests"] < out[1]["requests"], (
            "k-token drafts must coalesce verification requests")
        assert out[spec_k]["draft_tokens"] > 0 \
            and out[spec_k]["accept_rate"] > 0.0, \
            "acceptance rate must be measured and reported"
        assert all(v["tokens_equal"] for v in out.values()), \
            "draft streams must stay token-identical to the blocking run"
        print(f"# check passed: spec_k={spec_k} {vk:.3f}s < spec_k=1 "
              f"{v1:.3f}s virtual at {ASYNC_SLOTS} slots "
              f"({out[spec_k]['requests']} vs {out[1]['requests']} requests, "
              f"accept rate {out[spec_k]['accept_rate']:.2%}); streams "
              f"identical to blocking")
    return out


# virtual cost of ONE batched cloud service step (A100-class cloud
# partition); the batching window the cloud waits to accumulate arrivals
CLOUD_SERVICE_S = 0.008
CLOUD_WINDOW_S = 0.004


def run_cloud_batch(csv: bool = False, *, n_clients: int = 4,
                    max_new: int = 24, theta: float = 0.8,
                    check: bool = False) -> dict:
    """Multi-client sweep (paper §5, Fig 4): N edge engines, each its own
    WiFi link and virtual clock, sharing ONE cloud.  ``fifo`` prices the
    cloud as a per-request queue (every request occupies the server for
    ``CLOUD_SERVICE_S`` back-to-back) with per-engine cloud compute;
    ``batched`` routes compute through the shared ``CloudBatcher`` (one
    masked cloud step per wave of concurrent requests) and prices it with
    a batching service point.  ``--check`` asserts the batched cloud
    virtual makespan beats per-request FIFO at N>=4 and that both emit
    token-identical streams to N independent sync runs."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    ccfg = CollmConfig(theta=theta)

    # reference: each client run independently on a blocking SyncChannel
    ref_sys = ServingSystem(model, params, ccfg)
    ref = [ref_sys.generate([p], max_new, mode="collm", num_slots=1)
           ["tokens"][0] for p in prompts]

    n_layers = model.cfg.n_layers
    cloud_frac = (n_layers - model.cfg.exit_layers[0]) / n_layers
    out: dict = {}
    print("cloud,clients,virtual_s,cloud_busy_s,steps,mean_batch,"
          "requests,offload_pct,tokens_equal")
    for variant in ("fifo", "batched"):
        # one client has nobody to coalesce with: both variants are FIFO
        batched = variant == "batched" and n_clients > 1
        svc = CloudServicePoint(
            CLOUD_SERVICE_S,
            batch_window_s=CLOUD_WINDOW_S if batched else 0.0,
            max_batch=n_clients if batched else 1)
        chans = [AsyncSimChannel(PAPER_NET, service=svc)
                 for _ in range(n_clients)]
        sysm = ServingSystem(model, params, ccfg)
        r = sysm.generate_multi(prompts, max_new, cloud_batch=batched,
                                channels=chans, tick_time_s=TICK_TIME_S)
        st = r["stats"]
        # cloud work the edge kept OFF the cloud, vs. the cloud-only
        # deployment (every token, all layers) — the paper's headline
        offload = 100.0 * (1.0 - st.request_rate * cloud_frac)
        b = r.get("batcher", {})
        equal = r["tokens"] == ref
        out[variant] = {"virtual_s": r["virtual_time"],
                        "cloud_busy_s": svc.busy_s,
                        "steps": b.get("steps", svc.batches),
                        "mean_batch": b.get("mean_batch", 1.0),
                        "offload_pct": offload, "tokens_equal": equal}
        print(f"{variant},{n_clients},{r['virtual_time']:.3f},"
              f"{svc.busy_s:.3f},{out[variant]['steps']},"
              f"{out[variant]['mean_batch']},{st.cloud_requests},"
              f"{offload:.1f},{equal}")

    if check:
        v_f, v_b = out["fifo"]["virtual_s"], out["batched"]["virtual_s"]
        assert n_clients >= 4, "--check needs --clients >= 4"
        assert v_b < v_f, (
            f"batched cloud ({v_b:.3f}s virtual) should beat per-request "
            f"FIFO ({v_f:.3f}s virtual) at {n_clients} clients")
        assert out["batched"]["tokens_equal"] and out["fifo"]["tokens_equal"], \
            "multi-client streams must be token-identical to independent " \
            "sync runs"
        print(f"# check passed: batched {v_b:.3f}s < fifo {v_f:.3f}s "
              f"virtual at {n_clients} clients; streams identical to "
              f"independent runs")
    return out


def run_cloud_tp(csv: bool = False, *, n_clients: int = 3, max_new: int = 8,
                 theta: float = 0.8, dp: int = 2, tp: int = 4,
                 check: bool = False, rows: list = None) -> dict:
    """Cloud tensor parallelism (docs/sharding.md): the tiny EE model
    served with the cloud partition's steps compiled against a (dp x tp)
    host-device mesh vs. the single-device path.  Reports token identity,
    per-device cloud param bytes (the analytic
    ``estimate_param_bytes_per_device`` AND what ``device_put`` actually
    committed to device 0), trace counts across two ``generate_multi``
    fleets (the per-CoLLM memoization must keep N engines on one trace
    per step), and the collective traffic parsed out of the sharded
    ``cloud_step_masked`` HLO — predicted all-reduce / all-gather wire
    bytes per device per cloud step, the sharded counterpart of the KV
    bytes/token roofline rows.  With ``--check`` asserts token identity,
    estimate == placed bytes with the expected model-axis shrink (GQA KV
    projections and norms replicate, so the bar is >= 0.6*tp), zero
    re-traces on the second fleet, and >= 1 all-reduce in the step."""
    import jax.numpy as jnp

    from repro.core.transport import quantize
    from repro.launch import sharding as shardlib
    from repro.roofline.collectives import (parse_collectives,
                                            total_wire_bytes)
    from repro.serving.mesh_exec import mesh_context

    need = dp * tp
    if len(jax.devices()) < need:
        raise SystemExit(
            f"--cloud-tp {tp} --cloud-dp {dp} needs {need} devices but "
            f"only {len(jax.devices())} are visible; export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")

    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)

    ref = ServingSystem(model, params, CollmConfig(theta=theta)
                        ).generate_multi(prompts, max_new)
    sys_tp = ServingSystem(model, params,
                           CollmConfig(theta=theta, cloud_mesh=(dp, tp)))
    r1 = sys_tp.generate_multi(prompts, max_new)
    mc = mesh_context(sys_tp.collm)
    first_fleet = dict(mc.trace_counts)
    r2 = sys_tp.generate_multi(prompts, max_new)
    retraces = sum(mc.trace_counts.values()) - sum(first_fleet.values())
    identical = (r1["tokens"] == ref["tokens"]
                 and r2["tokens"] == ref["tokens"])

    # per-device param bytes: analytic estimate vs device_put's shards
    est = shardlib.estimate_param_bytes_per_device(
        model.param_specs(), mc.mesh, fsdp=False,
        head_dim=model.cfg.resolved_head_dim)
    dev0 = mc.mesh.devices.flat[0]
    placed = sum(s.data.nbytes for l in jax.tree.leaves(sys_tp.params)
                 for s in l.addressable_shards if s.device == dev0)
    total = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(params))
    shrink = total / placed

    # collective traffic of one sharded masked cloud step at B rows
    B, d = n_clients, model.cfg.d_model
    caches = mc.shard_caches(sys_tp.collm.init_cloud_cache(B, 64), batch=B)
    upload = quantize(jnp.zeros((B, 1, d), jnp.float32),
                      sys_tp.collm.ccfg.wire_format)
    pos = jnp.zeros((B,), jnp.int32)
    mask = jnp.ones((B,), bool)
    with shardlib.use_policy(mc.policy):
        hlo = jax.jit(sys_tp.collm.cloud_step_masked).lower(
            sys_tp.params, upload, caches, pos, mask).compile().as_text()
    coll = parse_collectives(hlo, need)
    ar = coll.get("all-reduce", {"count": 0, "wire_bytes": 0.0})
    ag = coll.get("all-gather", {"count": 0, "wire_bytes": 0.0})

    row = {"mode": "cloud_tp", "mesh": f"{dp}x{tp}", "devices": need,
           "clients": n_clients, "max_new": max_new,
           "tokens_equal": identical,
           "param_bytes_total": total, "param_bytes_per_dev": placed,
           "param_bytes_per_dev_est": est, "shrink_x": shrink,
           "trace_counts": first_fleet, "retraces_2nd_fleet": retraces,
           "allreduce_count": ar["count"],
           "allreduce_wire_bytes": ar["wire_bytes"],
           "allgather_count": ag["count"],
           "allgather_wire_bytes": ag["wire_bytes"],
           "coll_wire_bytes_per_step": total_wire_bytes(coll)}
    if rows is not None:
        rows.append(row)
    print("mesh,devices,tokens_equal,param_KB_per_dev,param_KB_est,"
          "shrink_x,retraces_2nd_fleet,allreduce_n,allreduce_KB,"
          "allgather_n,allgather_KB,coll_KB_per_step")
    print(f"{dp}x{tp},{need},{identical},{placed / 1e3:.1f},"
          f"{est / 1e3:.1f},{shrink:.2f},{retraces},{ar['count']},"
          f"{ar['wire_bytes'] / 1e3:.2f},{ag['count']},"
          f"{ag['wire_bytes'] / 1e3:.2f},"
          f"{total_wire_bytes(coll) / 1e3:.2f}")

    if check:
        assert identical, "sharded cloud steps must be token-identical " \
            "to the single-device path"
        assert abs(placed - est) <= 1e-6 * max(est, 1), (
            f"placed per-device bytes {placed} != estimate {est}")
        assert shrink >= 0.6 * tp, (
            f"per-device param bytes shrank only {shrink:.2f}x on a "
            f"model={tp} mesh (expected ~{tp}x less replicated leaves)")
        assert retraces == 0, (
            f"second generate_multi fleet re-traced {retraces} steps; "
            f"the per-CoLLM jit memoization must hold across engines")
        assert ar["count"] >= 1, (
            "a row-parallel cloud step must all-reduce partial sums; "
            "none found in the compiled HLO")
        print(f"# check passed: {dp}x{tp} mesh token-identical, "
              f"{shrink:.2f}x per-device param shrink (est==placed), "
              f"0 re-traces on 2nd fleet, {ar['count']} all-reduces "
              f"({total_wire_bytes(coll) / 1e3:.1f}KB wire/step)")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--theta", type=float, default=0.8)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--check", action="store_true",
                    help="assert >=3x speedup at 8 slots (sync) / overlap "
                         "beats blocking + deadline trace completes (sim)")
    ap.add_argument("--kv-layout", choices=("dense", "paged", "both"),
                    default="dense",
                    help="paged/both: compare KV layouts at 8/16 slots")
    ap.add_argument("--kv-dtype", choices=("float32", "int8", "both"),
                    default="float32",
                    help="int8/both: add the int8 paged pool to the layout "
                         "sweep (bytes/token + accuracy gates with --check)")
    ap.add_argument("--json", default="BENCH_throughput.json",
                    help="machine-readable output of the slot/layout/dtype "
                         "sweeps (written by the sync + paged paths)")
    ap.add_argument("--channel", choices=("sync", "sim"), default="sync",
                    help="sim: async-transport comparison (overlap vs "
                         "blocking + deadline-miss trace) instead of the "
                         "slot sweep")
    ap.add_argument("--cloud-batch", action="store_true",
                    help="multi-client sweep: N edge engines sharing one "
                         "cloud, batched CloudBatcher vs per-request FIFO")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="drafting sweep: spec_k=1 vs spec_k=K overlapped "
                         "at 8 slots on a high-RTT link (--check asserts "
                         "K-token drafts cut the virtual makespan)")
    ap.add_argument("--oversubscribe", action="store_true",
                    help="paged-KV preemption sweep: page budget at ~60%% "
                         "of worst-case demand, optimistic+preemptive vs "
                         "admission-blocked paging")
    ap.add_argument("--prefix-share", action="store_true",
                    help="radix prefix sharing sweep: N streams with a "
                         "common system prompt, shared vs. unshared "
                         "chunked prefill on float32 + int8 paged pools "
                         "(--check asserts fewer chunks/pages/upload "
                         "bytes, token-identical streams)")
    ap.add_argument("--cloud-tp", type=int, default=0,
                    help="cloud tensor-parallel sweep: serve with the "
                         "cloud partition compiled against a "
                         "(--cloud-dp x N) mesh vs. single device "
                         "(needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count)")
    ap.add_argument("--cloud-dp", type=int, default=2,
                    help="data-axis size of the --cloud-tp mesh")
    args = ap.parse_args()
    if args.cloud_tp:
        rows = []
        run_cloud_tp(n_clients=args.clients, max_new=args.max_new,
                     theta=args.theta, dp=args.cloud_dp, tp=args.cloud_tp,
                     check=args.check, rows=rows)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")
        return
    if args.prefix_share:
        rows = []
        run_prefix_share(n_clients=args.clients, max_new=args.max_new,
                         theta=args.theta, check=args.check, rows=rows)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")
        return
    if args.spec_k:
        rows = []
        run_spec(n_clients=args.clients, max_new=args.max_new,
                 theta=args.theta, spec_k=args.spec_k, check=args.check,
                 rows=rows)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")
        return
    if args.oversubscribe:
        run_oversubscribe(n_clients=args.clients, max_new=args.max_new,
                          theta=args.theta, check=args.check)
        return
    if args.cloud_batch:
        run_cloud_batch(n_clients=args.clients, max_new=args.max_new,
                        theta=args.theta, check=args.check)
        return
    if args.channel == "sim":
        run_channel(n_clients=args.clients, max_new=args.max_new,
                    theta=args.theta, check=args.check)
        return
    rows: list = []
    if args.kv_layout in ("dense", "both"):
        run(n_clients=args.clients, max_new=args.max_new, theta=args.theta,
            repeats=args.repeats, check=args.check, rows=rows)
    if args.kv_layout in ("paged", "both"):
        run_paged(n_clients=args.clients, max_new=args.max_new,
                  theta=args.theta, repeats=args.repeats,
                  kv_dtype=args.kv_dtype, check=args.check, rows=rows)
    if rows:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
