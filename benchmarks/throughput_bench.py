"""Serving throughput: continuous-batching scheduler vs. the seed's
sequential per-client loop, and dense vs. block-paged KV layouts.

Measures aggregate decode tokens/s on the tiny trained EE model for slot
counts 1/4/8/16 against the sequential baseline (same request set), in
co-inference mode at θ=0.8.  The acceptance bar for the batching PR is
>= 3x aggregate tokens/s at 8 slots.  ``--kv-layout paged`` (or ``both``)
additionally reports tokens/s and pooled-KV bytes per layout at 8/16
slots (see docs/kv_paging.md).

    PYTHONPATH=src:. python benchmarks/throughput_bench.py [--check]
    PYTHONPATH=src:. python benchmarks/throughput_bench.py --kv-layout both
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.collm import CollmConfig
from repro.serving.engine import ServingSystem

from benchmarks.common import tiny_trained_model

SLOT_COUNTS = (1, 4, 8, 16)


def _requests(data, n_clients: int, prompt_len: int = 12):
    return [data.sample_tokens(prompt_len) for _ in range(n_clients)]


def _tokens_per_s(fn, total_tokens: int, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return total_tokens / best


def run(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
        theta: float = 0.8, repeats: int = 1, check: bool = False) -> dict:
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new
    ccfg = CollmConfig(theta=theta)

    # both engines are warmed with the SAME shapes they are measured at
    # (same max_new -> same max_seq -> same compiled graphs) and timed with
    # the same repeat count.  Note the sequential path re-traces its edge
    # step per client by construction (fresh EdgeClient jit wrapper), which
    # no warmup can amortize — that cost is intrinsic to the seed loop.
    seq_sys = ServingSystem(model, params, ccfg)
    seq_sys.generate_sequential(prompts[:2], max_new)       # warm compile
    seq_tps = _tokens_per_s(
        lambda: seq_sys.generate_sequential(prompts, max_new, mode="collm"),
        total, repeats)

    out = {"sequential": seq_tps}
    print("engine,slots,clients,max_new,tokens_per_s,speedup_vs_sequential")
    print(f"sequential,1,{n_clients},{max_new},{seq_tps:.1f},1.00")
    for slots in SLOT_COUNTS:
        sys_b = ServingSystem(model, params, ccfg)
        sys_b.generate(prompts[:slots], max_new, num_slots=slots)  # warm
        tps = _tokens_per_s(
            lambda: sys_b.generate(prompts, max_new, mode="collm",
                                   num_slots=slots), total, repeats)
        out[slots] = tps
        print(f"batched,{slots},{n_clients},{max_new},{tps:.1f},"
              f"{tps / seq_tps:.2f}")

    if check:
        speedup = out[8] / seq_tps
        assert speedup >= 3.0, (
            f"continuous batching at 8 slots is only {speedup:.2f}x the "
            f"sequential loop (acceptance bar: 3x)")
        print(f"# check passed: {speedup:.2f}x >= 3x at 8 slots")
    return out


PAGED_SLOT_COUNTS = (8, 16)


def run_paged(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
              theta: float = 0.8, repeats: int = 1) -> dict:
    """Dense vs. block-paged KV at 8/16 slots: aggregate decode tokens/s
    and pooled-KV device bytes per layout (the paged pool is sized to the
    dense-equivalent page count, so the bytes column isolates layout
    overhead; shrinking ``num_pages`` below that is the memory win)."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new
    out: dict = {}
    print("layout,slots,clients,max_new,tokens_per_s,kv_bytes")
    for layout in ("dense", "paged"):
        ccfg = CollmConfig(theta=theta, kv_layout=layout)
        for slots in PAGED_SLOT_COUNTS:
            sys_b = ServingSystem(model, params, ccfg)
            sys_b.generate(prompts[:slots], max_new, num_slots=slots)  # warm
            tps = _tokens_per_s(
                lambda: sys_b.generate(prompts, max_new, mode="collm",
                                       num_slots=slots), total, repeats)
            kv_bytes = max(s.kv_cache_bytes()
                           for s in sys_b._schedulers.values())
            out[(layout, slots)] = {"tokens_per_s": tps, "kv_bytes": kv_bytes}
            print(f"{layout},{slots},{n_clients},{max_new},{tps:.1f},"
                  f"{kv_bytes}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--theta", type=float, default=0.8)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--check", action="store_true",
                    help="assert >=3x speedup at 8 slots")
    ap.add_argument("--kv-layout", choices=("dense", "paged", "both"),
                    default="dense",
                    help="paged/both: compare KV layouts at 8/16 slots")
    args = ap.parse_args()
    if args.kv_layout in ("dense", "both"):
        run(n_clients=args.clients, max_new=args.max_new, theta=args.theta,
            repeats=args.repeats, check=args.check)
    if args.kv_layout in ("paged", "both"):
        run_paged(n_clients=args.clients, max_new=args.max_new,
                  theta=args.theta, repeats=args.repeats)


if __name__ == "__main__":
    main()
