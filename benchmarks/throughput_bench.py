"""Serving throughput: continuous-batching scheduler vs. the seed's
sequential per-client loop, dense vs. block-paged KV layouts, and the
async cloud channel vs. the blocking dispatch.

Measures aggregate decode tokens/s on the tiny trained EE model for slot
counts 1/4/8/16 against the sequential baseline (same request set), in
co-inference mode at θ=0.8.  The acceptance bar for the batching PR is
>= 3x aggregate tokens/s at 8 slots.  ``--kv-layout paged`` (or ``both``)
additionally reports tokens/s and pooled-KV bytes per layout at 8/16
slots (see docs/kv_paging.md).

``--channel sim`` runs the async-transport comparison instead
(docs/async_transport.md): the same WiFi-class ``AsyncSimChannel`` priced
in virtual time, dispatched blocking vs. overlapped at 8 slots, plus a
deadline-miss trace (replies slower than the deadline -> edge-committed
tokens instead of stalls).  With ``--check`` it asserts the overlapped
virtual makespan beats the blocking one and that the deadline trace
still completes every stream.

    PYTHONPATH=src:. python benchmarks/throughput_bench.py [--check]
    PYTHONPATH=src:. python benchmarks/throughput_bench.py --kv-layout both
    PYTHONPATH=src:. python benchmarks/throughput_bench.py --channel sim --check
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.collm import CollmConfig
from repro.core.transport import AsyncSimChannel, ScriptedChannel
from repro.serving.engine import ServingSystem

from benchmarks.common import PAPER_NET, tiny_trained_model

SLOT_COUNTS = (1, 4, 8, 16)


def _requests(data, n_clients: int, prompt_len: int = 12):
    return [data.sample_tokens(prompt_len) for _ in range(n_clients)]


def _tokens_per_s(fn, total_tokens: int, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return total_tokens / best


def run(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
        theta: float = 0.8, repeats: int = 1, check: bool = False) -> dict:
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new
    ccfg = CollmConfig(theta=theta)

    # both engines are warmed with the SAME shapes they are measured at
    # (same max_new -> same max_seq -> same compiled graphs) and timed with
    # the same repeat count.  Note the sequential path re-traces its edge
    # step per client by construction (fresh EdgeClient jit wrapper), which
    # no warmup can amortize — that cost is intrinsic to the seed loop.
    seq_sys = ServingSystem(model, params, ccfg)
    seq_sys.generate_sequential(prompts[:2], max_new)       # warm compile
    seq_tps = _tokens_per_s(
        lambda: seq_sys.generate_sequential(prompts, max_new, mode="collm"),
        total, repeats)

    out = {"sequential": seq_tps}
    print("engine,slots,clients,max_new,tokens_per_s,speedup_vs_sequential")
    print(f"sequential,1,{n_clients},{max_new},{seq_tps:.1f},1.00")
    for slots in SLOT_COUNTS:
        sys_b = ServingSystem(model, params, ccfg)
        sys_b.generate(prompts[:slots], max_new, num_slots=slots)  # warm
        tps = _tokens_per_s(
            lambda: sys_b.generate(prompts, max_new, mode="collm",
                                   num_slots=slots), total, repeats)
        out[slots] = tps
        print(f"batched,{slots},{n_clients},{max_new},{tps:.1f},"
              f"{tps / seq_tps:.2f}")

    if check:
        speedup = out[8] / seq_tps
        assert speedup >= 3.0, (
            f"continuous batching at 8 slots is only {speedup:.2f}x the "
            f"sequential loop (acceptance bar: 3x)")
        print(f"# check passed: {speedup:.2f}x >= 3x at 8 slots")
    return out


PAGED_SLOT_COUNTS = (8, 16)


def run_paged(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
              theta: float = 0.8, repeats: int = 1) -> dict:
    """Dense vs. block-paged KV at 8/16 slots: aggregate decode tokens/s
    and pooled-KV device bytes per layout (the paged pool is sized to the
    dense-equivalent page count, so the bytes column isolates layout
    overhead; shrinking ``num_pages`` below that is the memory win)."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new
    out: dict = {}
    print("layout,slots,clients,max_new,tokens_per_s,kv_bytes")
    for layout in ("dense", "paged"):
        ccfg = CollmConfig(theta=theta, kv_layout=layout)
        for slots in PAGED_SLOT_COUNTS:
            sys_b = ServingSystem(model, params, ccfg)
            sys_b.generate(prompts[:slots], max_new, num_slots=slots)  # warm
            tps = _tokens_per_s(
                lambda: sys_b.generate(prompts, max_new, mode="collm",
                                       num_slots=slots), total, repeats)
            kv_bytes = max(s.kv_cache_bytes()
                           for s in sys_b._schedulers.values())
            out[(layout, slots)] = {"tokens_per_s": tps, "kv_bytes": kv_bytes}
            print(f"{layout},{slots},{n_clients},{max_new},{tps:.1f},"
                  f"{kv_bytes}")
    return out


ASYNC_SLOTS = 8
# virtual edge compute per decode tick: A100-class edge partition on the
# tiny split (the absolute value only scales the virtual axis; the
# overlap-vs-blocking *ratio* is what the bench measures)
TICK_TIME_S = 0.01


def run_channel(csv: bool = False, *, n_clients: int = 16, max_new: int = 24,
                theta: float = 0.8, check: bool = False) -> dict:
    """Async cloud channel vs. blocking dispatch under identical WiFi-class
    ``NetworkParams``, at 8 slots, in virtual time; plus a deadline-miss
    trace (reply latency >> deadline) showing the latency-aware early exit
    committing edge tokens instead of stalling."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = _requests(data, n_clients)
    total = n_clients * max_new
    ccfg = CollmConfig(theta=theta)
    out: dict = {}

    print("channel,dispatch,slots,virtual_s,virtual_ms_per_tok,wall_s,"
          "cloud_requests,deadline_misses,stall_s,overlap_s")
    for overlap in (False, True):
        ch = AsyncSimChannel(PAPER_NET, service_s=0.004)
        sysb = ServingSystem(model, params, ccfg)
        sysb.generate(prompts[:ASYNC_SLOTS], max_new,
                      num_slots=ASYNC_SLOTS, channel=ch,
                      tick_time_s=TICK_TIME_S, overlap=overlap)  # warm
        t0 = time.perf_counter()
        r = sysb.generate(prompts, max_new, mode="collm",
                          num_slots=ASYNC_SLOTS, channel=ch,
                          tick_time_s=TICK_TIME_S, overlap=overlap)
        wall = time.perf_counter() - t0
        st = r["stats"]
        name = "overlapped" if overlap else "blocking"
        out[name] = {"virtual_s": r["virtual_time"], "wall_s": wall,
                     "stats": st}
        print(f"wifi-sim,{name},{ASYNC_SLOTS},{r['virtual_time']:.3f},"
              f"{1e3 * r['virtual_time'] / total:.2f},{wall:.2f},"
              f"{st.cloud_requests},{st.deadline_misses},"
              f"{st.stall_s:.2f},{st.overlap_s:.2f}")

    # deadline-miss trace: every reply arrives long after its deadline
    ch = ScriptedChannel([0.5], deadline_s=0.02)
    sysd = ServingSystem(model, params, ccfg)
    r = sysd.generate(prompts, max_new, mode="collm", num_slots=ASYNC_SLOTS,
                      channel=ch, tick_time_s=TICK_TIME_S, fallback_after=4)
    st = r["stats"]
    complete = all(len(t) == max_new for t in r["tokens"])
    out["deadline"] = {"virtual_s": r["virtual_time"], "stats": st,
                       "complete": complete}
    print(f"deadline-trace,overlapped,{ASYNC_SLOTS},{r['virtual_time']:.3f},"
          f"{1e3 * r['virtual_time'] / total:.2f},-,{st.cloud_requests},"
          f"{st.deadline_misses},{st.stall_s:.2f},{st.overlap_s:.2f}")
    print(f"# deadline trace: {st.deadline_misses} misses -> edge-committed "
          f"tokens, {st.fallbacks} standalone fallbacks, all streams "
          f"complete: {complete}")

    if check:
        v_block = out["blocking"]["virtual_s"]
        v_over = out["overlapped"]["virtual_s"]
        assert v_over < v_block, (
            f"overlapped dispatch ({v_over:.3f}s virtual) should beat the "
            f"blocking path ({v_block:.3f}s virtual) at {ASYNC_SLOTS} slots")
        assert complete and st.deadline_misses > 0, (
            "deadline-miss trace must complete every stream via "
            "edge-committed tokens")
        print(f"# check passed: overlapped {v_over:.3f}s < blocking "
              f"{v_block:.3f}s virtual; deadline trace completed with "
              f"{st.deadline_misses} misses")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--theta", type=float, default=0.8)
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--check", action="store_true",
                    help="assert >=3x speedup at 8 slots (sync) / overlap "
                         "beats blocking + deadline trace completes (sim)")
    ap.add_argument("--kv-layout", choices=("dense", "paged", "both"),
                    default="dense",
                    help="paged/both: compare KV layouts at 8/16 slots")
    ap.add_argument("--channel", choices=("sync", "sim"), default="sync",
                    help="sim: async-transport comparison (overlap vs "
                         "blocking + deadline-miss trace) instead of the "
                         "slot sweep")
    args = ap.parse_args()
    if args.channel == "sim":
        run_channel(n_clients=args.clients, max_new=args.max_new,
                    theta=args.theta, check=args.check)
        return
    if args.kv_layout in ("dense", "both"):
        run(n_clients=args.clients, max_new=args.max_new, theta=args.theta,
            repeats=args.repeats, check=args.check)
    if args.kv_layout in ("paged", "both"):
        run_paged(n_clients=args.clients, max_new=args.max_new,
                  theta=args.theta, repeats=args.repeats)


if __name__ == "__main__":
    main()
