"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.netsim import ComputeParams, ModelSplit, NetworkParams

# A100-class constants calibrated in EXPERIMENTS.md §Table2 so that the
# cloud-based strategy lands on the paper's ~370 s / 100 Alpaca cases.
PAPER_COMP = ComputeParams(edge_layer_time=1.28e-3, cloud_layer_time=1.28e-3,
                           exit_head_time=1e-3)
PAPER_NET = NetworkParams(up_bw=3.8e6, down_bw=8e6, rtt=0.003)
PAPER_SPLIT = ModelSplit(n_layers=32, l_ee1=8, l_ee2=16, d_model=4096)


def time_call(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time (us) of a jitted call."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def tiny_trained_model(steps: int = 120, seed: int = 0) -> Dict:
    """Train the tiny EE model used by measured-trace benchmarks."""
    from repro.configs.base import ModelConfig
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.models.registry import build_model
    from repro.training.optim import AdamWConfig, init_adamw
    from repro.training.train_step import make_train_step

    cfg = ModelConfig(name="tiny-ee", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=256, tie_embeddings=True,
                      exit_layers=(1, 2)).validate()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_adamw(params)
    step = jax.jit(make_train_step(model, AdamWConfig(
        lr=1e-3, warmup_steps=10, total_steps=steps + 100)))
    data = SyntheticCorpus(DataConfig(vocab_size=256, seq_len=64,
                                      batch_size=8, kind="markov",
                                      seed=seed))
    for b in data.batches(steps):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, _ = step(params, opt, batch)
    return {"model": model, "params": params, "data": data}
