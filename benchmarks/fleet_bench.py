"""Trace-driven fleet simulation: adaptive control vs. static defaults
(docs/fleet_sim.md).

Two sweeps, both replaying bursty open-loop arrival traces
(``workload.ArrivalProcess``: gamma interarrivals + a diurnal ramp)
through the serving engine in virtual time, so every gated number is
deterministic:

``--fleet-window`` — N single-slot edge engines (``generate_multi``)
share one batching ``CloudServicePoint``.  ``static`` fixes the
accumulation window at the throughput bench's 4ms default; ``adaptive``
attaches a ``WindowController`` that sizes the window from the observed
request rate — 0 in the troughs (the window is pure latency tax when
nothing coalesces), ~(max_batch-1) mean gaps in the bursts.  Same
prompts, same arrivals, same service physics: the streams are
token-identical and only the latency distribution moves.

``--adaptive-pool`` — one 8-request fleet drains through a 4-slot paged
engine whose page budget is ~60% of worst-case demand, so bursts force
preemptions.  Both arms share one ``ResumeCostModel`` (resume costs are
billed into the virtual clock either way); ``static`` fixes
``preemption="recompute"`` with a zero watermark, ``adaptive`` adds the
engine-side ``AdaptiveController`` — watermark AIMD on observed
preemption/OutOfPages pressure, the fluid-ODE admission gate, and the
per-victim swap-vs-recompute choice priced by the shared cost model.

With ``--check`` each sweep asserts the adaptive arm beats (or ties)
the static defaults on p99 per-token latency AND SLO attainment at
equal token output; rows land in ``--json`` (BENCH_fleet.json).

    PYTHONPATH=src:. python benchmarks/fleet_bench.py --check
    PYTHONPATH=src:. python benchmarks/fleet_bench.py --fleet-window --check
    PYTHONPATH=src:. python benchmarks/fleet_bench.py --adaptive-pool --check
"""
from __future__ import annotations

import argparse
import json

from repro.core.collm import CollmConfig
from repro.core.transport import AsyncSimChannel, CloudServicePoint
from repro.core.workload import ArrivalProcess, arrival_times
from repro.serving.adaptive import (AdaptiveConfig, ResumeCostModel,
                                    WindowController)
from repro.serving.engine import ServingSystem

from benchmarks.common import PAPER_NET, tiny_trained_model

TICK_TIME_S = 0.01           # virtual edge compute per decode tick
CLOUD_SERVICE_S = 0.008      # one batched cloud service step
STATIC_WINDOW_S = 0.004      # the throughput bench's fixed default

# bursty day/night trace: clumped gamma arrivals (cv^2=4) riding a
# diurnal ramp — dense bursts where coalescing pays, sparse troughs
# where a fixed window is pure tax
FLEET_ARRIVALS = ArrivalProcess(rate=14.0, kind="gamma", cv2=4.0,
                                diurnal_amp=0.6, diurnal_period_s=1.2)
# per-stream SLOs (virtual s): TTFT from open-loop arrival to first
# token (queueing included), mean inter-token gap target
SLO_TTFT_S = 0.6
SLO_TPOT_S = 0.030


def _stat_row(name: str, r: dict) -> dict:
    st = r["stats"]
    return {
        "arm": name,
        "tokens": int(st.tokens),
        "virtual_s": r["virtual_time"],
        "ttft_p50_s": st.ttft_p(50), "ttft_p99_s": st.ttft_p(99),
        "token_lat_p50_s": st.token_lat_p(50),
        "token_lat_p99_s": st.token_lat_p(99),
        "slo_attainment": st.slo_attainment,
        "slo_total": st.slo_total, "slo_met": st.slo_met,
        "preemption_rate": st.preemption_rate,
        "deadline_miss_rate": st.deadline_miss_rate,
    }


def _print_row(row: dict) -> None:
    print(f"{row['arm']},{row['tokens']},{row['virtual_s']:.3f},"
          f"{1e3 * row['ttft_p50_s']:.1f},{1e3 * row['ttft_p99_s']:.1f},"
          f"{1e3 * row['token_lat_p50_s']:.2f},"
          f"{1e3 * row['token_lat_p99_s']:.2f},"
          f"{row['slo_attainment']:.3f},{row['preemption_rate']:.3f}")


def _check_adaptive_beats_static(sweep: str, static: dict,
                                 adaptive: dict) -> None:
    assert adaptive["tokens_equal"], (
        f"{sweep}: adaptive control must be token-invisible (same streams "
        f"as the static arm)")
    assert adaptive["token_lat_p99_s"] <= static["token_lat_p99_s"], (
        f"{sweep}: adaptive p99 token latency "
        f"{1e3 * adaptive['token_lat_p99_s']:.2f}ms should beat static "
        f"{1e3 * static['token_lat_p99_s']:.2f}ms")
    assert adaptive["slo_attainment"] >= static["slo_attainment"], (
        f"{sweep}: adaptive SLO attainment {adaptive['slo_attainment']:.3f} "
        f"should be >= static {static['slo_attainment']:.3f}")


# ---------------------------------------------------------------------------
# Sweep A: adaptive cloud batch window across a fleet of edge engines
# ---------------------------------------------------------------------------
def run_fleet_window(*, n_engines: int = 4, n_requests: int = 12,
                     max_new: int = 16, theta: float = 0.8, seed: int = 0,
                     check: bool = False, rows: list = None) -> dict:
    """N single-slot engines + one shared batching cloud, static 4ms
    accumulation window vs. rate-adaptive ``WindowController``, replaying
    the same bursty arrival trace."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = [data.sample_tokens(12) for _ in range(n_requests)]
    arrivals = arrival_times(FLEET_ARRIVALS, n_requests, seed=seed)

    out: dict = {}
    print("# fleet-window sweep: gamma cv2=4 + diurnal arrivals, "
          f"{n_engines} engines, shared cloud ({CLOUD_SERVICE_S * 1e3:.0f}ms "
          "service)")
    print("arm,tokens,virtual_s,ttft_p50_ms,ttft_p99_ms,lat_p50_ms,"
          "lat_p99_ms,slo_attainment,preempt_rate")
    for arm in ("static", "adaptive"):
        ctrl = (WindowController(max_window_s=STATIC_WINDOW_S)
                if arm == "adaptive" else None)
        svc = CloudServicePoint(CLOUD_SERVICE_S,
                                batch_window_s=STATIC_WINDOW_S,
                                max_batch=n_engines,
                                window_controller=ctrl)
        chans = [AsyncSimChannel(PAPER_NET, service=svc)
                 for _ in range(n_engines)]
        sysm = ServingSystem(model, params, CollmConfig(theta=theta))
        r = sysm.generate_multi(prompts, max_new, n_engines=n_engines,
                                channels=chans, tick_time_s=TICK_TIME_S,
                                arrivals=arrivals, slo_ttft_s=SLO_TTFT_S,
                                slo_tpot_s=SLO_TPOT_S)
        row = dict(_stat_row(arm, r), mode="fleet_window",
                   n_engines=n_engines, n_requests=n_requests,
                   max_new=max_new,
                   window_adjustments=(ctrl.adjustments if ctrl else 0),
                   cloud_batches=svc.batches)
        out[arm] = dict(row, tokens_list=r["tokens"])
        if rows is not None:
            rows.append(row)
        _print_row(row)
    out["adaptive"]["tokens_equal"] = (
        out["adaptive"]["tokens_list"] == out["static"]["tokens_list"])

    if check:
        _check_adaptive_beats_static("fleet-window", out["static"],
                                     out["adaptive"])
        assert out["adaptive"]["window_adjustments"] > 0, \
            "the window controller never adjusted the window"
        print(f"# check passed: adaptive window p99 "
              f"{1e3 * out['adaptive']['token_lat_p99_s']:.2f}ms <= static "
              f"{1e3 * out['static']['token_lat_p99_s']:.2f}ms, SLO "
              f"{out['adaptive']['slo_attainment']:.3f} >= "
              f"{out['static']['slo_attainment']:.3f}; streams identical")
    return out


# ---------------------------------------------------------------------------
# Sweep B: adaptive paged-pool control on an oversubscribed engine
# ---------------------------------------------------------------------------
POOL_SLOTS = 4
POOL_FRAC = 0.6              # page budget vs. worst-case demand
# shared resume physics: modest host link so swap-vs-recompute actually
# crosses over with context length instead of one mode dominating
RESUME_COST = ResumeCostModel(d0_s=0.004, d1_s=2.0e-4, host_bw=2.0e7)
POOL_ARRIVALS = ArrivalProcess(rate=30.0, kind="gamma", cv2=4.0,
                               diurnal_amp=0.5, diurnal_period_s=0.8)


def run_adaptive_pool(*, n_requests: int = 8, max_new: int = 16,
                      theta: float = 0.8, seed: int = 0,
                      check: bool = False, rows: list = None) -> dict:
    """Oversubscribed paged engine under a bursty open-loop trace:
    static (fixed recompute resume, zero watermark) vs. adaptive
    (watermark AIMD + fluid admission gate + per-victim resume mode),
    both billing resume costs from the SAME ``ResumeCostModel``."""
    tiny = tiny_trained_model()
    model, params, data = tiny["model"], tiny["params"], tiny["data"]
    prompts = [data.sample_tokens(12) for _ in range(n_requests)]
    arrivals = arrival_times(POOL_ARRIVALS, n_requests, seed=seed)
    ps = CollmConfig(kv_layout="paged").page_size
    worst = max((len(p) + max_new - 1) // ps + 1 for p in prompts)
    budget = max(worst, int(POOL_FRAC * POOL_SLOTS * worst))

    out: dict = {}
    print(f"# adaptive-pool sweep: {POOL_SLOTS} slots, {budget} pages "
          f"(~{100 * POOL_FRAC:.0f}% of worst-case), bursty arrivals")
    print("arm,tokens,virtual_s,ttft_p50_ms,ttft_p99_ms,lat_p50_ms,"
          "lat_p99_ms,slo_attainment,preempt_rate")
    for arm in ("static", "adaptive"):
        pre = "recompute" if arm == "static" else "swap"
        sysv = ServingSystem(model, params,
                             CollmConfig(theta=theta, kv_layout="paged",
                                         preemption=pre))
        kw = dict(num_slots=POOL_SLOTS, num_pages=budget,
                  tick_time_s=TICK_TIME_S, arrivals=arrivals,
                  slo_ttft_s=SLO_TTFT_S, slo_tpot_s=SLO_TPOT_S,
                  resume_cost=RESUME_COST)
        if arm == "adaptive":
            kw["adaptive"] = AdaptiveConfig()
        r = sysv.generate(prompts, max_new, mode="collm", **kw)
        row = dict(_stat_row(arm, r), mode="adaptive_pool",
                   slots=POOL_SLOTS, pages=budget, n_requests=n_requests,
                   max_new=max_new, preemptions=r["preemptions"],
                   oops=r["oops"], adaptive=r["adaptive"])
        out[arm] = dict(row, tokens_list=r["tokens"])
        if rows is not None:
            rows.append(row)
        _print_row(row)
    out["adaptive"]["tokens_equal"] = (
        out["adaptive"]["tokens_list"] == out["static"]["tokens_list"])

    if check:
        _check_adaptive_beats_static("adaptive-pool", out["static"],
                                     out["adaptive"])
        assert out["static"]["preemptions"] >= 1, (
            f"the {budget}-page budget should force at least one "
            f"preemption in the static arm")
        print(f"# check passed: adaptive pool p99 "
              f"{1e3 * out['adaptive']['token_lat_p99_s']:.2f}ms <= static "
              f"{1e3 * out['static']['token_lat_p99_s']:.2f}ms, SLO "
              f"{out['adaptive']['slo_attainment']:.3f} >= "
              f"{out['static']['slo_attainment']:.3f}; "
              f"{out['static']['preemptions']} vs "
              f"{out['adaptive']['preemptions']} preemptions; streams "
              f"identical")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engines", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--theta", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="assert adaptive beats static on p99 token "
                         "latency + SLO attainment at equal token output")
    ap.add_argument("--json", default="BENCH_fleet.json",
                    help="machine-readable sweep rows")
    ap.add_argument("--fleet-window", action="store_true",
                    help="run only the shared-cloud window sweep")
    ap.add_argument("--adaptive-pool", action="store_true",
                    help="run only the oversubscribed paged-pool sweep")
    args = ap.parse_args()
    both = not (args.fleet_window or args.adaptive_pool)
    rows: list = []
    if args.fleet_window or both:
        run_fleet_window(n_engines=args.engines, n_requests=args.requests,
                         max_new=args.max_new, theta=args.theta,
                         seed=args.seed, check=args.check, rows=rows)
    if args.adaptive_pool or both:
        run_adaptive_pool(n_requests=min(args.requests, 8),
                          max_new=args.max_new, theta=args.theta,
                          seed=args.seed, check=args.check, rows=rows)
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
