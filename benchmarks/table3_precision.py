"""Paper Table 3 + §4.3: effect of early-exit thresholds and transport
precision on predictions, measured on the REAL tiny EE model (not the
simulator): generation agreement vs the float32 undivided model, plus the
paper's hidden-state range check (fp16 representability)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.collm import CollmConfig
from repro.serving.engine import ServingSystem, token_agreement

from benchmarks.common import tiny_trained_model


def run(csv=True, n_prompts=4, gen=24):
    tt = tiny_trained_model()
    model, params, data = tt["model"], tt["params"], tt["data"]
    prompts = [data.sample_tokens(12) for _ in range(n_prompts)]
    base = ServingSystem(model, params, CollmConfig(theta=1.0)).generate(
        prompts, gen, mode="cloud")

    rows = []
    for theta in (0.8, 0.9, 1.0):
        for fmt in ("float32", "float16", "int8"):
            sysx = ServingSystem(model, params,
                                 CollmConfig(theta=theta, wire_format=fmt))
            r = sysx.generate(prompts, gen, mode="collm")
            ag = float(np.mean([token_agreement(a, b) for a, b in
                                zip(r["tokens"], base["tokens"])]))
            rows.append({"table": "table3", "theta": theta, "wire": fmt,
                         "agreement_lcsf1": round(ag, 4),
                         "request_rate_pct":
                             round(100 * r["stats"].request_rate, 1)})

    # paper §4.3: hidden-state range vs float16 representable range
    caches = model.init_cache(1, 64)
    x, exit_h, _, _ = model.prefill(
        params, {"tokens": jnp.asarray(prompts[0][None, :])}, caches)
    h = exit_h[model.cfg.exit_layers[0]]
    hmin, hmax = float(h.min()), float(h.max())
    rows.append({"table": "table3_range", "hidden_min": round(hmin, 2),
                 "hidden_max": round(hmax, 2),
                 "fp16_safe": bool(-65504 < hmin and hmax < 65504)})
    if csv:
        for row in rows:
            if row["table"] == "table3":
                print(f"table3,{row['theta']},{row['wire']},"
                      f"{row['agreement_lcsf1']},{row['request_rate_pct']}")
            else:
                print(f"table3_range,{row['hidden_min']},{row['hidden_max']},"
                      f"{row['fp16_safe']}")
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1))
