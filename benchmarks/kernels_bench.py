"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-times are reported for the jnp reference paths (the semantics the
kernels implement); kernel-vs-ref allclose is asserted as part of the run.
On TPU the same harness times the compiled kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attn.ops import flash_decode, flash_decode_paged
from repro.kernels.decode_attn.ref import (decode_attn_paged_ref,
                                           decode_attn_ref)
from repro.kernels.exit_head.ops import exit_confidence
from repro.kernels.exit_head.ref import exit_head_ref
from repro.kernels.exit_quant.ops import exit_quant
from repro.kernels.exit_quant.ref import exit_quant_ref
from repro.kernels.quantize.ops import quantize_int8
from repro.kernels.quantize.ref import quantize_int8_ref

from benchmarks.common import time_call


def _best_call(fn, *args, iters: int = 200) -> float:
    """Best-of-N wall time (us): the de-noised statistic for dispatch-bound
    calls, where the median on a shared runner drowns the effect."""
    import time as _t
    for _ in range(5):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = _t.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, _t.perf_counter() - t0)
    return best * 1e6


def _paged_pool(seed: int, num_pages: int, ps: int, kvh: int, d: int,
                b: int, n_lp: int):
    """Random fully-mapped page pool: every slot owns ``n_lp`` pages."""
    rng = np.random.RandomState(seed)
    P = num_pages + 1                                    # + trash page
    kp = jnp.asarray(rng.randn(P, ps, kvh, d).astype(np.float32))
    vp = jnp.asarray(rng.randn(P, ps, kvh, d).astype(np.float32))
    tbl = jnp.asarray(1 + np.arange(b * n_lp).reshape(b, n_lp) % num_pages,
                      jnp.int32)
    # all rows valid (pos <= cur): the timing sweep measures the full read
    pos = jnp.broadcast_to(jnp.arange(ps)[None], (P, ps)).astype(jnp.int32)
    cur = jnp.full((b,), n_lp * ps - 1, jnp.int32)
    return kp, vp, pos, tbl, cur


def run(csv=True):
    rows = []
    rng = jax.random.PRNGKey(0)
    on_tpu = jax.default_backend() == "tpu"

    # exit head: B x d @ V
    for b, d, v in [(8, 1024, 32000), (16, 2048, 49152)]:
        h = jax.random.normal(rng, (b, d))
        w = jax.random.normal(jax.random.PRNGKey(1), (v, d)) * 0.02
        ns = jnp.zeros((d,))
        ref = jax.jit(exit_head_ref)
        us = time_call(ref, h, w, ns, iters=10)
        rows.append({"name": f"exit_head_b{b}_d{d}_v{v}",
                     "us_per_call": round(us, 1),
                     "derived_gflops": round(2 * b * d * v / us / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})

    # flash decode: long-cache single token
    for b, h_, kv, d, s in [(4, 8, 2, 128, 8192), (1, 16, 8, 128, 32768)]:
        q = jax.random.normal(rng, (b, h_, d))
        k = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d))
        v = jax.random.normal(jax.random.PRNGKey(3), (b, s, kv, d))
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        cur = jnp.asarray(s - 1, jnp.int32)
        ref = jax.jit(decode_attn_ref)
        us = time_call(ref, q, k, v, pos, cur, iters=10)
        rows.append({"name": f"decode_attn_b{b}_h{h_}_s{s}",
                     "us_per_call": round(us, 1),
                     "derived_gbps": round(
                         2 * b * s * kv * d * 4 / us / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})

    # int8 quantize
    for n, d in [(1024, 4096)]:
        x = jax.random.normal(rng, (n, d))
        ref = jax.jit(quantize_int8_ref)
        us = time_call(ref, x, iters=10)
        rows.append({"name": f"quantize_int8_{n}x{d}",
                     "us_per_call": round(us, 1),
                     "derived_gbps": round(n * d * 4 / us / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})

    # paged flash decode, float32 vs int8 pages: same logical cache, the
    # int8 pool's HBM column shrinks ~4x (int8 data + fp32 per-row scale)
    for b, h_, kv, d, ps, n_lp in [(4, 8, 2, 128, 64, 16)]:
        num_pages = b * n_lp
        kp, vp, pos, tbl, cur = _paged_pool(5, num_pages, ps, kv, d, b, n_lp)
        q = jax.random.normal(rng, (b, h_, d))
        s = n_lp * ps
        f32_bytes = 2 * b * s * kv * d * 4          # K+V read per call
        ref = jax.jit(decode_attn_paged_ref)
        us = time_call(ref, q, kp, vp, pos, tbl, cur, iters=10)
        rows.append({"name": f"decode_attn_paged_f32_b{b}_s{s}",
                     "us_per_call": round(us, 1),
                     "hbm_bytes": f32_bytes,
                     "derived_gbps": round(f32_bytes / us / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})
        qk, sk = quantize_int8_ref(kp.reshape(-1, d))
        qv, sv = quantize_int8_ref(vp.reshape(-1, d))
        qk = qk.reshape(kp.shape)
        sk = sk.reshape(kp.shape[:3])
        qv = qv.reshape(vp.shape)
        sv = sv.reshape(vp.shape[:3])
        i8_bytes = 2 * b * s * kv * (d * 1 + 4)     # int8 data + fp32 scale
        refq = jax.jit(lambda *a: decode_attn_paged_ref(
            *a[:6], k_scale=a[6], v_scale=a[7]))
        us = time_call(refq, q, qk, qv, pos, tbl, cur, sk, sv, iters=10)
        rows.append({"name": f"decode_attn_paged_int8_b{b}_s{s}",
                     "us_per_call": round(us, 1),
                     "hbm_bytes": i8_bytes,
                     "derived_gbps": round(i8_bytes / us / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})

    # fused exit-head + quantize vs the two-launch baseline it replaces:
    # both passes read the same (B, d) hidden; the fusion saves one
    # dispatch and one HBM re-read of the hidden tile.  Timed at the
    # serving hot-path shape (a handful of decode slots x one token), where
    # the per-dispatch overhead the fusion removes is the dominant cost —
    # and with best-of-N timing, since median wall-clock on a shared CPU
    # runner is too noisy to resolve a dispatch
    for b, d, v in [(8, 128, 256)]:
        h = jax.random.normal(rng, (b, d))
        w = jax.random.normal(jax.random.PRNGKey(6), (v, d)) * 0.02
        ns = jnp.zeros((d,))
        hbm = b * d * 4 + v * d * 4 + b * d         # hidden + W + int8 out
        fused = jax.jit(exit_quant_ref)
        us_f = _best_call(fused, h, w, ns)
        eh = jax.jit(exit_head_ref)
        qz = jax.jit(quantize_int8_ref)
        two = lambda h_, w_, ns_: (eh(h_, w_, ns_), qz(h_))
        us_2 = _best_call(two, h, w, ns)
        rows.append({"name": f"exit_quant_fused_b{b}_d{d}_v{v}",
                     "us_per_call": round(us_f, 1), "hbm_bytes": hbm,
                     "derived_gbps": round(hbm / us_f / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})
        rows.append({"name": f"exit_quant_twolaunch_b{b}_d{d}_v{v}",
                     "us_per_call": round(us_2, 1),
                     "hbm_bytes": hbm + b * d * 4,  # hidden read twice
                     "derived_gbps": round((hbm + b * d * 4) / us_2 / 1e3, 2),
                     "path": "ref(jit) x2"})
        assert us_f <= us_2, (
            f"fused exit_quant ({us_f:.1f}us) should beat the two-launch "
            f"baseline ({us_2:.1f}us) at b={b} d={d} v={v}")

    # correctness cross-check (kernel interpret vs ref) on reduced shapes
    h = jax.random.normal(rng, (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(4), (1024, 128)) * 0.05
    c1, t1, _ = exit_confidence(h, w, jnp.zeros(128), block_v=256)
    c2, t2, _ = exit_head_ref(h, w, jnp.zeros(128))
    assert bool(jnp.all(t1 == t2)) and float(jnp.max(jnp.abs(c1 - c2))) < 1e-5
    cf, tf, _, qf, sf = exit_quant(h, w, jnp.zeros(128), block_v=256,
                                   interpret=True)
    cr, tr, _, qr, sr = exit_quant_ref(h, w, jnp.zeros(128))
    assert bool(jnp.all(tf == tr)) and bool(jnp.all(qf == qr))
    assert float(jnp.max(jnp.abs(cf - cr))) < 1e-5
    kp, vp, pos, tbl, cur = _paged_pool(7, 8, 8, 2, 32, 2, 4)
    qsm = jax.random.normal(rng, (2, 4, 32))
    o_k = flash_decode_paged(qsm, kp, vp, pos, tbl, cur, interpret=True)
    o_r = decode_attn_paged_ref(qsm, kp, vp, pos, tbl, cur)
    assert float(jnp.max(jnp.abs(o_k - o_r))) < 2e-5
    qk, sk = quantize_int8_ref(kp.reshape(-1, 32))
    qv, sv = quantize_int8_ref(vp.reshape(-1, 32))
    qk, sk = qk.reshape(kp.shape), sk.reshape(kp.shape[:3])
    qv, sv = qv.reshape(vp.shape), sv.reshape(vp.shape[:3])
    o_k8 = flash_decode_paged(qsm, qk, qv, pos, tbl, cur, k_scale=sk,
                              v_scale=sv, interpret=True)
    o_r8 = decode_attn_paged_ref(qsm, qk, qv, pos, tbl, cur, k_scale=sk,
                                 v_scale=sv)
    assert float(jnp.max(jnp.abs(o_k8 - o_r8))) < 2e-5
    rows.append({"name": "kernel_vs_ref_allclose", "us_per_call": 0,
                 "derived": "pass"})
    if csv:
        for row in rows:
            print(f"kernels,{row['name']},{row['us_per_call']},"
                  f"{row.get('derived_gflops', row.get('derived_gbps', row.get('derived', '')))}")
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1))
