"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-times are reported for the jnp reference paths (the semantics the
kernels implement); kernel-vs-ref allclose is asserted as part of the run.
On TPU the same harness times the compiled kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attn.ops import flash_decode
from repro.kernels.decode_attn.ref import decode_attn_ref
from repro.kernels.exit_head.ops import exit_confidence
from repro.kernels.exit_head.ref import exit_head_ref
from repro.kernels.quantize.ops import quantize_int8
from repro.kernels.quantize.ref import quantize_int8_ref

from benchmarks.common import time_call


def run(csv=True):
    rows = []
    rng = jax.random.PRNGKey(0)
    on_tpu = jax.default_backend() == "tpu"

    # exit head: B x d @ V
    for b, d, v in [(8, 1024, 32000), (16, 2048, 49152)]:
        h = jax.random.normal(rng, (b, d))
        w = jax.random.normal(jax.random.PRNGKey(1), (v, d)) * 0.02
        ns = jnp.zeros((d,))
        ref = jax.jit(exit_head_ref)
        us = time_call(ref, h, w, ns, iters=10)
        rows.append({"name": f"exit_head_b{b}_d{d}_v{v}",
                     "us_per_call": round(us, 1),
                     "derived_gflops": round(2 * b * d * v / us / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})

    # flash decode: long-cache single token
    for b, h_, kv, d, s in [(4, 8, 2, 128, 8192), (1, 16, 8, 128, 32768)]:
        q = jax.random.normal(rng, (b, h_, d))
        k = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d))
        v = jax.random.normal(jax.random.PRNGKey(3), (b, s, kv, d))
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        cur = jnp.asarray(s - 1, jnp.int32)
        ref = jax.jit(decode_attn_ref)
        us = time_call(ref, q, k, v, pos, cur, iters=10)
        rows.append({"name": f"decode_attn_b{b}_h{h_}_s{s}",
                     "us_per_call": round(us, 1),
                     "derived_gbps": round(
                         2 * b * s * kv * d * 4 / us / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})

    # int8 quantize
    for n, d in [(1024, 4096)]:
        x = jax.random.normal(rng, (n, d))
        ref = jax.jit(quantize_int8_ref)
        us = time_call(ref, x, iters=10)
        rows.append({"name": f"quantize_int8_{n}x{d}",
                     "us_per_call": round(us, 1),
                     "derived_gbps": round(n * d * 4 / us / 1e3, 2),
                     "path": "kernel" if on_tpu else "ref(jit)"})

    # correctness cross-check (kernel interpret vs ref) on reduced shapes
    h = jax.random.normal(rng, (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(4), (1024, 128)) * 0.05
    c1, t1, _ = exit_confidence(h, w, jnp.zeros(128), block_v=256)
    c2, t2, _ = exit_head_ref(h, w, jnp.zeros(128))
    assert bool(jnp.all(t1 == t2)) and float(jnp.max(jnp.abs(c1 - c2))) < 1e-5
    rows.append({"name": "kernel_vs_ref_allclose", "us_per_call": 0,
                 "derived": "pass"})
    if csv:
        for row in rows:
            print(f"kernels,{row['name']},{row['us_per_call']},"
                  f"{row.get('derived_gflops', row.get('derived_gbps', row.get('derived', '')))}")
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1))
