"""Benchmark harness — one entry per paper table/figure + roofline.

Prints ``name,...`` CSV lines.  Heavy model-based benches (table3) train a
tiny EE model on the fly (~30 s on CPU)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig4_scaling, kernels_bench, roofline_table,
                            table2_deployment, table3_precision,
                            table4_ablation, throughput_bench)
    benches = [
        ("table2", table2_deployment.run),
        ("table4", table4_ablation.run),
        ("fig4", fig4_scaling.run),
        ("table3", table3_precision.run),
        ("kernels", kernels_bench.run),
        ("roofline", roofline_table.run),
        ("throughput", throughput_bench.run),
        ("paged_kv", throughput_bench.run_paged),
        ("async_channel", throughput_bench.run_channel),
        ("cloud_batch", throughput_bench.run_cloud_batch),
    ]
    failures = []
    for name, fn in benches:
        print(f"# --- {name} ---", flush=True)
        try:
            fn(csv=True)
        except Exception as e:
            failures.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
