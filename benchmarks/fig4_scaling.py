"""Paper Fig 4: multi-edge-client scaling (1..5 clients), CE-CoLLM vs
cloud-based deployment, both datasets, theta in {0.8, 0.9}."""
from __future__ import annotations

from repro.core.netsim import simulate
from repro.core.workload import ALPACA, XSUM, paper_calibrated_cases, \
    split_clients

from benchmarks.common import PAPER_COMP, PAPER_NET, PAPER_SPLIT


def run(csv=True):
    rows = []
    for prof in (ALPACA, XSUM):
        for n in range(1, 6):
            # each client serves the full 100-case workload replicated, as in
            # the paper (total work grows with client count)
            cases = paper_calibrated_cases(prof, 100, seed=1)
            clients = [list(cases) for _ in range(n)]
            rc = simulate("cloud_llm", clients, PAPER_NET, PAPER_COMP,
                          PAPER_SPLIT)
            rows.append({"table": "fig4", "dataset": prof.name,
                         "clients": n, "strategy": "cloud_llm", **rc.as_row()})
            for theta in (0.8, 0.9):
                r = simulate("ce_collm", clients, PAPER_NET, PAPER_COMP,
                             PAPER_SPLIT, theta=theta)
                rows.append({"table": "fig4", "dataset": prof.name,
                             "clients": n, "strategy": f"ce_collm@{theta}",
                             **r.as_row()})
    if csv:
        for row in rows:
            print(f"fig4,{row['dataset']},{row['clients']},"
                  f"{row['strategy']},{row['total_s']}")
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(csv=False), indent=1))
