"""§Roofline: three-term roofline per (arch x shape x mesh) from the
dry-run artifacts in artifacts/dryrun/."""
from __future__ import annotations

import glob
import json
import os

from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES_BY_NAME
from repro.roofline.analyze import analyze


def run(csv=True, art_dir="artifacts/dryrun", opt_dir="artifacts/opt",
        out_csv="artifacts/roofline.csv"):
    rows = []
    for label, d in (("baseline", art_dir), ("optimized", opt_dir)):
        for path in sorted(glob.glob(os.path.join(d, "*.json"))):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") != "ok" or "shape" not in rec:
                continue   # skip two-tier (collm_*) artifacts
            cfg = get_config(rec["arch"])
            shape = SHAPES_BY_NAME[rec["shape"]]
            terms = analyze(rec, cfg, shape)
            row = terms.row()
            row["pass"] = label
            ma = rec.get("memory_analysis", {})
            row["hbm_gb"] = round((ma.get("argument_size_in_bytes", 0)
                                   + ma.get("temp_size_in_bytes", 0))
                                  / 2 ** 30, 2)
            row["fits_16gb"] = row["hbm_gb"] <= 16.0
            rows.append(row)
    if csv and rows:
        os.makedirs(os.path.dirname(out_csv), exist_ok=True)
        cols = list(rows[0].keys())
        with open(out_csv, "w") as f:
            f.write(",".join(cols) + "\n")
            for row in rows:
                f.write(",".join(str(row[c]) for c in cols) + "\n")
        for row in rows:
            print("roofline," + ",".join(str(row[c]) for c in cols))
    elif csv:
        print("roofline,NO_ARTIFACTS (run: python -m repro.launch.dryrun --all)")
    return rows


if __name__ == "__main__":
    import json as _j
    print(_j.dumps(run(csv=False), indent=1))
